from repro.data.pipeline import SyntheticTokenPipeline, make_batch_specs  # noqa: F401
