"""Deterministic synthetic token pipeline with background prefetch.

Two modes:
* ``affine`` — next token = (31 * tok + 7) % vocab: a *learnable* stream so
  the end-to-end training example shows loss actually dropping;
* ``random`` — i.i.d. tokens (throughput benchmarking; loss floor = ln V).

Determinism: batch ``i`` depends only on (seed, i) — a restarted job
resumes mid-stream with identical data (required for checkpoint/restart
tests to be exact). The pipeline is sharding-aware: with a mesh it places
each batch as a global device array under the 'batch' logical rule.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding.specs import LogicalRules, to_named_sharding


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(name -> (shape, dtype, logical)) for the train batch of this arch."""
    b, t = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {
            "src_embeds": ((b, t, cfg.d_model), jnp.bfloat16, ("batch", "seq", None)),
            "tgt_tokens": ((b, t), jnp.int32, ("batch", "seq")),
            "targets": ((b, t), jnp.int32, ("batch", "seq")),
        }
    if cfg.family == "vlm":
        return {
            "embeds": ((b, t, cfg.d_model), jnp.bfloat16, ("batch", "seq", None)),
            "targets": ((b, t), jnp.int32, ("batch", "seq")),
        }
    return {
        "tokens": ((b, t), jnp.int32, ("batch", "seq")),
        "targets": ((b, t), jnp.int32, ("batch", "seq")),
    }


class SyntheticTokenPipeline:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        *,
        seed: int = 0,
        mode: str = "affine",
        mesh=None,
        rules: LogicalRules | None = None,
        prefetch: int = 2,
        start_batch: int = 0,
    ):
        self.cfg, self.shape = cfg, shape
        self.seed, self.mode = seed, mode
        self.mesh, self.rules = mesh, rules
        self.index = start_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # ----------------------------------------------------------- generation

    def _host_batch(self, index: int) -> dict[str, np.ndarray]:
        b, t = self.shape.global_batch, self.shape.seq_len
        v = max(2, self.cfg.vocab_size)
        rng = np.random.default_rng((self.seed, index))
        if self.mode == "affine":
            first = rng.integers(0, v, size=(b, 1), dtype=np.int64)
            seq = [first]
            for _ in range(t):
                seq.append((31 * seq[-1] + 7) % v)
            stream = np.concatenate(seq, axis=1)  # (b, t+1)
        else:
            stream = rng.integers(0, v, size=(b, t + 1), dtype=np.int64)
        tokens = stream[:, :t].astype(np.int32)
        targets = stream[:, 1:].astype(np.int32)
        out: dict[str, np.ndarray] = {}
        for name, (shp, dtype, _) in make_batch_specs(self.cfg, self.shape).items():
            if name in ("tokens", "tgt_tokens"):
                out[name] = tokens
            elif name == "targets":
                out[name] = targets
            else:  # stub frontend embeddings, derived deterministically
                emb = rng.standard_normal(size=shp).astype(np.float32) * 0.02
                out[name] = emb
        return out

    def _place(self, host: dict[str, np.ndarray]):
        if self.mesh is None or self.rules is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        specs = make_batch_specs(self.cfg, self.shape)
        placed = {}
        for name, arr in host.items():
            shp, dtype, logical = specs[name]
            sharding = to_named_sharding(self.mesh, shp, logical, self.rules)
            placed[name] = jax.device_put(jnp.asarray(arr, dtype), sharding)
        return placed

    def _producer(self):
        while not self._stop.is_set():
            batch = self._host_batch(self.index)
            self.index += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.25)
                    break
                except queue.Full:
                    continue

    # ----------------------------------------------------------- iteration

    def __iter__(self):
        return self

    def __next__(self):
        return self._place(self._q.get())

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
