"""Continuous-batching decode loop over the paged KV arena.

The scheduler's micro-batching coalesces decode steps that happen to
arrive inside one window; between windows the (possibly fused) instance
idles while every client round-trips its own future. The continuous
batcher replaces that rendezvous with a *persistent in-flight batch*: one
decode loop drives a fixed power-of-two-capacity batch step after step,
and requests JOIN the batch at any step boundary (post-prefill) and LEAVE
on EOS or their step limit. Empty slots are masked — their block-table
rows point at the arena's scratch page and their ``cur_len`` is zero — so
the compiled program shape never changes and no request ever waits for a
batch to "form".

Admission runs through SLO class lanes (:class:`ClassLanes`): when a slot
frees, the waiting request of the *strictest* class takes it first — the
slot-assignment analogue of the admission queues' window preemption. A
transient :class:`~repro.serving.kvpool.ArenaFull` re-queues the request at
the front of its lane; optionally best-effort arrivals beyond
``max_queue`` are shed (fail fast) so an overload degrades background
traffic before strict classes queue.

Chunked prefill: a joiner's prompt no longer serializes in front of the
batch. Admission starts a *prefill job* (pages allocated through the
arena's shared-prefix cache) and the loop advances it ONE budgeted chunk
between decode steps, so residents keep emitting while the joiner's
prompt streams in. The per-step chunk budget comes from the strict lane's
inter-token slack: with EWMA estimates of per-token prefill time and the
batch step time (same :class:`ServiceTimeEstimate` the queueing windows
use), the budget is the token count that fits inside
``slack_fraction x min-strict-slack - step_time``, floored at
``min_chunk`` so prefills always progress. ``serialize_prefill=True``
restores the old admit-time full prefill (the comparison baseline), and
``prefill_chunk=N`` pins the chunk size for deterministic tests.

Every request's RAM bill is its pages: on exit the batcher records an
:class:`~repro.core.billing.ArenaLease` — peak pages held x page bytes x
residency seconds — the per-request GB-s the paper's RAM-reduction story
is about.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.billing import ArenaLease
from repro.scheduler.adaptive import ServiceTimeEstimate
from repro.scheduler.batching import largest_pow2_le
from repro.scheduler.scheduler import OverloadShedError
from repro.scheduler.slo import BEST_EFFORT, ClassLanes, SLOClass
from repro.serving.engine import ServingEngine, _greedy_token
from repro.serving.kvpool import ArenaFull, KVArena


class ShedError(OverloadShedError):
    """Best-effort request shed at admission (batcher queue bound hit).
    Subclasses the scheduler's OverloadShedError so one except clause
    implements a client's back-off policy for both admission paths."""


def _deliver(future: Future, *, result=None, exc=None) -> None:
    """Resolve a future the client may have CANCELLED meanwhile — the
    InvalidStateError must not fail co-resident requests or kill the decode
    loop thread (same contract as the coalescer's _resolve)."""
    try:
        if exc is not None:
            future.set_exception(exc)
        else:
            future.set_result(result)
    except Exception:
        if not future.cancelled():
            raise


class _Request:
    __slots__ = (
        "inputs", "max_new_tokens", "eos_id", "slo", "future",
        "t_submit", "t_alloc", "t_admit", "tokens", "step_s", "seq_id",
        "cur_len", "remaining", "next_token", "last_emit", "job",
        "span", "psid",
    )

    def __init__(self, inputs, max_new_tokens, eos_id, slo, future, t_submit):
        self.inputs = inputs
        self.max_new_tokens = int(max_new_tokens)
        self.eos_id = eos_id
        self.slo = slo
        self.future = future
        self.t_submit = t_submit
        self.t_alloc = 0.0
        self.t_admit = 0.0
        self.tokens: list[int] = []
        self.step_s: list[float] = []
        self.seq_id = None
        self.cur_len = 0
        self.remaining = 0
        self.next_token = 0
        self.last_emit = 0.0
        self.job = None  # PagedPrefillJob while the chunked prefill runs
        self.span = None  # obs.SpanContext root (None when tracing off)
        self.psid = None  # pre-allocated prefill-stall span id (chunk parent)


class ContinuousBatcher:
    """Persistent decode batch over a paged ServingEngine.

    ``capacity`` clamps to the largest power of two <= the request (one
    compiled program serves every step). ``max_queue`` (optional) bounds
    the admission lanes: best-effort arrivals beyond it are shed.

    The batcher assumes exclusive use of the engine's arena while running:
    all page allocation and all decode-step store-backs happen on its one
    loop thread (don't interleave ``generate_paged`` with a live batcher)."""

    # provlint: submit-side state shared with the loop thread. Slot state
    # (_slots/_bt/_cur/_tok/...) is loop-thread-only and needs no lock.
    GUARDED_FIELDS = {
        "_lanes": "_cv",
        "_stopped": "_cv",
        "shed": "_cv",
    }

    def __init__(self, engine: ServingEngine, *, capacity: int = 8,
                 max_queue: int | None = None,
                 prefill_chunk: int | None = None,
                 serialize_prefill: bool = False,
                 min_chunk: int = 8,
                 slack_fraction: float = 0.5):
        if engine.arena is None:
            raise ValueError("engine needs enable_paging() before continuous batching")
        self.engine = engine
        self.clock = engine.platform.clock
        self.capacity = largest_pow2_le(capacity)
        self.max_queue = max_queue
        self.prefill_chunk = prefill_chunk      # fixed chunk size override
        self.serialize_prefill = serialize_prefill
        self.min_chunk = max(1, int(min_chunk))
        self.slack_fraction = float(slack_fraction)
        self._est_prefill = ServiceTimeEstimate()  # seconds per PREFILL TOKEN
        self._est_step = ServiceTimeEstimate()     # seconds per batch decode step
        self._job: _Request | None = None          # the one in-flight chunked prefill
        self.prefill_chunks = 0
        self._slots: list[_Request | None] = [None] * self.capacity
        # persistent per-slot step inputs: block-table rows are rebuilt only
        # when a slot's page set changes (join / page-boundary extend /
        # leave), not on every step — empty rows stay all-scratch
        self._bt = np.zeros((self.capacity, engine.block_width), np.int32)
        self._cur = np.zeros((self.capacity,), np.int32)
        self._tok = np.zeros((self.capacity, 1), np.int32)
        self._lanes = ClassLanes()
        self._cv = threading.Condition()
        self._stopped = False
        self._seq = 0
        self.steps = 0
        self.tokens_out = 0
        self.completed = 0
        self.shed = 0
        self._occupancy_sum = 0
        # obs.Tracer (duck-typed): every submit mints a "serve" trace whose
        # queue-wait / prefill-stall (+ chunk children) / batch-compute
        # phases tile [t_submit, t_done] exactly
        self._tracer = getattr(engine.platform, "tracer", None)
        self._thread = threading.Thread(target=self._loop, daemon=True, name="continuous-batcher")
        self._thread.start()

    # ----------------------------------------------------------------- API

    def submit(self, inputs: dict, max_new_tokens: int, *,
               slo: SLOClass | None = None, eos_id: int | None = None) -> Future:
        """Admit one generation request. Returns a Future resolving to
        ``{"tokens": (1, n) int32, "step_s": per-token seconds, "pages":
        peak pages held, "queued_s": lane wait}``."""
        slo = slo or BEST_EFFORT
        b = jax.tree.leaves(inputs)[0].shape[0]
        if b != 1:
            # one request = one sequence = one slot; a multi-row prompt
            # would silently serve only row 0 (split it client-side)
            raise ValueError(f"ContinuousBatcher serves one sequence per request, got batch {b}")
        fut: Future = Future()
        req = _Request(inputs, max_new_tokens, eos_id, slo, fut, self.clock.now())
        if self._tracer is not None:
            req.span = self._tracer.begin_request(
                self.engine.entry, "serve", t0=req.t_submit,
                attrs={"slo": slo.name, "max_new_tokens": req.max_new_tokens})
        with self._cv:
            if self._stopped:
                raise RuntimeError("batcher is shut down")
            be_depth = self._lanes.best_effort_depth()
            if (
                self.max_queue is not None
                and slo.best_effort
                and be_depth >= self.max_queue
            ):
                # shed on the BEST-EFFORT backlog only (queued strict
                # traffic must not push background work out — same depth
                # semantics as the scheduler's be_shed_depth)
                self.shed += 1
                fut.set_exception(ShedError(
                    f"best-effort shed: {be_depth} queued >= {self.max_queue}"
                ))
                self._fail_span(req, "ShedError")
                return fut
            self._lanes.push(req, slo)
            self._cv.notify_all()
        return fut

    def stats(self) -> dict:
        with self._cv:
            active = sum(1 for s in self._slots if s is not None)
            return {
                "capacity": self.capacity,
                "active": active,
                "queued": self._lanes.counts(),
                "steps": self.steps,
                "tokens": self.tokens_out,
                "completed": self.completed,
                "shed": self.shed,
                "prefill_chunks": self.prefill_chunks,
                "prefilling": self._job is not None,
                "mean_occupancy": (self._occupancy_sum / self.steps / self.capacity)
                if self.steps else 0.0,
                "arena": self.engine.arena.stats(),
            }

    def reset_stats(self) -> None:
        """Zero the step/occupancy/completion counters (benchmark warmup
        isolation — same discipline as scheduler.reset_stats)."""
        with self._cv:
            self.steps = 0
            self.tokens_out = 0
            self.completed = 0
            self.shed = 0
            self.prefill_chunks = 0
            self._occupancy_sum = 0

    def shutdown(self, timeout: float = 30.0) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        self._thread.join(timeout)

    # ------------------------------------------------------------ internals

    @staticmethod
    def _fail_span(req: _Request, error: str) -> None:
        """Close a request's trace root on an error/shed path — the span tree
        stays latency-conserving (an unfinished root would drop the whole
        trace from attribution)."""
        if req.span is not None:
            req.span.finish(args={"error": error})

    def _admit(self) -> None:
        """Fill free slots from the lanes, strictest class first. Runs on
        the loop thread. The chunked path (default for token prompts)
        starts ONE prefill job and returns — the loop interleaves its
        chunks with decode steps via :meth:`_prefill_tick`, and the next
        admission waits for the job to seat. ``serialize_prefill`` (or a
        non-token prompt) takes the old full-prefill-at-admit path."""
        while True:
            if self._job is not None:
                return  # a chunked prefill is in flight: it owns admission
            free = [i for i, s in enumerate(self._slots) if s is None]
            if not free:
                return
            with self._cv:
                got = self._lanes.pop()
            if got is None:
                return
            req, slo = got
            arena = self.engine.arena
            t_in = jax.tree.leaves(req.inputs)[0].shape[1]
            # the LAST decode step writes position t_in + max_new - 2; the
            # whole lifetime must fit the table and the pool, or the request
            # is permanently unservable: fail fast — requeueing would starve
            # the lane forever, and admitting would blow up mid-flight and
            # take every co-resident stream down with it
            final_len = t_in + max(0, req.max_new_tokens - 1)
            need = arena.pages_for(final_len)
            if need > min(arena.num_pages - 1, self.engine.block_width):
                _deliver(req.future, exc=ArenaFull(
                    f"prompt {t_in} + {req.max_new_tokens} generated tokens needs "
                    f"{need} pages; pool holds {arena.num_pages - 1}, "
                    f"table {self.engine.block_width}"
                ))
                self._fail_span(req, "ArenaFull")
                continue
            self._seq += 1
            req.seq_id = ("cb", self._seq)
            # residency starts when the pages do: both admission paths
            # allocate BEFORE running any chain, and the lease bills that too
            req.t_alloc = self.clock.now()
            if not self.serialize_prefill and "tokens" in req.inputs:
                try:
                    req.job = self.engine.begin_prefill_paged(req.seq_id, req.inputs)
                except ArenaFull:
                    with self._cv:
                        self._lanes.requeue(req, slo)  # transient: residents
                    return                             # will free pages
                except BaseException as exc:  # noqa: BLE001 — deliver, don't kill the loop
                    _deliver(req.future, exc=exc)
                    self._fail_span(req, type(exc).__name__)
                    continue
                self._job = req
                return
            try:
                logits, t_in = self.engine.prefill_paged(req.seq_id, req.inputs)
            except ArenaFull:
                with self._cv:
                    self._lanes.requeue(req, slo)  # transient: residents will
                return                             # free pages; retry first
            except BaseException as exc:  # noqa: BLE001 — deliver, don't kill the loop
                _deliver(req.future, exc=exc)
                self._fail_span(req, type(exc).__name__)
                continue
            req.cur_len = t_in
            self._seat(req, logits)

    def _seat(self, req: _Request, logits) -> None:
        """Prefill finished (either path): emit the first token and take a
        free slot — one is guaranteed, because slots only fill through this
        method and admission checked before starting."""
        req.t_admit = self.clock.now()
        if req.span is not None:
            # exact tiling of [t_submit, t_admit]: lane wait, then prompt
            # processing (chunk spans nest under the stall, so stall
            # self-time = time the prompt WAITED between chunks)
            req.span.emit("queue-wait", "queue-wait", req.t_submit, req.t_alloc)
            req.span.emit("prefill-stall", "prefill-stall", req.t_alloc,
                          req.t_admit, span_id=req.psid)
        req.last_emit = req.t_admit  # first token emitted at admission
        req.remaining = req.max_new_tokens
        first = int(np.asarray(_greedy_token(jnp.asarray(logits)))[0, 0])
        req.tokens.append(first)
        req.remaining -= 1
        req.next_token = first
        if req.remaining <= 0 or first == req.eos_id:
            self._finish(req)
            return
        slot = next(i for i, s in enumerate(self._slots) if s is None)
        self._slots[slot] = req
        self._bt[slot] = self.engine.arena.block_row(req.seq_id, self.engine.block_width)

    def _chunk_budget(self, req: _Request) -> int:
        """Prompt tokens the in-flight prefill may process this tick.

        Derived from the strict residents' inter-token slack: the chunk
        must fit inside ``slack_fraction x min(target - time_since_last
        _emit)`` minus the decode step the residents still need, using the
        EWMA per-token prefill estimate. Floored at ``min_chunk`` so cold
        starts and exhausted slack still make progress (starving the
        prefill forever would just move the stall to the joiner)."""
        remaining = req.job.remaining
        if self.prefill_chunk is not None:
            return self.prefill_chunk
        strict = [r for r in self._slots if r is not None and not r.slo.best_effort]
        if not strict:
            return max(self.min_chunk, remaining)  # nobody to protect
        per_tok = self._est_prefill.value
        if per_tok is None or per_tok <= 0.0:
            return self.min_chunk  # cold start: seed the estimate cheaply
        now = self.clock.now()
        slack = min(max(0.0, r.slo.target_s - (now - r.last_emit)) for r in strict)
        step_s = self._est_step.value or 0.0
        budget_s = max(0.0, self.slack_fraction * slack - step_s)
        return max(self.min_chunk, int(budget_s / per_tok))

    def _prefill_tick(self) -> bool:
        """Advance the in-flight chunked prefill by one budgeted chunk;
        seat the request when its prompt completes. Returns True if a
        chunk ran (the loop uses it to keep spinning while idle-but-
        prefilling)."""
        req = self._job
        if req is None:
            return False
        budget = self._chunk_budget(req)
        pos0 = req.job.pos
        t0 = self.clock.now()
        try:
            logits = self.engine.prefill_chunk_paged(req.job, budget)
        except BaseException as exc:  # noqa: BLE001 — deliver, don't kill the loop
            self._job = None
            self.engine.arena.free(req.seq_id)
            _deliver(req.future, exc=exc)
            self._fail_span(req, type(exc).__name__)
            return True
        done = req.job.pos - pos0
        t1 = self.clock.now()
        if done > 0:  # a whole-prompt cache hit computes zero prompt tokens
            self._est_prefill.observe((t1 - t0) / done)
        if req.span is not None:
            if req.psid is None:
                # parent for every chunk: the prefill-stall span _seat emits
                # over [t_alloc, t_admit] once the prompt completes
                req.psid = req.span.alloc_id()
            req.span.emit("prefill-chunk", "prefill-chunk", t0, t1,
                          parent_id=req.psid, args={"tokens": done})
        self.prefill_chunks += 1
        if logits is None:
            return True  # more chunks to go
        self._job = None
        req.cur_len = req.job.t_in
        req.job = None
        self._seat(req, logits)
        return True

    def _release_slot(self, i: int) -> None:
        """Clear a slot back to masked: all-scratch row, zero length/token."""
        self._slots[i] = None
        self._bt[i] = KVArena.RESERVED_PAGE
        self._cur[i] = 0
        self._tok[i, 0] = 0

    def _finish(self, req: _Request) -> None:
        pages = self.engine.arena.peak_pages(req.seq_id)
        # sampled BEFORE free: each still-held page weighted by 1/refcount,
        # so a shared prefix is billed once across the fleet holding it
        amortized = self.engine.arena.amortized_pages(req.seq_id)
        self.engine.arena.free(req.seq_id)
        t_done = self.clock.now()
        self.engine.platform.meter.record_arena(ArenaLease(
            function=self.engine.entry,
            request_id=str(req.seq_id),
            pages=pages,
            page_bytes=self.engine.arena.page_bytes,
            t_alloc=req.t_alloc,
            t_free=t_done,
            amortized_pages=amortized,
        ))
        self.completed += 1
        self.tokens_out += len(req.tokens)
        if req.span is not None:
            req.span.emit("batch-compute", "batch-compute", req.t_admit, t_done,
                          args={"tokens": len(req.tokens)})
            req.span.finish(t_done, args={"tokens": len(req.tokens),
                                          "pages": pages})
        _deliver(req.future, result={
            "tokens": np.asarray(req.tokens, np.int32)[None, :],
            "step_s": list(req.step_s),
            "pages": pages,
            "amortized_pages": amortized,
            "queued_s": req.t_admit - req.t_submit,
        })

    def _step(self) -> None:
        """One decode step for the whole fixed-capacity batch."""
        width = self.engine.block_width
        active = []
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            try:
                added = self.engine.arena.extend(req.seq_id, req.cur_len + 1)
                # the write position may sit on a SHARED page (a prefix-
                # cache hit whose partial tail page another sequence also
                # holds): copy-on-write it before the step's scatter
                moved = self.engine.arena.make_private(req.seq_id, req.cur_len)
            except ArenaFull:
                # pool exhausted mid-flight: truncate THIS request (deliver
                # what it generated) instead of failing the whole batch
                self._release_slot(i)
                self._finish(req)
                continue
            if added or moved:  # this slot's page set changed
                self._bt[i] = self.engine.arena.block_row(req.seq_id, width)
                if req.span is not None:
                    # page-extend / copy-on-write land as instants on the
                    # request's own timeline (CoW = a shared prefix page
                    # privatized before this step's scatter)
                    req.span.event("page-cow" if moved else "page-extend",
                                   args={"added": bool(added),
                                         "cow": bool(moved),
                                         "len": req.cur_len})
            self._tok[i, 0] = req.next_token
            self._cur[i] = req.cur_len
            active.append(i)
        logits = self.engine.paged_decode_step(jnp.asarray(self._tok), self._cur, self._bt)
        nxt = np.asarray(_greedy_token(jnp.asarray(logits)))
        now = self.clock.now()
        self.steps += 1
        self._occupancy_sum += len(active)
        for i in active:
            req = self._slots[i]
            tok = int(nxt[i, 0])
            req.tokens.append(tok)
            # inter-token time = gap since this request's LAST emission, so
            # stalls between steps (a joining request's serialized prefill)
            # are charged honestly, not just the decode-step compute
            req.step_s.append(now - req.last_emit)
            req.last_emit = now
            req.cur_len += 1
            req.remaining -= 1
            req.next_token = tok
            if req.remaining <= 0 or tok == req.eos_id:
                self._release_slot(i)
                self._finish(req)

    def _loop(self) -> None:
        while True:
            self._admit()
            # one prefill chunk rides between decode steps: residents keep
            # emitting while a joiner's prompt streams in
            prefilled = self._prefill_tick()
            busy = any(s is not None for s in self._slots)
            if not busy:
                if prefilled:
                    continue  # mid-prefill with no residents: next chunk now
                with self._cv:
                    if self._stopped:
                        break
                    # parks for new submits AND paces admission retries when
                    # the arena is transiently full (externally held pages);
                    # through the injected clock so the batcher is drivable
                    # in simulated time like every other timed wait
                    self.clock.wait_on(self._cv, 0.05)
                    continue
            t0 = self.clock.now()
            try:
                self._step()
                self._est_step.observe(self.clock.now() - t0)
            except BaseException as exc:  # noqa: BLE001 — a raising step must
                # fail the in-flight requests, not silently kill the loop
                for i, req in enumerate(self._slots):
                    if req is not None:
                        self._release_slot(i)
                        self.engine.arena.free(req.seq_id)
                        _deliver(req.future, exc=exc)
                        self._fail_span(req, type(exc).__name__)
            with self._cv:
                if self._stopped and all(s is None for s in self._slots) \
                        and self._lanes.depth() == 0 and self._job is None:
                    break
        # drain: fail the in-flight prefill and whatever is still queued so
        # no client hangs
        if self._job is not None:
            req, self._job = self._job, None
            self.engine.arena.free(req.seq_id)
            _deliver(req.future, exc=RuntimeError("batcher shut down"))
            self._fail_span(req, "shutdown")
        with self._cv:
            while True:
                got = self._lanes.pop()
                if got is None:
                    break
                _deliver(got[0].future, exc=RuntimeError("batcher shut down"))
                self._fail_span(got[0], "shutdown")
