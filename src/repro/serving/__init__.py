from repro.serving.continuous import ContinuousBatcher, ShedError  # noqa: F401
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.kvpool import ArenaFull, KVArena  # noqa: F401
