"""Serving engine: deploys a model on the Provuse platform as a FaaS
function *chain* and serves batched prefill/decode through it.

Chain layout (blocks families — dense/moe/vlm/ssm):

    <arch>/embed  ->  <arch>/g0  ->  ...  ->  <arch>/g{G-1}  ->  <arch>/head

Each stage is an independently deployed function holding its own layer-slice
weights; every stage synchronously calls the next and returns the final
result back up the chain — while the head computes, every upstream instance
is blocked (the paper's double-billing chain). enc-dec archs deploy the
canonical two-function app (encoder -> decoder); hybrid deploys
embed -> core -> head.

The platform observes the synchronous edges during live traffic and fuses
the chain step by step into a single XLA program per request type — no code
here ever asks for fusion; it *happens to* the deployment (transparent,
platform-side). Per-token latency before/after is the paper's Fig. 5.

Stage functions are shape-polymorphic: a (B, T>1) input takes the prefill
path (and scatter-fills the preallocated max_len cache); (B, 1) takes the
decode path. One deployed function serves both request types, mirroring a
FaaS function with two routes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.function import FunctionSpec
from repro.core.platform import ProvusePlatform
from repro.models import encdec as ed
from repro.models import hybrid as hy
from repro.models import transformer as tfm
from repro.models.layers import apply_norm, embed_tokens, unembed
from repro.models.model import Model
from repro.models.params import init_params


def _slice_tree(tree, lo: int, hi: int):
    return jax.tree.map(lambda x: x[lo:hi], tree)


def _pick_groups(n_layers: int, requested: int) -> int:
    g = min(requested, n_layers)
    while g > 1 and n_layers % g:
        g -= 1
    return max(1, g)


class ServingEngine:
    def __init__(self, model: Model, platform: ProvusePlatform, *, max_len: int = 256, params=None, trust_domain: str | None = None):
        self.model = model
        self.cfg = model.cfg
        self.platform = platform
        self.max_len = max_len
        self.params = params if params is not None else model.init(jax.random.PRNGKey(0))
        self.prefix = self.cfg.name
        self.trust = trust_domain or self.cfg.name
        self.entry = f"{self.prefix}/embed"
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm", "ssm"):
            self._deploy_blocks_chain()
        elif fam == "audio":
            self._deploy_encdec_chain()
        elif fam == "hybrid":
            self._deploy_monolithic_chain()
        else:
            raise ValueError(fam)

    # ------------------------------------------------------------ chains

    def _deploy_blocks_chain(self) -> None:
        cfg = self.cfg
        L = cfg.num_layers
        g = _pick_groups(L, cfg.num_function_groups)
        per = L // g
        kind = "moe" if cfg.family == "moe" else ("ssm" if cfg.family == "ssm" else "dense")
        names = [f"{self.prefix}/g{i}" for i in range(g)]
        head_name = f"{self.prefix}/head"

        def embed_fn(ctx, params, inputs, cur_len, caches):
            if "tokens" in inputs:
                x = embed_tokens(params, inputs["tokens"])
            else:
                x = inputs["embeds"]
            return ctx.call(names[0], x, cur_len, caches)

        self.platform.deploy(
            FunctionSpec(self.entry, embed_fn, {"table": self.params["embed"]["table"]}, self.trust)
        )

        def make_group_fn(i: int):
            key = f"g{i}"
            nxt = names[i + 1] if i + 1 < g else head_name

            def group_fn(ctx, params, x, cur_len, caches):
                old = caches[key]
                if x.shape[1] == 1:  # decode
                    h, new_cache, _ = tfm.apply_stack_decode(params, x, old, cfg, kind, None, cur_len)
                else:  # prefill: build the cache and scatter into max_len slots
                    positions = jnp.arange(x.shape[1])[None, :]
                    h, built, _ = tfm.apply_stack_full(params, x, cfg, kind, None, positions, collect_cache=True)
                    if kind == "ssm":
                        new_cache = built
                    else:
                        new_cache = jax.tree.map(
                            lambda full, part: jax.lax.dynamic_update_slice(
                                full, part.astype(full.dtype), (0, 0, 0, 0, 0)
                            ),
                            old,
                            built,
                        )
                caches = dict(caches)
                caches[key] = new_cache
                return ctx.call(nxt, h, cur_len, caches)

            return group_fn

        blocks = self.params["blocks"]
        for i, name in enumerate(names):
            self.platform.deploy(
                FunctionSpec(name, make_group_fn(i), _slice_tree(blocks, i * per, (i + 1) * per), self.trust)
            )

        def head_fn(ctx, params, x, cur_len, caches):
            h = apply_norm(params["ln_f"], x[:, -1:], cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, caches

        self.platform.deploy(
            FunctionSpec(head_name, head_fn, {"ln_f": self.params["ln_f"], "embed": self.params["embed"]}, self.trust)
        )
        self.group_names = names
        self.kind = kind

    def _deploy_encdec_chain(self) -> None:
        cfg = self.cfg
        dec_name = f"{self.prefix}/decoder"

        def enc_fn(ctx, params, inputs, cur_len, caches):
            enc, _ = ed.encode(params, inputs["src_embeds"], cfg, None)
            return ctx.call(dec_name, enc, inputs["tokens"], cur_len, caches)

        def dec_fn(ctx, params, *args):
            if len(args) == 4:  # prefill: (enc, tokens, cur_len, caches)
                enc, tokens, cur_len, caches = args
                cross = ed.cross_kv_from_enc(params["encdec"], enc)
                x = embed_tokens(params["embed"], tokens)
                src_len = jnp.full((x.shape[0],), enc.shape[1], jnp.int32)
                h, new_self, _ = ed.decoder_step(
                    params["encdec"], x, caches["self"], cross, cfg, None, cur_len, src_len
                )
                caches = {"self": new_self, "cross": cross}
            else:  # decode: (tokens, cur_len, caches)
                tokens, cur_len, caches = args
                x = embed_tokens(params["embed"], tokens)
                src = caches["cross"]["k"].shape[2]
                src_len = jnp.full((x.shape[0],), src, jnp.int32)
                h, new_self, _ = ed.decoder_step(
                    params["encdec"], x, caches["self"], caches["cross"], cfg, None, cur_len, src_len
                )
                caches = {"self": new_self, "cross": caches["cross"]}
            h = apply_norm(params["ln_f"], h, cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, caches

        enc_params = {"encoder": self.params["encdec"]["encoder"]}
        dec_params = {
            "encdec": {"decoder": self.params["encdec"]["decoder"]},
            "embed": self.params["embed"],
            "ln_f": self.params["ln_f"],
        }
        # encode() expects params["encoder"]; decoder fns expect the nested form
        self.platform.deploy(FunctionSpec(self.entry, enc_fn, enc_params, self.trust))
        self.platform.deploy(FunctionSpec(dec_name, dec_fn, dec_params, self.trust))
        self.dec_name = dec_name

    def _deploy_monolithic_chain(self) -> None:
        cfg = self.cfg
        core_name = f"{self.prefix}/core"
        head_name = f"{self.prefix}/head"

        def embed_fn(ctx, params, inputs, cur_len, caches):
            x = embed_tokens(params, inputs["tokens"])
            return ctx.call(core_name, x, cur_len, caches)

        def core_fn(ctx, params, x, cur_len, caches):
            if x.shape[1] == 1:
                h, new_caches, _ = hy.apply_hybrid_decode(params, x, caches, cfg, None, cur_len)
            else:
                positions = jnp.arange(x.shape[1])[None, :]
                h, built, _ = hy.apply_hybrid_full(params, x, cfg, None, positions, collect_cache=True)
                new_caches = dict(caches)
                new_caches["groups"] = built["groups"]
                if "tail" in built:
                    new_caches["tail"] = built["tail"]
                new_caches["attn"] = jax.tree.map(
                    lambda full, part: jax.lax.dynamic_update_slice(
                        full, part.astype(full.dtype), (0, 0, 0, 0, 0)
                    ),
                    caches["attn"],
                    built["attn"],
                )
            return ctx.call(head_name, h, cur_len, new_caches)

        def head_fn(ctx, params, x, cur_len, caches):
            h = apply_norm(params["ln_f"], x[:, -1:], cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, caches

        self.platform.deploy(FunctionSpec(self.entry, embed_fn, {"table": self.params["embed"]["table"]}, self.trust))
        self.platform.deploy(FunctionSpec(core_name, core_fn, self.params["hybrid"], self.trust))
        self.platform.deploy(
            FunctionSpec(head_name, head_fn, {"ln_f": self.params["ln_f"], "embed": self.params["embed"]}, self.trust)
        )

    # ------------------------------------------------------------ caches

    def empty_caches(self, batch: int):
        from repro.configs.base import ShapeConfig

        shape = ShapeConfig("serve", self.max_len, batch, "decode")
        cache = init_params(self.model.cache_defs(shape), jax.random.PRNGKey(0))
        if self.cfg.family in ("dense", "moe", "vlm", "ssm"):
            # re-key the model-level (L, ...) cache by chain stage
            g = len(self.group_names)
            per = self.cfg.num_layers // g
            return {
                f"g{i}": _slice_tree(cache, i * per, (i + 1) * per) for i in range(g)
            }
        return cache

    # ------------------------------------------------------------ serving API

    def prefill(self, inputs: dict, caches=None):
        b = jax.tree.leaves(inputs)[0].shape[0]
        if caches is None:
            caches = self.empty_caches(b)
        if self.cfg.family == "audio":
            t = jnp.zeros((b,), jnp.int32)
            logits, caches = self.platform.invoke(self.entry, inputs, t, {"self": caches["self"]})
            cur_len = jnp.ones((b,), jnp.int32)
        else:
            t_in = inputs["tokens"].shape[1] if "tokens" in inputs else inputs["embeds"].shape[1]
            cur_len = jnp.full((b,), t_in, jnp.int32)
            logits, caches = self.platform.invoke(self.entry, inputs, cur_len, caches)
        return logits, caches, cur_len

    def decode_step(self, tokens, cur_len, caches):
        if self.cfg.family == "audio":
            return self.platform.invoke(self.dec_name, tokens, cur_len, caches)
        inputs = {"tokens": tokens}
        return self.platform.invoke(self.entry, inputs, cur_len, caches)

    def decode_step_async(self, tokens, cur_len, caches):
        """Scheduled decode step: returns a Future of (logits, caches).
        Concurrent clients decoding with the same shapes coalesce into one
        micro-batched execution on the (possibly fused) chain."""
        if self.cfg.family == "audio":
            return self.platform.invoke_async(self.dec_name, tokens, cur_len, caches)
        return self.platform.invoke_async(self.entry, {"tokens": tokens}, cur_len, caches)

    def generate(self, inputs: dict, steps: int):
        """Greedy generation; returns (tokens (B, steps), per-token seconds)."""
        import time

        logits, caches, cur_len = self.prefill(inputs)
        tokens = jnp.argmax(jnp.asarray(logits), axis=-1)[:, None].astype(jnp.int32)
        out = [tokens]
        lat = []
        for _ in range(steps - 1):
            t0 = time.perf_counter()
            logits, caches = self.decode_step(tokens, cur_len, caches)
            lat.append(time.perf_counter() - t0)
            cur_len = cur_len + 1
            tokens = jnp.argmax(jnp.asarray(logits), axis=-1)[:, None].astype(jnp.int32)
            out.append(tokens)
        return jnp.concatenate(out, axis=1), lat
