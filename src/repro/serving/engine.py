"""Serving engine: deploys a model on the Provuse platform as a FaaS
function *chain* and serves batched prefill/decode through it.

Chain layout (blocks families — dense/moe/vlm/ssm):

    <arch>/embed  ->  <arch>/g0  ->  ...  ->  <arch>/g{G-1}  ->  <arch>/head

Each stage is an independently deployed function holding its own layer-slice
weights; every stage synchronously calls the next and returns the final
result back up the chain — while the head computes, every upstream instance
is blocked (the paper's double-billing chain). enc-dec archs deploy the
canonical two-function app (encoder -> decoder); hybrid deploys
embed -> core -> head.

The platform observes the synchronous edges during live traffic and fuses
the chain step by step into a single XLA program per request type — no code
here ever asks for fusion; it *happens to* the deployment (transparent,
platform-side). Per-token latency before/after is the paper's Fig. 5.

Stage functions are shape-polymorphic: a (B, T>1) input takes the prefill
path (and scatter-fills the preallocated max_len cache); (B, 1) takes the
decode path. One deployed function serves both request types, mirroring a
FaaS function with two routes.

Paged serving: with ``enable_paging`` the decode route can also serve from
a shared :class:`~repro.serving.kvpool.KVArena` — ``caches`` then carries a
block table plus each stage's page-pool slice instead of per-client dense
pytrees, and the SAME deployed (possibly fused) chain reads/writes arena
pages. Fused and unfused chains serve from one arena, so fusion benchmarks
measure the paper's effect at realistic occupancy (see
``serving/continuous.py`` for the decode loop that keeps it busy).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dispatch import TRACER
from repro.configs.base import ModelConfig
from repro.core.function import FunctionSpec
from repro.core.platform import ProvusePlatform
from repro.models import encdec as ed
from repro.models import hybrid as hy
from repro.models import transformer as tfm
from repro.models.layers import apply_norm, embed_tokens, unembed
from repro.models.model import Model
from repro.models.params import init_params
from repro.serving.kvpool import KVArena

#: Greedy sampling as ONE compiled device step: the previous inline
#: ``jnp.argmax(jnp.asarray(logits))`` dispatched eagerly and forced a host
#: sync per token, so the timed per-token loop measured transfer stalls,
#: not device time.
_greedy_token = jax.jit(
    lambda logits: jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
)


class PagedPrefillJob:
    """Host-side cursor for one chunked paged prefill: ``pos`` tracks how
    many prompt tokens already have resident KV (cached prefix pages count
    immediately), ``t_in`` is the full prompt length."""

    __slots__ = ("seq_id", "tokens", "pos")

    def __init__(self, seq_id, tokens: np.ndarray, pos: int):
        self.seq_id = seq_id
        self.tokens = tokens  # (t_in,) int32
        self.pos = pos

    @property
    def t_in(self) -> int:
        return len(self.tokens)

    @property
    def remaining(self) -> int:
        return self.t_in - self.pos


def _slice_tree(tree, lo: int, hi: int):
    return jax.tree.map(lambda x: x[lo:hi], tree)


def _pick_groups(n_layers: int, requested: int) -> int:
    g = min(requested, n_layers)
    while g > 1 and n_layers % g:
        g -= 1
    return max(1, g)


class ServingEngine:
    def __init__(self, model: Model, platform: ProvusePlatform, *, max_len: int = 256,
                 params=None, trust_domain: str | None = None,
                 kv_pages: int = 0, kv_page_size: int = 16):
        self.model = model
        self.cfg = model.cfg
        self.platform = platform
        self.max_len = max_len
        self.params = params if params is not None else model.init(jax.random.PRNGKey(0))
        self.prefix = self.cfg.name
        self.trust = trust_domain or self.cfg.name
        self.entry = f"{self.prefix}/embed"
        self.arena: KVArena | None = None
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm", "ssm"):
            self._deploy_blocks_chain()
        elif fam == "audio":
            self._deploy_encdec_chain()
        elif fam == "hybrid":
            self._deploy_monolithic_chain()
        else:
            raise ValueError(fam)
        if kv_pages:
            self.enable_paging(kv_pages, kv_page_size)

    # ------------------------------------------------------------ chains

    def _deploy_blocks_chain(self) -> None:
        cfg = self.cfg
        L = cfg.num_layers
        g = _pick_groups(L, cfg.num_function_groups)
        per = L // g
        kind = "moe" if cfg.family == "moe" else ("ssm" if cfg.family == "ssm" else "dense")
        names = [f"{self.prefix}/g{i}" for i in range(g)]
        head_name = f"{self.prefix}/head"

        def embed_fn(ctx, params, inputs, cur_len, caches):
            if "tokens" in inputs:
                x = embed_tokens(params, inputs["tokens"])
            else:
                x = inputs["embeds"]
            return ctx.call(names[0], x, cur_len, caches)

        self.platform.deploy(
            FunctionSpec(self.entry, embed_fn, {"table": self.params["embed"]["table"]}, self.trust)
        )

        def make_group_fn(i: int):
            key = f"g{i}"
            nxt = names[i + 1] if i + 1 < g else head_name

            def group_fn(ctx, params, x, cur_len, caches):
                if "block_table" in caches:  # paged: caches hold the arena
                    if "chunk_valid" in caches:  # chunked-prefill rows
                        h, new_arena, _ = tfm.apply_stack_prefill_chunk_paged(
                            params, x, caches[key], caches["block_table"], cfg,
                            kind, None, cur_len, caches["chunk_valid"],
                        )
                    else:  # single-token decode ("__frozen__" = no KV write)
                        h, new_arena, _ = tfm.apply_stack_decode_paged(
                            params, x, caches[key], caches["block_table"], cfg,
                            kind, None, cur_len, "__frozen__" not in caches,
                        )
                    caches = dict(caches)
                    caches[key] = new_arena
                    return ctx.call(nxt, h, cur_len, caches)
                old = caches[key]
                if x.shape[1] == 1:  # decode
                    h, new_cache, _ = tfm.apply_stack_decode(params, x, old, cfg, kind, None, cur_len)
                else:  # prefill: build the cache and scatter into max_len slots
                    positions = jnp.arange(x.shape[1])[None, :]
                    h, built, _ = tfm.apply_stack_full(params, x, cfg, kind, None, positions, collect_cache=True)
                    if kind == "ssm":
                        new_cache = built
                    else:
                        new_cache = jax.tree.map(
                            lambda full, part: jax.lax.dynamic_update_slice(
                                full, part.astype(full.dtype), (0, 0, 0, 0, 0)
                            ),
                            old,
                            built,
                        )
                caches = dict(caches)
                caches[key] = new_cache
                return ctx.call(nxt, h, cur_len, caches)

            return group_fn

        blocks = self.params["blocks"]
        for i, name in enumerate(names):
            self.platform.deploy(
                FunctionSpec(name, make_group_fn(i), _slice_tree(blocks, i * per, (i + 1) * per), self.trust)
            )

        def head_fn(ctx, params, x, cur_len, caches):
            if isinstance(caches, dict) and "chunk_valid" in caches:
                # chunked prefill pads the chunk to a power of two: the last
                # REAL row's hidden state is at chunk_valid - 1, not -1
                h = jax.lax.dynamic_slice_in_dim(x, caches["chunk_valid"][0] - 1, 1, axis=1)
            else:
                h = x[:, -1:]
            h = apply_norm(params["ln_f"], h, cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, caches

        self.platform.deploy(
            FunctionSpec(head_name, head_fn, {"ln_f": self.params["ln_f"], "embed": self.params["embed"]}, self.trust)
        )
        self.group_names = names
        self.kind = kind

    def _deploy_encdec_chain(self) -> None:
        cfg = self.cfg
        dec_name = f"{self.prefix}/decoder"

        def enc_fn(ctx, params, inputs, cur_len, caches):
            enc, _ = ed.encode(params, inputs["src_embeds"], cfg, None)
            return ctx.call(dec_name, enc, inputs["tokens"], cur_len, caches)

        def dec_fn(ctx, params, *args):
            if len(args) == 4:  # prefill: (enc, tokens, cur_len, caches)
                enc, tokens, cur_len, caches = args
                cross = ed.cross_kv_from_enc(params["encdec"], enc)
                x = embed_tokens(params["embed"], tokens)
                src_len = jnp.full((x.shape[0],), enc.shape[1], jnp.int32)
                h, new_self, _ = ed.decoder_step(
                    params["encdec"], x, caches["self"], cross, cfg, None, cur_len, src_len
                )
                caches = {"self": new_self, "cross": cross}
            else:  # decode: (tokens, cur_len, caches)
                tokens, cur_len, caches = args
                x = embed_tokens(params["embed"], tokens)
                src = caches["cross"]["k"].shape[2]
                src_len = jnp.full((x.shape[0],), src, jnp.int32)
                h, new_self, _ = ed.decoder_step(
                    params["encdec"], x, caches["self"], caches["cross"], cfg, None, cur_len, src_len
                )
                caches = {"self": new_self, "cross": caches["cross"]}
            h = apply_norm(params["ln_f"], h, cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, caches

        enc_params = {"encoder": self.params["encdec"]["encoder"]}
        dec_params = {
            "encdec": {"decoder": self.params["encdec"]["decoder"]},
            "embed": self.params["embed"],
            "ln_f": self.params["ln_f"],
        }
        # encode() expects params["encoder"]; decoder fns expect the nested form
        self.platform.deploy(FunctionSpec(self.entry, enc_fn, enc_params, self.trust))
        self.platform.deploy(FunctionSpec(dec_name, dec_fn, dec_params, self.trust))
        self.dec_name = dec_name

    def _deploy_monolithic_chain(self) -> None:
        cfg = self.cfg
        core_name = f"{self.prefix}/core"
        head_name = f"{self.prefix}/head"

        def embed_fn(ctx, params, inputs, cur_len, caches):
            x = embed_tokens(params, inputs["tokens"])
            return ctx.call(core_name, x, cur_len, caches)

        def core_fn(ctx, params, x, cur_len, caches):
            if x.shape[1] == 1:
                h, new_caches, _ = hy.apply_hybrid_decode(params, x, caches, cfg, None, cur_len)
            else:
                positions = jnp.arange(x.shape[1])[None, :]
                h, built, _ = hy.apply_hybrid_full(params, x, cfg, None, positions, collect_cache=True)
                new_caches = dict(caches)
                new_caches["groups"] = built["groups"]
                if "tail" in built:
                    new_caches["tail"] = built["tail"]
                new_caches["attn"] = jax.tree.map(
                    lambda full, part: jax.lax.dynamic_update_slice(
                        full, part.astype(full.dtype), (0, 0, 0, 0, 0)
                    ),
                    caches["attn"],
                    built["attn"],
                )
            return ctx.call(head_name, h, cur_len, new_caches)

        def head_fn(ctx, params, x, cur_len, caches):
            h = apply_norm(params["ln_f"], x[:, -1:], cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, caches

        self.platform.deploy(FunctionSpec(self.entry, embed_fn, {"table": self.params["embed"]["table"]}, self.trust))
        self.platform.deploy(FunctionSpec(core_name, core_fn, self.params["hybrid"], self.trust))
        self.platform.deploy(
            FunctionSpec(head_name, head_fn, {"ln_f": self.params["ln_f"], "embed": self.params["embed"]}, self.trust)
        )

    # ------------------------------------------------------- provisioning

    def chain_names(self) -> list[str]:
        """Every function name this engine deployed, in chain order."""
        fam = self.cfg.family
        if fam == "audio":
            return [self.entry, self.dec_name]
        if fam == "hybrid":
            return [self.entry, f"{self.prefix}/core", f"{self.prefix}/head"]
        return [self.entry, *self.group_names, f"{self.prefix}/head"]

    def scale_to_zero(self) -> tuple[str, ...]:
        """Park the whole serving chain as snapshots (platform must have
        snapshots enabled). Idle models stop paying for resident params; the
        next prefill/decode resurrects the chain from its snapshots. Returns
        the parked function names."""
        parked: list[str] = []
        for name in self.chain_names():
            if name in parked:
                continue  # co-parked as a member of an earlier fused group
            if self.platform.registry.get(name) is None:
                continue  # already parked (or never routed)
            parked.extend(self.platform.scale_to_zero(name))
        return tuple(parked)

    # ------------------------------------------------------------ caches

    def empty_caches(self, batch: int):
        from repro.configs.base import ShapeConfig

        shape = ShapeConfig("serve", self.max_len, batch, "decode")
        cache = init_params(self.model.cache_defs(shape), jax.random.PRNGKey(0))
        if self.cfg.family in ("dense", "moe", "vlm", "ssm"):
            # re-key the model-level (L, ...) cache by chain stage
            g = len(self.group_names)
            per = self.cfg.num_layers // g
            return {
                f"g{i}": _slice_tree(cache, i * per, (i + 1) * per) for i in range(g)
            }
        return cache

    # ------------------------------------------------------------ paging

    @property
    def paging_supported(self) -> bool:
        """Paged KV applies to length-indexed attention caches; SSM state is
        recurrent and enc-dec/hybrid keep their dedicated layouts."""
        return self.cfg.family in ("dense", "moe", "vlm")

    def enable_paging(self, num_pages: int, page_size: int = 16) -> KVArena:
        """Preallocate the shared KV arena: one (layers, pages, page, KV, hd)
        pool per chain stage, one allocator/block table across stages."""
        if not self.paging_supported:
            raise ValueError(f"paged KV unsupported for family {self.cfg.family!r}")
        if self.max_len % page_size:
            raise ValueError(f"max_len={self.max_len} must be a multiple of page_size={page_size}")
        g = len(self.group_names)
        per = self.cfg.num_layers // g
        self.arena = KVArena(
            {f"g{i}": per for i in range(g)},
            num_pages=num_pages,
            page_size=page_size,
            kv_heads=self.cfg.num_kv_heads,
            head_dim=self.cfg.head_dim,
            dtype=jnp.dtype(self.cfg.kv_cache_dtype),
        )
        self.block_width = self.arena.max_pages_per_seq(self.max_len)
        return self.arena

    def prefill_paged(self, seq_id, inputs: dict):
        """Admit one request into the arena: dense chain prefill (the
        prefill route is unchanged), then copy-on-prefill scatters the built
        cache into freshly allocated pages and the dense pytree is dropped.

        Token prompts go through the arena's shared-prefix cache: leading
        pages whose content hashes hit are held by reference and skipped by
        the scatter; a whole-prompt hit skips the dense prefill entirely —
        one frozen decode step at the last prompt position recovers the
        first-token logits from the cached pages (bit-exact: the masked
        padded positions contribute exact zeros, same as the dense path).
        Returns (last logits (1, V), prompt length)."""
        assert self.arena is not None, "enable_paging first"
        t_in = inputs["tokens"].shape[1] if "tokens" in inputs else inputs["embeds"].shape[1]
        if "tokens" in inputs:
            _, cached = self.arena.alloc_prefill(seq_id, np.asarray(inputs["tokens"])[0])
        else:
            self.arena.alloc(seq_id, t_in)  # no content hash for raw embeds
            cached = 0
        try:
            if cached >= t_in:
                logits = self._frozen_first_token(seq_id, inputs, t_in)
            else:
                logits, caches, _ = self.prefill(inputs)
                self.arena.write_prefill(seq_id, caches, t_in)
            self.arena.commit_prefill(seq_id)
        except BaseException:
            self.arena.free(seq_id)
            raise
        return logits, t_in

    def _frozen_first_token(self, seq_id, inputs: dict, t_in: int):
        """First-token logits for a whole-prompt prefix-cache hit: every
        page is already resident, so ONE frozen (no-KV-write) decode step at
        position t_in - 1 reads them back — nothing shared is touched."""
        row = self.arena.block_row(seq_id, self.block_width)
        last = np.asarray(inputs["tokens"])[:, -1:].astype(np.int32)
        return self.paged_decode_step(
            last, np.asarray([t_in - 1], np.int32), row[None, :], write_kv=False
        )

    def begin_prefill_paged(self, seq_id, inputs: dict) -> "PagedPrefillJob":
        """Allocate pages for a token prompt (through the shared-prefix
        cache) and return a chunked-prefill cursor — drive it with
        :meth:`prefill_chunk_paged` between decode steps. The cursor starts
        past any cached prefix."""
        assert self.arena is not None, "enable_paging first"
        tokens = np.asarray(inputs["tokens"])[0].astype(np.int32)
        _, cached = self.arena.alloc_prefill(seq_id, tokens)
        return PagedPrefillJob(seq_id=seq_id, tokens=tokens, pos=int(cached))

    def prefill_chunk_paged(self, job: "PagedPrefillJob", max_tokens: int):
        """Advance a chunked prefill by up to ``max_tokens`` prompt tokens:
        one chain invocation scatters the chunk's KV into the job's pages
        and attends causally from the chunk's start offset. Returns the
        first-token logits (1, V) once the prompt is fully processed, else
        None. The chunk buffer is padded to the next power of two (the real
        count rides in ``chunk_valid``) so the compile cache sees O(log
        max_len) chunk programs, not one per length."""
        assert self.arena is not None, "enable_paging first"
        t_in = job.t_in
        if job.pos >= t_in:  # whole-prompt hit: nothing to compute
            logits = self._frozen_first_token(
                job.seq_id, {"tokens": job.tokens[None, :]}, t_in
            )
            self.arena.commit_prefill(job.seq_id)
            return logits
        c = max(1, min(int(max_tokens), t_in - job.pos))
        padded = 1 << (c - 1).bit_length()
        buf = np.zeros((1, padded), np.int32)
        buf[0, :c] = job.tokens[job.pos : job.pos + c]
        row = self.arena.block_row(job.seq_id, self.block_width)
        caches = self.paged_caches(row[None, :])
        caches["chunk_valid"] = jnp.asarray([c], jnp.int32)
        self.platform.handler.note_demand(self.entry)
        logits, caches = self.platform._invoke_with_retry(
            self.entry,
            ({"tokens": jnp.asarray(buf)}, jnp.asarray([job.pos], jnp.int32), caches),
        )
        for name in self.arena.data:
            self.arena.swap_data(name, caches[name])
        job.pos += c
        if job.pos >= t_in:
            self.arena.commit_prefill(job.seq_id)
            return logits
        return None

    def paged_caches(self, block_table) -> dict:
        """Assemble the decode ``caches`` pytree for a batch served from the
        arena: the block table plus every stage's live page pool."""
        assert self.arena is not None, "enable_paging first"
        caches = {"block_table": jnp.asarray(block_table, jnp.int32)}
        for name, stage in self.arena.data.items():
            caches[name] = stage
        return caches

    def paged_decode_step(self, tokens, cur_len, block_table, *, write_kv: bool = True):
        """One decode step for a batch whose caches live in the arena.
        tokens: (B, 1); cur_len: (B,) — ragged per-request lengths;
        block_table: (B, width). The updated page pools are stored back so
        the arena always holds the latest state.

        ``write_kv=False`` runs the FROZEN variant (shared-prefix whole-hit
        admission): the step reads pages but writes nothing and no state is
        stored back. The marker rides in the caches pytree, so the frozen
        step compiles as its own program.

        Dispatches through the no-canary path: ``invoke`` would retain the
        step's args — the ENTIRE arena pytree — as the merge health-check
        canary, pinning a stale full copy of the pool between steps and
        doubling the very RAM paging exists to save. Merge health checks
        still have canaries from the (dense) prefill invocations; demand is
        noted so the fusion policy sees serve traffic as client load."""
        TRACER.note_decode_step()
        self.platform.handler.note_demand(self.entry)
        caches = self.paged_caches(block_table)
        if not write_kv:
            caches["__frozen__"] = ()
        logits, caches = self.platform._invoke_with_retry(
            self.entry,
            ({"tokens": tokens}, jnp.asarray(cur_len, jnp.int32), caches),
        )
        if write_kv:
            for name in self.arena.data:
                self.arena.swap_data(name, caches[name])
        return logits

    def _block_table_for(self, seq_ids) -> np.ndarray:
        rows = [self.arena.block_row(s, self.block_width) for s in seq_ids]
        return np.stack(rows)

    # ------------------------------------------------------------ serving API

    def prefill(self, inputs: dict, caches=None):
        b = jax.tree.leaves(inputs)[0].shape[0]
        if caches is None:
            caches = self.empty_caches(b)
        if self.cfg.family == "audio":
            t = jnp.zeros((b,), jnp.int32)
            logits, caches = self.platform.invoke(self.entry, inputs, t, {"self": caches["self"]})
            cur_len = jnp.ones((b,), jnp.int32)
        else:
            t_in = inputs["tokens"].shape[1] if "tokens" in inputs else inputs["embeds"].shape[1]
            cur_len = jnp.full((b,), t_in, jnp.int32)
            logits, caches = self.platform.invoke(self.entry, inputs, cur_len, caches)
        return logits, caches, cur_len

    def decode_step(self, tokens, cur_len, caches):
        TRACER.note_decode_step()
        if self.cfg.family == "audio":
            return self.platform.invoke(self.dec_name, tokens, cur_len, caches)
        inputs = {"tokens": tokens}
        return self.platform.invoke(self.entry, inputs, cur_len, caches)

    def decode_step_async(self, tokens, cur_len, caches):
        """Scheduled decode step: returns a Future of (logits, caches).
        Concurrent clients decoding with the same shapes coalesce into one
        micro-batched execution on the (possibly fused) chain."""
        if self.cfg.family == "audio":
            return self.platform.invoke_async(self.dec_name, tokens, cur_len, caches)
        return self.platform.invoke_async(self.entry, {"tokens": tokens}, cur_len, caches)

    def generate(self, inputs: dict, steps: int):
        """Greedy generation; returns (tokens (B, steps), per-token seconds)."""
        import time

        logits, caches, cur_len = self.prefill(inputs)
        tokens = _greedy_token(jnp.asarray(logits))
        out = [tokens]
        lat = []
        for _ in range(steps - 1):
            t0 = time.perf_counter()
            logits, caches = self.decode_step(tokens, cur_len, caches)
            lat.append(time.perf_counter() - t0)
            cur_len = cur_len + 1
            tokens = _greedy_token(jnp.asarray(logits))
            out.append(tokens)
        return jnp.concatenate(out, axis=1), lat

    def generate_paged(self, inputs: dict, steps: int):
        """Greedy generation served from the KV arena — same outputs as
        :meth:`generate`, bit for bit (the gathered page view is the same
        width as the dense cache and masked positions contribute exact
        zeros), but decode reads/writes shared pages instead of per-client
        dense cache pytrees. Pages are freed on exit."""
        import time

        assert self.arena is not None, "enable_paging first"
        b = jax.tree.leaves(inputs)[0].shape[0]
        seq_ids = [("gen", id(inputs), i) for i in range(b)]
        # dense prefill ONCE for the whole batch, then scatter each row's
        # built cache into its pages (copy-on-prefill)
        logits, caches, cur_len = self.prefill(inputs)
        t_in = int(np.asarray(cur_len)[0])
        try:
            for i, sid in enumerate(seq_ids):
                self.arena.alloc(sid, t_in)
                row = {k: jax.tree.map(lambda a: a[:, i : i + 1], v) for k, v in caches.items()}
                self.arena.write_prefill(sid, row, t_in)
            del caches
            tokens = _greedy_token(jnp.asarray(logits))
            out = [tokens]
            lat = []
            cur = np.full((b,), t_in, np.int64)
            for _ in range(steps - 1):
                t0 = time.perf_counter()
                for sid, c in zip(seq_ids, cur):
                    self.arena.extend(sid, int(c) + 1)  # page for the write position
                bt = self._block_table_for(seq_ids)
                logits = self.paged_decode_step(tokens, cur.astype(np.int32), bt)
                lat.append(time.perf_counter() - t0)
                cur += 1
                tokens = _greedy_token(jnp.asarray(logits))
                out.append(tokens)
            return jnp.concatenate(out, axis=1), lat
        finally:
            for sid in seq_ids:
                self.arena.free(sid)
