"""Paged KV-cache arena: cross-request cache sharing for batched decode.

The per-client serving path gives every request its own full ``max_len``
cache pytree — RAM proportional to ``clients x max_len`` regardless of how
many tokens each client actually holds, and every scheduled decode step
stacks/splits those pytrees through the batching boundary. The arena
replaces that with ONE preallocated page pool per chain stage:

* every stage owns ``k``/``v`` arrays of shape
  ``(stage_layers, num_pages, page_size, kv_heads, head_dim)``;
* a sequence holds ``ceil(cur_len / page_size)`` pages, tracked in a host-
  side block table (sequence -> physical page ids, in logical order);
* pages are allocated at prefill (copy-on-prefill scatters the dense
  prefill cache into pages), extended one page at a time as decode crosses
  a page boundary, and returned to the free list when the request leaves —
  reuse is defrag-free because every page is identical.

Page 0 is a reserved scratch page that is never allocated: the continuous
batcher points empty decode slots' block-table rows at it, so a masked
slot's (discarded) token write can never land in a live sequence's memory.

Shared-prefix page cache
------------------------

Requests sharing a prompt prefix share the prefix's *pages*. Pages are
refcounted, and a content-addressed index maps prompt prefixes to live
pages at page granularity: each full page-sized token chunk gets a chained
``blake2b`` digest (so a hit at chunk ``i`` certifies the whole prefix
``[0, (i+1) * page)``), plus a whole-prompt key covering a partial tail.
:meth:`alloc_prefill` serves index hits by reference (refcount + 1) and
allocates fresh pages only past the cached prefix; registration activates
at :meth:`commit_prefill`, once the prefill has actually written the data.
Freed pages KEEP their index entries while on the free list (free-but-
cached) and are resurrected on a later hit; allocation prefers un-indexed
pages and purges a page's entries when it is reused for new content.

Writers never touch a shared page: prefill writes start past the cached
prefix, and :meth:`make_private` copies a page on the first divergent
write (copy-on-write through the same functional ``.at[].set`` path), so
the indexed page always holds exactly the registered prefix.

RAM story (the paper's): platform RAM for serving is now proportional to
*unique pages held* — tokens actually resident, deduplicated across
requests — not to ``clients x max_len``;
:class:`~repro.core.billing.ArenaLease` bills each request for the pages
it held, amortized by refcount for shared ones.

The allocator is host-side (plain ints under ``_lock``); the page *data*
are device arrays updated functionally — decode programs gather pages
through the block table and scatter the new token's K/V back (see
``models/attention.py: paged_decode_attention`` and the Pallas kernel in
``kernels/paged_attention.py``). Every host-side read-modify-write swap of
``self.data`` (prefill scatter, CoW copy, step store-back) happens under
``_data_lock``, so two concurrent writers can never rebase on the same
stale array and silently drop each other's pages.
"""
from __future__ import annotations

import hashlib
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import guarded_by


class ArenaFull(RuntimeError):
    """No free pages left for an allocation (admission should back off)."""


class KVArena:
    """One page pool shared by every stage of a serving chain.

    ``stages`` maps stage name -> number of layers hosted by that stage;
    all stages share one allocator and one block table (a sequence occupies
    the same physical page ids in every stage's arrays, so one table row
    drives the whole chain's gather).
    """

    #: physical page 0 is scratch: masked/empty decode slots write here
    RESERVED_PAGE = 0

    # provlint: host-side bookkeeping is guarded by _lock; the device
    # arrays in `data` tolerate unlocked reads (GIL-atomic reference
    # loads) but every functional RMW swap must hold _data_lock.
    GUARDED_FIELDS = {
        "_free": "_lock",
        "_held": "_lock",
        "_lens": "_lock",
        "_peak_held": "_lock",
        "_refs": "_lock",
        "_index": "_lock",
        "_page_keys": "_lock",
        "_pending": "_lock",
        "_shared_upto": "_lock",
        "shared_hits": "_lock",
        "shared_pages_served": "_lock",
        "cow_copies": "_lock",
    }
    GUARDED_WRITES = {"data": "_data_lock"}

    def __init__(
        self,
        stages: dict[str, int],
        *,
        num_pages: int,
        page_size: int,
        kv_heads: int,
        head_dim: int,
        dtype=jnp.bfloat16,
    ):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if page_size < 1 or page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got {page_size}")
        self.stages = dict(stages)
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.kv_heads = int(kv_heads)
        self.head_dim = int(head_dim)
        self.dtype = jnp.dtype(dtype)
        self.data: dict[str, dict[str, jax.Array]] = {
            name: {
                "k": jnp.zeros((n_layers, num_pages, page_size, kv_heads, head_dim), self.dtype),
                "v": jnp.zeros((n_layers, num_pages, page_size, kv_heads, head_dim), self.dtype),
            }
            for name, n_layers in self.stages.items()
        }
        self._lock = threading.Lock()
        # guards every functional read-modify-write swap on self.data (the
        # allocator lock covers only host-side page bookkeeping)
        self._data_lock = threading.Lock()
        # LIFO free list: recently-freed (cache-warm) pages are reused first
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._held: dict[object, list[int]] = {}
        self._lens: dict[object, int] = {}
        self._peak_held: dict[object, int] = {}
        # --- shared-prefix state ---
        self._refs: dict[int, int] = {}               # page -> holder count
        self._index: dict[bytes, int] = {}            # content key -> page
        self._page_keys: dict[int, list[bytes]] = {}  # page -> its index keys
        self._pending: dict[object, list[tuple[bytes, int]]] = {}
        self._shared_upto: dict[object, int] = {}     # leading pages held by ref
        self.shared_hits = 0          # prefills that reused >= 1 page
        self.shared_pages_served = 0  # pages served by reference, cumulative
        self.cow_copies = 0           # copy-on-write page copies

    # ------------------------------------------------------------ geometry

    @property
    def page_bytes(self) -> int:
        """Bytes ONE page occupies across the whole chain (all stages, k+v)
        — the unit of the per-request RAM bill."""
        per_layer = 2 * self.page_size * self.kv_heads * self.head_dim * self.dtype.itemsize
        return per_layer * sum(self.stages.values())

    def pages_for(self, length: int) -> int:
        return max(1, -(-int(length) // self.page_size))

    def max_pages_per_seq(self, max_len: int) -> int:
        if max_len % self.page_size:
            raise ValueError(f"max_len={max_len} must be a multiple of page_size={self.page_size}")
        return max_len // self.page_size

    # ------------------------------------------------------------ hashing

    def _page_digests(self, tokens: np.ndarray) -> list[bytes]:
        """One chained digest per FULL page-sized token chunk: digest i
        certifies the entire prefix [0, (i+1)*page), so a single index hit
        is a whole-prefix match, not a per-chunk one."""
        out: list[bytes] = []
        h = b""
        ps = self.page_size
        for i in range(len(tokens) // ps):
            h = hashlib.blake2b(
                b"P" + h + tokens[i * ps : (i + 1) * ps].tobytes(), digest_size=16
            ).digest()
            out.append(h)
        return out

    def _prompt_key(self, digests: list[bytes], tokens: np.ndarray) -> bytes:
        """Whole-prompt key (chain + partial tail + length): lets an EXACT
        repeat prompt share its partial last page too."""
        tail = tokens[len(digests) * self.page_size :]
        base = digests[-1] if digests else b""
        return hashlib.blake2b(
            b"W" + base + tail.tobytes() + len(tokens).to_bytes(8, "little"),
            digest_size=16,
        ).digest()

    # ------------------------------------------------------------ allocator

    @guarded_by("_lock")
    def _purge_keys_locked(self, page: int) -> None:
        for key in self._page_keys.pop(page, ()):
            if self._index.get(key) == page:
                del self._index[key]

    @guarded_by("_lock")
    def _pop_free_page_locked(self) -> int:
        """Pop a free page, preferring pages with no retained index entries
        (reusing an indexed free page evicts its cached prefix)."""
        if not self._free:
            raise ArenaFull("no free pages")
        for j in range(len(self._free) - 1, -1, -1):
            if self._free[j] not in self._page_keys:
                return self._free.pop(j)
        p = self._free.pop()
        self._purge_keys_locked(p)
        return p

    def alloc(self, seq_id, length: int) -> list[int]:
        """Reserve private pages for a sequence of ``length`` tokens.
        Raises :class:`ArenaFull` (allocating nothing) when the pool can't
        cover it. Content-aware allocation (prefix sharing) goes through
        :meth:`alloc_prefill` instead."""
        need = self.pages_for(length)
        with self._lock:
            if seq_id in self._held:
                raise ValueError(f"sequence {seq_id!r} already holds pages")
            if need > len(self._free):
                raise ArenaFull(f"need {need} pages, {len(self._free)} free")
            pages = [self._pop_free_page_locked() for _ in range(need)]
            for p in pages:
                self._refs[p] = 1
            self._held[seq_id] = pages
            self._lens[seq_id] = int(length)
            self._peak_held[seq_id] = need
            return list(pages)

    def alloc_prefill(self, seq_id, tokens) -> tuple[list[int], int]:
        """Content-aware allocation for a token prompt: leading pages whose
        chained prefix digests hit the index are served BY REFERENCE
        (refcount + 1, resurrecting free-but-cached pages), fresh pages
        cover the rest. Returns ``(pages, cached_tokens)`` —
        ``cached_tokens`` is how many leading prompt tokens already have
        resident KV (the prefill may start there; ``cached == len(tokens)``
        is a whole-prompt hit, partial tail page included).

        Registration of THIS prompt's chunks is recorded pending and
        activates at :meth:`commit_prefill` once the KV is written."""
        tok = np.asarray(tokens).reshape(-1).astype(np.int64)
        t_in = len(tok)
        if t_in == 0:
            raise ValueError("empty prompt")
        need_total = self.pages_for(t_in)
        digests = self._page_digests(tok)
        exact = t_in % self.page_size == 0
        prompt_key = None if exact else self._prompt_key(digests, tok)
        with self._lock:
            if seq_id in self._held:
                raise ValueError(f"sequence {seq_id!r} already holds pages")
            shared: list[int] = []
            for d in digests:
                p = self._index.get(d)
                if p is None:
                    break
                shared.append(p)
            cached = min(len(shared) * self.page_size, t_in)
            if prompt_key is not None and len(shared) == len(digests):
                tail = self._index.get(prompt_key)
                if tail is not None and tail not in shared:
                    shared.append(tail)
                    cached = t_in
            fresh_need = need_total - len(shared)
            resurrect = sum(1 for p in shared if p not in self._refs)
            if fresh_need > len(self._free) - resurrect:
                raise ArenaFull(
                    f"need {fresh_need} fresh pages, "
                    f"{len(self._free) - resurrect} free after sharing"
                )
            for p in shared:
                if p in self._refs:
                    self._refs[p] += 1
                else:  # free-but-cached: pull it back off the free list
                    self._free.remove(p)
                    self._refs[p] = 1
            fresh = [self._pop_free_page_locked() for _ in range(fresh_need)]
            for p in fresh:
                self._refs[p] = 1
            pages = shared + fresh
            self._held[seq_id] = pages
            self._lens[seq_id] = t_in
            self._peak_held[seq_id] = need_total
            self._shared_upto[seq_id] = len(shared)
            if shared:
                self.shared_hits += 1
                self.shared_pages_served += len(shared)
            pend = [(d, i) for i, d in enumerate(digests) if d not in self._index]
            if prompt_key is not None and prompt_key not in self._index:
                pend.append((prompt_key, need_total - 1))
            if pend:
                self._pending[seq_id] = pend
            return list(pages), cached

    def commit_prefill(self, seq_id) -> None:
        """Activate the prefix-index registrations recorded at
        :meth:`alloc_prefill` — call once the prefill has WRITTEN the
        pages' KV (serving an unwritten page by reference would hand out
        zeros)."""
        with self._lock:
            pend = self._pending.pop(seq_id, ())
            pages = self._held.get(seq_id)
            if pages is None:
                return
            for key, idx in pend:
                if key in self._index:
                    continue  # a concurrent prefill registered it first
                p = pages[idx]
                self._index[key] = p
                self._page_keys.setdefault(p, []).append(key)

    def shared_pages(self, seq_id) -> int:
        """How many of a sequence's leading pages came from the prefix
        cache (held by reference, never written by this sequence)."""
        with self._lock:
            return self._shared_upto.get(seq_id, 0)

    def extend(self, seq_id, new_len: int) -> list[int]:
        """Grow a sequence to ``new_len`` tokens, appending pages as the
        length crosses page boundaries. Returns the pages added."""
        with self._lock:
            if seq_id not in self._held:
                raise KeyError(f"unknown sequence {seq_id!r}")
            if new_len < self._lens[seq_id]:
                raise ValueError("sequences never shrink; free and realloc instead")
            need = self.pages_for(new_len) - len(self._held[seq_id])
            if need > len(self._free):
                raise ArenaFull(f"need {need} more pages, {len(self._free)} free")
            added = [self._pop_free_page_locked() for _ in range(need)]
            for p in added:
                self._refs[p] = 1
            self._held[seq_id].extend(added)
            self._lens[seq_id] = int(new_len)
            self._peak_held[seq_id] = max(self._peak_held[seq_id], len(self._held[seq_id]))
            return added

    def free(self, seq_id) -> int:
        """Drop a sequence's page references; pages whose refcount hits
        zero return to the pool — KEEPING their prefix-index entries
        (free-but-cached) until the page is reused. Returns how many pages
        the sequence held."""
        with self._lock:
            pages = self._held.pop(seq_id, None)
            self._lens.pop(seq_id, None)
            self._peak_held.pop(seq_id, None)
            self._pending.pop(seq_id, None)
            self._shared_upto.pop(seq_id, None)
            if pages is None:
                return 0
            for p in reversed(pages):
                self._refs[p] -= 1
                if self._refs[p] == 0:
                    del self._refs[p]
                    self._free.append(p)
            return len(pages)

    def make_private(self, seq_id, pos: int) -> bool:
        """Copy-on-write: ensure the page holding token position ``pos`` is
        exclusively owned by ``seq_id`` before a write lands there. If the
        page is shared (refcount > 1), copy its data to a fresh page and
        swap it into this sequence's table. Returns True when the block row
        changed (callers must rebuild it). Raises :class:`ArenaFull` when
        no page is free for the copy."""
        with self._lock:
            pages = self._held.get(seq_id)
            if pages is None:
                raise KeyError(f"unknown sequence {seq_id!r}")
            idx = int(pos) // self.page_size
            if idx >= len(pages):
                raise ValueError(f"position {pos} past {seq_id!r}'s pages (extend first)")
            old = pages[idx]
            if self._refs.get(old, 0) <= 1:
                return False
            new = self._pop_free_page_locked()
            self._refs[new] = 1
            self._refs[old] -= 1
            pages[idx] = new
            if self._shared_upto.get(seq_id, 0) > idx:
                self._shared_upto[seq_id] = idx
            self.cow_copies += 1
        # the shared region of `old` is immutable while shared, so the copy
        # itself is safe outside the allocator lock; the swap serializes
        # with the other device-array writers
        with self._data_lock:
            for stage in self.data.values():
                for kv in ("k", "v"):
                    arr = stage[kv]
                    stage[kv] = arr.at[:, new].set(arr[:, old])
        return True

    # ------------------------------------------------------------ queries

    def pages_held(self, seq_id) -> int:
        with self._lock:
            return len(self._held.get(seq_id, ()))

    def peak_pages(self, seq_id) -> int:
        with self._lock:
            return self._peak_held.get(seq_id, 0)

    def amortized_pages(self, seq_id) -> float:
        """The sequence's page count with each page weighted by 1/refcount
        — a fleet sharing a prefix splits its bill (sampled at call time;
        the batcher samples on exit)."""
        with self._lock:
            pages = self._held.get(seq_id, ())
            return float(sum(1.0 / self._refs[p] for p in pages))

    def seq_len(self, seq_id) -> int:
        with self._lock:
            return self._lens.get(seq_id, 0)

    def block_row(self, seq_id, width: int) -> np.ndarray:
        """The sequence's block-table row, padded with the scratch page to
        ``width`` entries (int32)."""
        with self._lock:
            return self._block_row_locked(seq_id, width)

    @guarded_by("_lock")
    def _block_row_locked(self, seq_id, width: int) -> np.ndarray:
        pages = self._held.get(seq_id, [])
        if len(pages) > width:
            raise ValueError(f"{seq_id!r} holds {len(pages)} pages > table width {width}")
        row = np.full((width,), self.RESERVED_PAGE, np.int32)
        row[: len(pages)] = pages
        return row

    def used_pages(self) -> int:
        """Unique physical pages in use (shared pages count once)."""
        with self._lock:
            return len(self._refs)

    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def check_consistency(self) -> None:
        """Fuzz-test invariant, extended to refcounted sharing: every
        non-reserved page is free xor held; a held page's refcount equals
        the number of sequences holding it; every row covers its sequence's
        length; index entries point at real pages and back-links match."""
        with self._lock:
            holders: dict[int, int] = {}
            for sid, pages in self._held.items():
                if len(pages) != self.pages_for(self._lens[sid]):
                    raise AssertionError(
                        f"{sid!r}: {len(pages)} pages for len {self._lens[sid]}"
                    )
                if len(set(pages)) != len(pages):
                    raise AssertionError(f"{sid!r} holds a page twice: {pages}")
                for p in pages:
                    if not 0 < p < self.num_pages:
                        raise AssertionError(f"page {p} out of range (or reserved)")
                    holders[p] = holders.get(p, 0) + 1
            for p, n in holders.items():
                if self._refs.get(p) != n:
                    raise AssertionError(
                        f"page {p}: refcount {self._refs.get(p)} != {n} holders"
                    )
            for p in self._refs:
                if p not in holders:
                    raise AssertionError(f"page {p} refcounted but held by no one")
            seen_free: set[int] = set()
            for p in self._free:
                if p in holders:
                    raise AssertionError(f"page {p} both free and held")
                if p in seen_free:
                    raise AssertionError(f"page {p} on the free list twice")
                if not 0 < p < self.num_pages:
                    raise AssertionError(f"free page {p} out of range (or reserved)")
                seen_free.add(p)
            if len(seen_free) + len(holders) != self.num_pages - 1:
                missing = set(range(1, self.num_pages)) - seen_free - set(holders)
                raise AssertionError(f"leaked pages: {sorted(missing)}")
            for key, p in self._index.items():
                if p not in holders and p not in seen_free:
                    raise AssertionError(f"index key -> nonexistent page {p}")
                if key not in self._page_keys.get(p, ()):
                    raise AssertionError(f"index key for page {p} missing back-link")
            for p, keys in self._page_keys.items():
                for key in keys:
                    if self._index.get(key) != p:
                        raise AssertionError(f"stale page-key on page {p}")

    def stats(self) -> dict:
        with self._lock:
            held = {str(k): len(v) for k, v in self._held.items()}
            return {
                "num_pages": self.num_pages,
                "page_size": self.page_size,
                "page_bytes": self.page_bytes,
                "free": len(self._free),
                "used": len(self._refs),
                "held_nominal": sum(held.values()),
                "sequences": len(held),
                "held_by_seq": held,
                "shared_hits": self.shared_hits,
                "shared_pages_served": self.shared_pages_served,
                "cow_copies": self.cow_copies,
                "prefix_index": len(self._index),
            }

    # ------------------------------------------------------------ page data

    def write_prefill(self, seq_id, stage_caches: dict, length: int) -> None:
        """Copy-on-prefill: scatter a request's dense prefill caches into
        its allocated pages. ``stage_caches[stage]`` is the chain's dense
        cache for ONE request — ``{'k','v'}`` of shape ``(L, 1, S, kv, hd)``
        or ``(L, S, kv, hd)`` — with the first ``length`` positions valid.

        Pages obtained from the prefix cache are SKIPPED: they already hold
        the prefix KV, and they may be shared — rewriting one would clobber
        a co-holder's tail-page decode writes. The device-array swap runs
        under ``_data_lock`` so two concurrent prefills into the same stage
        can't rebase on the same stale array and drop each other's pages."""
        with self._lock:
            pages = list(self._held.get(seq_id, ()))
            skip = self._shared_upto.get(seq_id, 0)
        if not pages:
            raise KeyError(f"no pages allocated for {seq_id!r}")
        for stage in stage_caches:
            if stage not in self.data:
                raise KeyError(
                    f"unknown arena stage {stage!r} (have {sorted(self.data)})"
                )
        n = self.pages_for(length)
        if skip >= n:
            return  # whole prefix served from the cache: nothing to write
        ids = jnp.asarray(pages[skip:n], jnp.int32)
        lo = skip * self.page_size
        span = n * self.page_size
        with self._data_lock:
            for stage, cache in stage_caches.items():
                dst = self.data[stage]
                for kv in ("k", "v"):
                    src = cache[kv]
                    if src.ndim == 5:  # (L, 1, S, kv, hd) -> (L, S, kv, hd)
                        src = src[:, 0]
                    if src.shape[1] < span:
                        raise ValueError(
                            f"prefill cache covers {src.shape[1]} positions < {span} paged"
                        )
                    chunks = src[:, lo:span].reshape(
                        src.shape[0], n - skip, self.page_size, self.kv_heads, self.head_dim
                    )
                    dst[kv] = dst[kv].at[:, ids].set(chunks.astype(self.dtype))

    def swap_data(self, stage: str, new: dict) -> None:
        """Store back a stage's updated page arrays (a decode/chunk step's
        output) under the data lock, keeping the reference swap atomic with
        concurrent prefill scatters and CoW copies."""
        with self._data_lock:
            self.data[stage] = new

    def gather(self, seq_id, stage: str, width: int | None = None) -> dict:
        """Contiguous view of one sequence's cache for a stage — the test
        oracle (and the shape the gather-fallback decode reconstructs).
        Returns ``{'k','v'}`` of shape (L, width*page, kv, hd).

        The (pages, default width) snapshot is taken under ONE lock
        acquisition: deriving the width from ``seq_len`` and re-reading the
        page list separately would race a concurrent ``extend`` into a
        spurious ValueError for a perfectly healthy sequence."""
        with self._lock:
            pages = self._held.get(seq_id, [])
            if width is None:
                width = max(1, len(pages))
            row_np = self._block_row_locked(seq_id, width)
        row = jnp.asarray(row_np)
        out = {}
        for kv in ("k", "v"):
            pages_v = self.data[stage][kv][:, row]  # (L, width, page, kv, hd)
            l = pages_v.shape[0]
            out[kv] = pages_v.reshape(l, width * self.page_size, self.kv_heads, self.head_dim)
        return out
