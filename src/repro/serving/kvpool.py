"""Paged KV-cache arena: cross-request cache sharing for batched decode.

The per-client serving path gives every request its own full ``max_len``
cache pytree — RAM proportional to ``clients x max_len`` regardless of how
many tokens each client actually holds, and every scheduled decode step
stacks/splits those pytrees through the batching boundary. The arena
replaces that with ONE preallocated page pool per chain stage:

* every stage owns ``k``/``v`` arrays of shape
  ``(stage_layers, num_pages, page_size, kv_heads, head_dim)``;
* a sequence holds ``ceil(cur_len / page_size)`` pages, tracked in a host-
  side block table (sequence -> physical page ids, in logical order);
* pages are allocated at prefill (copy-on-prefill scatters the dense
  prefill cache into pages), extended one page at a time as decode crosses
  a page boundary, and returned to the free list when the request leaves —
  reuse is defrag-free because every page is identical.

Page 0 is a reserved scratch page that is never allocated: the continuous
batcher points empty decode slots' block-table rows at it, so a masked
slot's (discarded) token write can never land in a live sequence's memory.

RAM story (the paper's): platform RAM for serving is now proportional to
*pages held* — tokens actually resident — not to ``clients x max_len``;
:class:`~repro.core.billing.ArenaLease` bills each request for exactly the
pages it held, for exactly as long as it held them.

The allocator is host-side (plain ints under a lock); the page *data* are
device arrays updated functionally — decode programs gather pages through
the block table and scatter the new token's K/V back (see
``models/attention.py: paged_decode_attention`` and the Pallas kernel in
``kernels/paged_attention.py``).
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np


class ArenaFull(RuntimeError):
    """No free pages left for an allocation (admission should back off)."""


class KVArena:
    """One page pool shared by every stage of a serving chain.

    ``stages`` maps stage name -> number of layers hosted by that stage;
    all stages share one allocator and one block table (a sequence occupies
    the same physical page ids in every stage's arrays, so one table row
    drives the whole chain's gather).
    """

    #: physical page 0 is scratch: masked/empty decode slots write here
    RESERVED_PAGE = 0

    def __init__(
        self,
        stages: dict[str, int],
        *,
        num_pages: int,
        page_size: int,
        kv_heads: int,
        head_dim: int,
        dtype=jnp.bfloat16,
    ):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if page_size < 1 or page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got {page_size}")
        self.stages = dict(stages)
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.kv_heads = int(kv_heads)
        self.head_dim = int(head_dim)
        self.dtype = jnp.dtype(dtype)
        self.data: dict[str, dict[str, jax.Array]] = {
            name: {
                "k": jnp.zeros((n_layers, num_pages, page_size, kv_heads, head_dim), self.dtype),
                "v": jnp.zeros((n_layers, num_pages, page_size, kv_heads, head_dim), self.dtype),
            }
            for name, n_layers in self.stages.items()
        }
        self._lock = threading.Lock()
        # LIFO free list: recently-freed (cache-warm) pages are reused first
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._held: dict[object, list[int]] = {}
        self._lens: dict[object, int] = {}
        self._peak_held: dict[object, int] = {}

    # ------------------------------------------------------------ geometry

    @property
    def page_bytes(self) -> int:
        """Bytes ONE page occupies across the whole chain (all stages, k+v)
        — the unit of the per-request RAM bill."""
        per_layer = 2 * self.page_size * self.kv_heads * self.head_dim * self.dtype.itemsize
        return per_layer * sum(self.stages.values())

    def pages_for(self, length: int) -> int:
        return max(1, -(-int(length) // self.page_size))

    def max_pages_per_seq(self, max_len: int) -> int:
        if max_len % self.page_size:
            raise ValueError(f"max_len={max_len} must be a multiple of page_size={self.page_size}")
        return max_len // self.page_size

    # ------------------------------------------------------------ allocator

    def alloc(self, seq_id, length: int) -> list[int]:
        """Reserve pages for a sequence of ``length`` tokens. Raises
        :class:`ArenaFull` (allocating nothing) when the pool can't cover
        it."""
        need = self.pages_for(length)
        with self._lock:
            if seq_id in self._held:
                raise ValueError(f"sequence {seq_id!r} already holds pages")
            if need > len(self._free):
                raise ArenaFull(f"need {need} pages, {len(self._free)} free")
            pages = [self._free.pop() for _ in range(need)]
            self._held[seq_id] = pages
            self._lens[seq_id] = int(length)
            self._peak_held[seq_id] = need
            return list(pages)

    def extend(self, seq_id, new_len: int) -> list[int]:
        """Grow a sequence to ``new_len`` tokens, appending pages as the
        length crosses page boundaries. Returns the pages added."""
        with self._lock:
            if seq_id not in self._held:
                raise KeyError(f"unknown sequence {seq_id!r}")
            if new_len < self._lens[seq_id]:
                raise ValueError("sequences never shrink; free and realloc instead")
            need = self.pages_for(new_len) - len(self._held[seq_id])
            if need > len(self._free):
                raise ArenaFull(f"need {need} more pages, {len(self._free)} free")
            added = [self._free.pop() for _ in range(need)]
            self._held[seq_id].extend(added)
            self._lens[seq_id] = int(new_len)
            self._peak_held[seq_id] = max(self._peak_held[seq_id], len(self._held[seq_id]))
            return added

    def free(self, seq_id) -> int:
        """Return a sequence's pages to the pool; returns how many."""
        with self._lock:
            pages = self._held.pop(seq_id, None)
            self._lens.pop(seq_id, None)
            self._peak_held.pop(seq_id, None)
            if pages is None:
                return 0
            self._free.extend(reversed(pages))
            return len(pages)

    # ------------------------------------------------------------ queries

    def pages_held(self, seq_id) -> int:
        with self._lock:
            return len(self._held.get(seq_id, ()))

    def peak_pages(self, seq_id) -> int:
        with self._lock:
            return self._peak_held.get(seq_id, 0)

    def seq_len(self, seq_id) -> int:
        with self._lock:
            return self._lens.get(seq_id, 0)

    def block_row(self, seq_id, width: int) -> np.ndarray:
        """The sequence's block-table row, padded with the scratch page to
        ``width`` entries (int32)."""
        with self._lock:
            pages = self._held.get(seq_id, [])
            if len(pages) > width:
                raise ValueError(f"{seq_id!r} holds {len(pages)} pages > table width {width}")
            row = np.full((width,), self.RESERVED_PAGE, np.int32)
            row[: len(pages)] = pages
            return row

    def used_pages(self) -> int:
        with self._lock:
            return sum(len(p) for p in self._held.values())

    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def check_consistency(self) -> None:
        """Fuzz-test invariant: every non-reserved page is in exactly one
        place (the free list xor one sequence's table), and every row covers
        its sequence's length."""
        with self._lock:
            seen: dict[int, object] = {}
            for sid, pages in self._held.items():
                if len(pages) != self.pages_for(self._lens[sid]):
                    raise AssertionError(
                        f"{sid!r}: {len(pages)} pages for len {self._lens[sid]}"
                    )
                for p in pages:
                    if p in seen:
                        raise AssertionError(f"page {p} held by {seen[p]!r} and {sid!r}")
                    if not 0 < p < self.num_pages:
                        raise AssertionError(f"page {p} out of range (or reserved)")
                    seen[p] = sid
            for p in self._free:
                if p in seen:
                    raise AssertionError(f"page {p} both free and held by {seen[p]!r}")
                seen[p] = "<free>"
            if len(seen) != self.num_pages - 1:
                missing = set(range(1, self.num_pages)) - set(seen)
                raise AssertionError(f"leaked pages: {sorted(missing)}")

    def stats(self) -> dict:
        with self._lock:
            held = {str(k): len(v) for k, v in self._held.items()}
            return {
                "num_pages": self.num_pages,
                "page_size": self.page_size,
                "page_bytes": self.page_bytes,
                "free": len(self._free),
                "used": sum(held.values()),
                "sequences": len(held),
                "held_by_seq": held,
            }

    # ------------------------------------------------------------ page data

    def write_prefill(self, seq_id, stage_caches: dict, length: int) -> None:
        """Copy-on-prefill: scatter a request's dense prefill caches into
        its allocated pages. ``stage_caches[stage]`` is the chain's dense
        cache for ONE request — ``{'k','v'}`` of shape ``(L, 1, S, kv, hd)``
        or ``(L, S, kv, hd)`` — with the first ``length`` positions valid."""
        with self._lock:
            pages = list(self._held.get(seq_id, ()))
        if not pages:
            raise KeyError(f"no pages allocated for {seq_id!r}")
        n = self.pages_for(length)
        ids = jnp.asarray(pages[:n], jnp.int32)
        span = n * self.page_size
        for stage, cache in stage_caches.items():
            if stage not in self.data:
                continue
            dst = self.data[stage]
            for kv in ("k", "v"):
                src = cache[kv]
                if src.ndim == 5:  # (L, 1, S, kv, hd) -> (L, S, kv, hd)
                    src = src[:, 0]
                if src.shape[1] < span:
                    raise ValueError(
                        f"prefill cache covers {src.shape[1]} positions < {span} paged"
                    )
                chunks = src[:, :span].reshape(
                    src.shape[0], n, self.page_size, self.kv_heads, self.head_dim
                )
                dst[kv] = dst[kv].at[:, ids].set(chunks.astype(self.dtype))

    def gather(self, seq_id, stage: str, width: int | None = None) -> dict:
        """Contiguous view of one sequence's cache for a stage — the test
        oracle (and the shape the gather-fallback decode reconstructs).
        Returns ``{'k','v'}`` of shape (L, width*page, kv, hd)."""
        width = width or self.pages_for(self.seq_len(seq_id))
        row = jnp.asarray(self.block_row(seq_id, width))
        out = {}
        for kv in ("k", "v"):
            pages = self.data[stage][kv][:, row]  # (L, width, page, kv, hd)
            l = pages.shape[0]
            out[kv] = pages.reshape(l, width * self.page_size, self.kv_heads, self.head_dim)
        return out
