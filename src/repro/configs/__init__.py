from repro.configs.base import (  # noqa: F401
    ARCHS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    get_arch,
    get_shape,
    reduced_config,
    register_arch,
    shape_skip_reason,
)

# Importing the arch modules registers them.
from repro.configs import (  # noqa: F401
    qwen3_moe_30b_a3b,
    phi35_moe_42b_a6_6b,
    starcoder2_3b,
    llama32_1b,
    granite_34b,
    stablelm_1_6b,
    chameleon_34b,
    seamless_m4t_medium,
    mamba2_370m,
    zamba2_7b,
)
