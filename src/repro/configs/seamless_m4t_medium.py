"""SeamlessM4T-medium — enc-dec, 12L encoder + 12L decoder, d_model=1024
16H (MHA kv=16) d_ff=4096 vocab=256206, multimodal (audio frontend stub
provides frame embeddings). [arXiv:2308.11596]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        num_layers=12,            # encoder layers
        num_decoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_head=64,
        d_ff=4096,
        vocab_size=256206,
        act="gelu",
        norm="layernorm",
        rope_theta=10000.0,
        frontend="audio",
        num_function_groups=2,    # encoder fn + decoder fn: the canonical sync edge
        microbatches=4,  # train_4k fits 16GB/chip with grad accumulation
        source="arXiv:2308.11596",
    )
)
