"""StarCoder2-3B — 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
GQA + RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_head=128,
        d_ff=12288,
        vocab_size=49152,
        act="gelu",
        norm="layernorm",
        rope_theta=1e5,
        num_function_groups=4,
        source="arXiv:2402.19173",
    )
)
