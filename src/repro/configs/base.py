"""Model + shape configuration registry.

Every assigned architecture is a :class:`ModelConfig`; every assigned input
shape is a :class:`ShapeConfig`. A dry-run / benchmark cell is the pair.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

FULL_ATTENTION_FAMILIES = ("dense", "moe", "vlm", "audio")
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "dropping"     # dropping (capacity gather/scatter) | ragged (dropless)
    moe_min_group_tokens: int = 0  # 0 = auto (see moe.py group heuristic)
    router_aux_weight: float = 0.01
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    # --- hybrid (zamba2): shared transformer block applied every k layers ---
    shared_attn_every: int = 0
    # --- enc-dec ---
    num_decoder_layers: int = 0
    # --- misc arch knobs ---
    act: str = "silu"
    norm: str = "rmsnorm"
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    frontend: str = "none"         # none | audio | vlm (stub embeddings per spec)
    # --- platform deployment: Provuse function-chain granularity ---
    num_function_groups: int = 4
    # --- serving ---
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | float8_e4m3fn (quantized KV)
    # --- training knobs ---
    remat: bool = True
    microbatches: int = 1
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(1, self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.num_decoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in SUBQUADRATIC_FAMILIES


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCHS: dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k needs sub-quadratic sequence handling: run it only for
    SSM / hybrid archs (skip for pure full-attention — DESIGN.md §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names


def shape_skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 524k-token decode requires sub-quadratic attention (DESIGN.md §4)"
    return None


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    changes: dict = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 256) if cfg.vocab_size else 0,
        d_head=16 if cfg.num_heads else 0,
        num_heads=min(cfg.num_heads, 4) if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, max(1, min(cfg.num_kv_heads, 2))) if cfg.num_kv_heads else 0,
        remat=False,
        microbatches=1,
        num_function_groups=2,
    )
    if cfg.num_experts:
        changes.update(num_experts=min(cfg.num_experts, 4), num_experts_per_tok=min(cfg.num_experts_per_tok, 2), moe_d_ff=32)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.shared_attn_every:
        changes.update(shared_attn_every=2, num_layers=5)  # 2 groups of 2 + tail of 1
    if cfg.num_decoder_layers:
        changes.update(num_decoder_layers=2)
    return dataclasses.replace(cfg, **changes)
