"""Qwen3-MoE-30B-A3B — 48L d_model=2048 32H (GQA kv=4) MoE 128e top-8,
per-expert d_ff=768, vocab 151936. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_head=128,
        d_ff=0,                 # all layers are MoE
        moe_d_ff=768,
        num_experts=128,
        num_experts_per_tok=8,
        vocab_size=151936,
        act="silu",
        norm="rmsnorm",
        qk_norm=True,           # qwen3 uses per-head q/k RMSNorm
        rope_theta=1e6,
        num_function_groups=6,
        moe_impl="dropping_ep",  # EP-local dispatch+psum_scatter combine (EXPERIMENTS §Perf A1)
        microbatches=4,  # train_4k fits 16GB/chip with grad accumulation
        source="hf:Qwen/Qwen3-30B-A3B",
    )
)
