"""Phi-3.5-MoE-42B-A6.6B — 32L d_model=4096 32H (GQA kv=8) d_ff=6400,
MoE 16e top-2, vocab 32064. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_head=128,
        d_ff=0,
        moe_d_ff=6400,
        num_experts=16,
        num_experts_per_tok=2,
        vocab_size=32064,
        act="silu",
        norm="layernorm",
        rope_theta=10000.0,
        num_function_groups=4,
        moe_impl="dropping_ep",  # EP-local dispatch+psum_scatter combine (EXPERIMENTS §Perf A1)
        microbatches=8,  # train_4k fits 16GB/chip with grad accumulation
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )
)
