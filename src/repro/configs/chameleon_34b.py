"""Chameleon-34B — 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536,
early-fusion VQ image tokens (frontend stub provides patch embeddings).
[arXiv:2405.09818]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_head=128,
        d_ff=22016,
        vocab_size=65536,
        act="silu",
        norm="rmsnorm",
        qk_norm=True,           # chameleon stabilizes with QK-norm
        rope_theta=10000.0,
        frontend="vlm",
        num_function_groups=6,
        microbatches=4,  # train_4k fits 16GB/chip with grad accumulation
        source="arXiv:2405.09818",
    )
)
