"""Granite-34B-Code — 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
[arXiv:2405.04324]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_head=128,
        d_ff=24576,
        vocab_size=49152,
        act="gelu",
        norm="layernorm",
        rope_theta=10000.0,
        tie_embeddings=True,
        num_function_groups=8,
        microbatches=4,  # train_4k fits 16GB/chip with grad accumulation
        source="arXiv:2405.04324",
    )
)
