"""StableLM-2-1.6B — 24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_head=64,
        d_ff=5632,
        vocab_size=100352,
        act="silu",
        norm="layernorm",
        rope_theta=10000.0,
        num_function_groups=4,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
)
