"""Mamba2-370M — 48L d_model=1024, attention-free SSD (state-space duality),
ssm_state=128, vocab 50280. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_groups=1,
        conv_kernel=4,
        act="silu",
        norm="rmsnorm",
        tie_embeddings=True,
        num_function_groups=4,
        microbatches=2,  # train_4k fits 16GB/chip with grad accumulation
        source="arXiv:2405.21060",
    )
)
