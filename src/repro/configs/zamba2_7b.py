"""Zamba2-7B — 81L d_model=3584, Mamba2 backbone + shared attention block
(32H MHA, d_ff=14336) applied every 6th layer, ssm_state=64, vocab 32000.
81 layers = 13 groups of 6 + tail of 3 (DESIGN.md §4). [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_head=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_groups=1,
        conv_kernel=4,
        shared_attn_every=6,
        act="silu",
        norm="rmsnorm",
        num_function_groups=4,
        microbatches=4,  # train_4k fits 16GB/chip with grad accumulation
        source="arXiv:2411.15242",
    )
)
