"""Step-atomic checkpointing with retention, async save, and
restore-with-reshard (elastic scaling).

Atomicity: a checkpoint is written to ``step_<N>.tmp/`` and ``os.rename``d
into place — a crash mid-save can never produce a readable-but-corrupt
checkpoint, so restart always finds a consistent latest step.

Elasticity: checkpoints store *logical* content (flattened arrays keyed by
tree path), not device layouts. ``restore`` re-shards every leaf onto the
mesh it is given — save on mesh A, restore on mesh B (tested), which is how
the framework handles node loss / cluster resize: restart with a new mesh
and continue from the latest step.

Multi-host note: on a real pod each process would write only its addressable
shards (same layout, per-process files) and restore with
``jax.make_array_from_single_device_arrays``; the single-process container
writes full arrays. The API is identical either way.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.scheduler.clock import SYSTEM_CLOCK

# numpy can't serialize ml_dtypes types; store them as same-width uint views
# and record the true dtype in meta.json.
_VIEW_AS = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
    np.dtype(ml_dtypes.float8_e5m2): np.uint8,
}
_VIEW_BACK = {str(k): k for k in _VIEW_AS}


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, retain: int = 3, async_save: bool = False,
                 clock=None):
        self.directory = directory
        self.retain = retain
        self.async_save = async_save
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        os.makedirs(directory, exist_ok=True)
        self._save_thread: threading.Thread | None = None
        self.save_log: list[dict] = []

    # --------------------------------------------------------------- save

    def save(self, step: int, state) -> None:
        if self.async_save:
            host_state = jax.tree.map(lambda x: np.asarray(x), state)  # snapshot
            self.wait()  # one in-flight save at a time
            self._save_thread = threading.Thread(
                target=self._save_sync, args=(step, host_state), daemon=True
            )
            self._save_thread.start()
        else:
            self._save_sync(step, state)

    def wait(self) -> None:
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None

    def _save_sync(self, step: int, state) -> None:
        t0 = time.perf_counter()
        tmp = os.path.join(self.directory, f"step_{step:010d}.tmp")
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(state)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        meta = {
            "step": step,
            "keys": sorted(arrays),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "wall_time": self.clock.now(),
        }
        arrays = {
            k: (v.view(_VIEW_AS[v.dtype]) if v.dtype in _VIEW_AS else v)
            for k, v in arrays.items()
        }
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._cleanup()
        self.save_log.append({"step": step, "seconds": time.perf_counter() - t0})

    def _cleanup(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.retain] if self.retain else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, *, mesh=None, rules=None, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Re-shards onto ``shardings`` (a matching pytree)
        or onto each ``like`` leaf's own sharding if it has one."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        data = dict(np.load(os.path.join(path, "arrays.npz")).items())
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        for key, dt in meta["dtypes"].items():
            if dt in _VIEW_BACK and key in data:
                data[key] = data[key].view(_VIEW_BACK[dt])
        flat_like = _flatten_with_paths(like)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}
        restored = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            target_dtype = jnp.result_type(leaf)
            sharding = flat_shard.get(key)
            if sharding is None:
                sharding = getattr(leaf, "sharding", None)
                if sharding is not None and getattr(sharding, "is_fully_addressable", True) is False:
                    sharding = None
            val = jnp.asarray(arr, dtype=target_dtype)
            if sharding is not None:
                val = jax.device_put(val, sharding)
            restored[key] = val
        # rebuild in tree order
        keys_in_order = list(_flatten_with_paths(like).keys())
        return jax.tree_util.tree_unflatten(treedef, [restored[k] for k in keys_in_order])
