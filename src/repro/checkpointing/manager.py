"""Step-atomic checkpointing with retention, async save, and
restore-with-reshard (elastic scaling).

Atomicity: a checkpoint is written to ``step_<N>.tmp/`` and ``os.rename``d
into place — a crash mid-save can never produce a readable-but-corrupt
checkpoint, so restart always finds a consistent latest step.

Elasticity: checkpoints store *logical* content (flattened arrays keyed by
tree path), not device layouts. ``restore`` re-shards every leaf onto the
mesh it is given — save on mesh A, restore on mesh B (tested), which is how
the framework handles node loss / cluster resize: restart with a new mesh
and continue from the latest step.

Multi-host note: on a real pod each process would write only its addressable
shards (same layout, per-process files) and restore with
``jax.make_array_from_single_device_arrays``; the single-process container
writes full arrays. The API is identical either way.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.scheduler.clock import SYSTEM_CLOCK

# numpy can't serialize ml_dtypes types; store them as same-width uint views
# and record the true dtype in meta.json.
_VIEW_AS = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
    np.dtype(ml_dtypes.float8_e5m2): np.uint8,
}
_VIEW_BACK = {str(k): k for k in _VIEW_AS}


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


class CheckpointSaveError(RuntimeError):
    """An async save worker failed. Raised on the NEXT ``wait()`` /
    ``latest_step()`` / ``save()`` — the thread itself can only die silently,
    and a training loop that keeps stepping against a checkpointer that
    stopped persisting is the failure mode this surfaces."""


class CheckpointManager:
    def __init__(self, directory: str, *, retain: int = 3, async_save: bool = False,
                 clock=None, writer=None):
        self.directory = directory
        self.retain = retain
        self.async_save = async_save
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        os.makedirs(directory, exist_ok=True)
        self._save_thread: threading.Thread | None = None
        self._save_error: BaseException | None = None
        self._writer = writer if writer is not None else np.savez
        self.save_log: list[dict] = []

    # --------------------------------------------------------------- save

    def save(self, step: int, state) -> None:
        if self.async_save:
            host_state = jax.tree.map(lambda x: np.asarray(x), state)  # snapshot
            self.wait()  # one in-flight save at a time; surfaces a prior failure
            self._save_thread = threading.Thread(
                target=self._save_guarded, args=(step, host_state), daemon=True
            )
            self._save_thread.start()
        else:
            self._save_sync(step, state)

    def _save_guarded(self, step: int, state) -> None:
        try:
            self._save_sync(step, state)
        except BaseException as exc:  # noqa: BLE001 — captured, re-raised on wait()
            self._save_error = exc

    def _surface_save_error(self) -> None:
        exc = self._save_error
        if exc is not None:
            # surfaced once: the failed step is gone either way, and the next
            # save may succeed (transient disk pressure, fixed permissions)
            self._save_error = None
            raise CheckpointSaveError(f"async checkpoint save failed: {exc!r}") from exc

    def wait(self) -> None:
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        self._surface_save_error()

    def _save_sync(self, step: int, state) -> None:
        t0 = time.perf_counter()
        tmp = os.path.join(self.directory, f"step_{step:010d}.tmp")
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(state)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        meta = {
            "step": step,
            "keys": sorted(arrays),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "wall_time": self.clock.now(),
        }
        arrays = {
            k: (v.view(_VIEW_AS[v.dtype]) if v.dtype in _VIEW_AS else v)
            for k, v in arrays.items()
        }
        self._writer(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._cleanup()
        self.save_log.append({"step": step, "seconds": time.perf_counter() - t0})

    def _cleanup(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.retain] if self.retain else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        # A finished-but-failed async worker must not let the PREVIOUS step
        # silently masquerade as latest. Only a completed thread is joined —
        # latest_step never blocks behind an in-flight save.
        t = self._save_thread
        if t is not None and not t.is_alive():
            self.wait()
        else:
            self._surface_save_error()
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, *, mesh=None, rules=None, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Re-shards onto ``shardings`` (a matching pytree)
        or onto each ``like`` leaf's own sharding if it has one."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        data = dict(np.load(os.path.join(path, "arrays.npz")).items())
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        for key, dt in meta["dtypes"].items():
            if dt in _VIEW_BACK and key in data:
                data[key] = data[key].view(_VIEW_BACK[dt])
        flat_like = _flatten_with_paths(like)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}
        restored = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            target_dtype = jnp.result_type(leaf)
            sharding = flat_shard.get(key)
            if sharding is None:
                sharding = getattr(leaf, "sharding", None)
                if sharding is not None and getattr(sharding, "is_fully_addressable", True) is False:
                    sharding = None
            val = jnp.asarray(arr, dtype=target_dtype)
            if sharding is not None:
                val = jax.device_put(val, sharding)
            restored[key] = val
        # rebuild in tree order
        keys_in_order = list(_flatten_with_paths(like).keys())
        return jax.tree_util.tree_unflatten(treedef, [restored[k] for k in keys_in_order])


# ------------------------------------------------------------------ snapshots


def snapshot_digest(tree) -> str:
    """Content address of a param tree: treedef plus every leaf's path,
    dtype, shape, and full bytes. Bit-exact by construction — two trees
    share a digest iff they restore identically."""
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_flatten(tree)[1]
    h = hashlib.blake2b(digest_size=16)
    h.update(str(treedef).encode())
    for key in sorted(flat):
        arr = np.asarray(flat[key])
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(repr(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


class SnapshotIntegrityError(RuntimeError):
    """Restored bytes do not re-hash to the requested digest (on-disk
    corruption / truncation) — the caller must fall back to a cold build."""


class SnapshotStore:
    """Content-addressed instance snapshots — warm-provisioning level 2.

    Layout: ``<dir>/<digest>/leaf_00000.npy .. leaf_NNNNN.npy + meta.json``
    where the digest is :func:`snapshot_digest` of the param tree. Writes go
    to ``<digest>.tmp`` and ``os.rename`` into place (same crash-atomicity as
    checkpoints); ``put`` of an already-stored tree is a metadata touch
    (content-address dedup — a fleet of same-weights functions stores one
    copy). ``restore`` opens each leaf with ``np.load(mmap_mode='r')`` so
    bytes are paged in lazily, and by default re-hashes what it read against
    the digest — a resurrect either gets bit-exact params or an integrity
    error, never silent corruption.

    ``retain`` > 0 keeps only the N most-recently-used snapshots (mtime LRU;
    both put-dedup and restore refresh recency). 0 disables eviction — the
    platform pins parked functions' snapshots simply by not enabling it.
    """

    GUARDED_FIELDS = {
        "puts": "_lock",
        "dedup_hits": "_lock",
        "restores": "_lock",
        "put_s": "_lock",
        "restore_s": "_lock",
        "evicted": "_lock",
    }

    def __init__(self, directory: str, *, retain: int = 0, clock=None):
        self.directory = directory
        self.retain = retain
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self.puts = 0
        self.dedup_hits = 0
        self.restores = 0
        self.put_s = 0.0
        self.restore_s = 0.0
        self.evicted = 0

    def path_of(self, digest: str) -> str:
        return os.path.join(self.directory, digest)

    def contains(self, digest: str) -> bool:
        return os.path.isdir(self.path_of(digest))

    def put(self, tree) -> str:
        """Store ``tree`` under its content address; returns the digest."""
        t0 = time.perf_counter()
        digest = snapshot_digest(tree)
        final = self.path_of(digest)
        if os.path.isdir(final):
            os.utime(final)  # refresh LRU recency
            with self._lock:
                self.dedup_hits += 1
            return digest
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(tree)
        keys = sorted(flat)
        treedef = jax.tree_util.tree_flatten(tree)[1]
        meta = {
            "digest": digest,
            "keys": keys,
            "treedef": str(treedef),
            "dtypes": {},
            "shapes": {},
            "wall_time": self.clock.now(),
        }
        for i, key in enumerate(keys):
            arr = np.asarray(flat[key])
            meta["dtypes"][key] = str(arr.dtype)
            meta["shapes"][key] = list(arr.shape)  # BEFORE ascontiguousarray: it promotes 0-d to (1,)
            arr = np.ascontiguousarray(arr)
            stored = arr.view(_VIEW_AS[arr.dtype]) if arr.dtype in _VIEW_AS else arr
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), stored)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.rename(tmp, final)  # atomic publish
        with self._lock:
            self.puts += 1
            self.put_s += time.perf_counter() - t0
        self._evict()
        return digest

    def restore(self, digest: str, like, *, verify: bool = True):
        """Rebuild the tree of ``like`` (arrays or ShapeDtypeStructs) from the
        snapshot at ``digest``. ``verify=True`` re-hashes the restored host
        bytes and raises :class:`SnapshotIntegrityError` on mismatch."""
        t0 = time.perf_counter()
        final = self.path_of(digest)
        if not os.path.isdir(final):
            raise FileNotFoundError(f"no snapshot {digest} in {self.directory}")
        os.utime(final)  # refresh LRU recency
        with open(os.path.join(final, "meta.json")) as f:
            meta = json.load(f)
        host = {}
        for i, key in enumerate(meta["keys"]):
            arr = np.load(os.path.join(final, f"leaf_{i:05d}.npy"), mmap_mode="r")
            dt = meta["dtypes"][key]
            if dt in _VIEW_BACK:
                arr = arr.view(_VIEW_BACK[dt])
            # a memmap is never 0-d: np.load promotes scalar leaves to (1,);
            # reshape restores the recorded shape without copying
            host[key] = arr.reshape(meta["shapes"][key])
        flat_like = _flatten_with_paths(like)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        if verify:
            np_tree = jax.tree_util.tree_unflatten(
                treedef, [np.asarray(host[k]) for k in flat_like]
            )
            got = snapshot_digest(np_tree)
            if got != digest:
                raise SnapshotIntegrityError(
                    f"snapshot {digest} restored with digest {got}"
                )
        out = jax.tree_util.tree_unflatten(
            treedef,
            [jnp.asarray(host[k], dtype=jnp.result_type(flat_like[k])) for k in flat_like],
        )
        with self._lock:
            self.restores += 1
            self.restore_s += time.perf_counter() - t0
        return out

    def _evict(self) -> None:
        if not self.retain:
            return
        dirs = []
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            if name.endswith(".tmp") or not os.path.isdir(path):
                continue
            dirs.append((os.path.getmtime(path), path))
        dirs.sort()
        for _, path in dirs[: -self.retain]:
            shutil.rmtree(path, ignore_errors=True)
            with self._lock:
                self.evicted += 1

    def stats(self) -> dict:
        with self._lock:
            out = {
                "puts": self.puts,
                "dedup_hits": self.dedup_hits,
                "restores": self.restores,
                "put_s": round(self.put_s, 4),
                "restore_s": round(self.restore_s, 4),
                "evicted": self.evicted,
            }
        out["entries"] = sum(
            1 for d in os.listdir(self.directory) if not d.endswith(".tmp")
        )
        return out
