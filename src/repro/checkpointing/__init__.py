from repro.checkpointing.manager import (  # noqa: F401
    CheckpointManager,
    CheckpointSaveError,
    SnapshotIntegrityError,
    SnapshotStore,
    snapshot_digest,
)
