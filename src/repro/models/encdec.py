"""Encoder-decoder transformer (SeamlessM4T-style backbone).

The modality frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, S_src, d) — the encoder consumes them
directly. Decoder = causal self-attention + cross-attention over encoder
states.

On the Provuse platform the encoder and decoder are deployed as two separate
functions — the decoder's blocking wait on encoder output is the canonical
synchronous edge the Function Handler detects (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.models.layers import apply_mlp, apply_norm, mlp_defs, norm_defs
from repro.models.params import ParamDef, stack_defs
from repro.sharding.specs import LogicalRules, shard_as


def cross_attn_defs(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((d, h, hd), ("embed_fsdp", "heads", "head_dim")),
        "wk": ParamDef((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed_fsdp")),
    }


def decoder_block_defs(cfg: ModelConfig):
    defs = tfm.block_defs(cfg, "dense")
    defs["ln_cross"] = norm_defs(cfg)
    defs["cross"] = cross_attn_defs(cfg)
    return defs


def encdec_defs(cfg: ModelConfig):
    return {
        "encoder": stack_defs(tfm.block_defs(cfg, "dense"), cfg.num_layers),
        "decoder": stack_defs(decoder_block_defs(cfg), cfg.num_decoder_layers),
    }


def _apply_cross(params, x, enc_kv, cfg, valid_src_len=None):
    """x: (B,T,d); enc_kv = (k,v): (B,S,KV,hd)."""
    h = apply_norm(params["ln_cross"], x, cfg)
    q = jnp.einsum("btd,dhk->bthk", h, params["cross"]["wq"])
    if x.shape[1] == 1 and valid_src_len is not None:
        out = attn_mod.decode_attention(q, enc_kv[0], enc_kv[1], valid_src_len)
    else:
        out = attn_mod.full_attention(q, enc_kv[0], enc_kv[1], causal=False)
    return x + jnp.einsum("bthk,hkd->btd", out, params["cross"]["wo"])


def encode(params, src: jax.Array, cfg: ModelConfig, rules: LogicalRules | None):
    """src: (B, S, d) frame embeddings -> encoder states (B, S, d)."""
    positions = jnp.arange(src.shape[1])[None, :]
    x, _, metrics = tfm.apply_stack_full(
        params["encoder"], src, cfg, "dense", rules, positions, causal=False
    )
    return x, metrics


def cross_kv_from_enc(params, enc: jax.Array):
    """Project encoder states into per-decoder-layer cross K/V.
    Returns {'k','v'}: (L_dec, B, S, KV, hd) — the decode-time cross cache."""

    def one_layer(layer_params, _):
        k = jnp.einsum("bsd,dhk->bshk", enc, layer_params["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, layer_params["cross"]["wv"])
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(lambda c, p: one_layer(p, c), None, params["decoder"])
    return {"k": ks, "v": vs}


def decode_train(params, tgt_emb: jax.Array, enc: jax.Array, cfg: ModelConfig, rules):
    """Teacher-forced decoder over full target. tgt_emb: (B, T, d)."""
    positions = jnp.arange(tgt_emb.shape[1])[None, :]

    def body(carry, layer_params):
        h, _, metrics = tfm.apply_block_full(layer_params, carry, cfg, "dense", rules, positions, causal=True)
        k = jnp.einsum("bsd,dhk->bshk", enc, layer_params["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, layer_params["cross"]["wv"])
        h = _apply_cross(layer_params, h, (k, v), cfg)
        h = shard_as(h, ("batch", "seq", None), rules)
        return h, metrics

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, metrics = jax.lax.scan(body_fn, tgt_emb, params["decoder"])
    return x, jax.tree.map(jnp.sum, metrics)


def decoder_step(params, x: jax.Array, self_cache, cross_cache, cfg, rules, cur_len, src_len):
    """One decode token. self_cache k/v: (L,B,S_tgt,KV,hd); cross_cache k/v:
    (L,B,S_src,KV,hd)."""

    def body(carry, inp):
        layer_params, cache, ck, cv = inp
        h, new_cache, metrics = tfm.apply_block_decode(layer_params, carry, cache, cfg, "dense", rules, cur_len)
        h = _apply_cross(layer_params, h, (ck, cv), cfg, valid_src_len=src_len)
        return h, (new_cache, metrics)

    x, (new_caches, metrics) = jax.lax.scan(
        body, x, (params["decoder"], self_cache, cross_cache["k"], cross_cache["v"])
    )
    return x, new_caches, jax.tree.map(jnp.sum, metrics)
