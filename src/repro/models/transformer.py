"""Pre-LN transformer blocks and scanned stacks.

Layers are stacked on a leading 'layers' axis and applied with
``jax.lax.scan`` so the HLO is O(1) in depth (critical: the dry-run compiles
88-layer/34B programs on a CPU host). ``jax.checkpoint`` wraps the block body
when ``cfg.remat`` — activation memory is one residual stream per layer
boundary, everything else recomputed in backward.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_defs, norm_defs
from repro.models.params import ParamDef, stack_defs
from repro.sharding.specs import LogicalRules, shard_as, shard_as_bf16_grad

ZERO_METRICS = {"moe_aux": 0.0, "moe_dropped": 0.0}


def _metrics_like(m: dict | None) -> dict:
    out = dict(ZERO_METRICS)
    if m:
        out.update(m)
    return {k: jnp.asarray(v, jnp.float32) for k, v in out.items()}


# ------------------------------------------------------------------ blocks


def block_defs(cfg: ModelConfig, kind: str):
    """kind: dense | moe | ssm"""
    if kind == "ssm":
        return {"ln1": norm_defs(cfg), "ssm": ssm_mod.ssm_defs(cfg)}
    defs = {
        "ln1": norm_defs(cfg),
        "attn": attn_mod.attn_defs(cfg),
        "ln2": norm_defs(cfg),
    }
    if kind == "moe":
        defs["moe"] = moe_mod.moe_defs(cfg)
    else:
        defs["mlp"] = mlp_defs(cfg)
    return defs


def layer_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "moe":
        return "moe"
    return "dense"


def apply_block_full(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    positions: jax.Array,
    causal: bool = True,
    collect_cache: bool = False,
):
    """Full-sequence block. Returns (x, cache_entry | None, metrics).

    cache_entry: (k, v) for attention kinds, ssm state dict for 'ssm'."""
    metrics = None
    if kind == "ssm":
        h = apply_norm(params["ln1"], x, cfg)
        if collect_cache:
            out, cache = ssm_mod.apply_ssm(params["ssm"], h, cfg, rules, return_cache=True)
        else:
            out, cache = ssm_mod.apply_ssm(params["ssm"], h, cfg, rules), None
        x = x + out
        x = shard_as(x, ("batch", "seq", None), rules)
        return x, cache, _metrics_like(metrics)

    h = apply_norm(params["ln1"], x, cfg)
    q, k, v = attn_mod.qkv_project(params["attn"], h, cfg, positions)
    q = shard_as(q, ("batch", "seq_full", "act_heads", None), rules)
    k_attn, v_attn = k, v
    if rules is not None:
        msize = rules.mesh_axis_sizes.get("model", 1)
        if cfg.num_heads % msize == 0 and 1 < cfg.num_kv_heads < msize:
            # GQA under TP: the (kv, group) split of the head dim cannot be
            # sharded 16-way without GSPMD splitting BOTH sub-dims, which
            # inserts partial-sum all-reduces inside every attention chunk
            # (measured ~360 GB/step on qwen3 train — EXPERIMENTS §Perf #3).
            # K/V are TP-replicated anyway; repeating them to full heads
            # keeps the head dim cleanly sharded and attention collective-free.
            rep = cfg.num_heads // cfg.num_kv_heads
            k_attn = jnp.repeat(k, rep, axis=2)
            v_attn = jnp.repeat(v, rep, axis=2)
            k_attn = shard_as(k_attn, ("batch", "seq_full", "act_heads", None), rules)
            v_attn = shard_as(v_attn, ("batch", "seq_full", "act_heads", None), rules)
    out = attn_mod.full_attention(q, k_attn, v_attn, causal=causal)
    x = x + attn_mod.attn_output(params["attn"], out)
    x = shard_as_bf16_grad(x, ("batch", "seq", None), rules)
    if collect_cache:
        # the prefill-built cache must land in the decode layout (seq or
        # kv-heads over 'model'), not batch-only sharded — and in the
        # configured cache dtype (fp8 when quantized-KV is on)
        cache_dt = jnp.dtype(cfg.kv_cache_dtype)
        k = shard_as(k.astype(cache_dt), ("batch", "cache_seq", "cache_kv_heads", None), rules)
        v = shard_as(v.astype(cache_dt), ("batch", "cache_seq", "cache_kv_heads", None), rules)

    h = apply_norm(params["ln2"], x, cfg)
    if kind == "moe":
        y, metrics = moe_mod.apply_moe(params["moe"], h, cfg, rules)
    else:
        y = apply_mlp(params["mlp"], h, cfg)
    x = x + y
    x = shard_as_bf16_grad(x, ("batch", "seq", None), rules)
    return x, (k, v), _metrics_like(metrics)


def apply_block_decode(
    params,
    x: jax.Array,
    cache: dict,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    cur_len: jax.Array,
):
    """Single-token block step. cache: {'k','v'} or SSM state dict."""
    metrics = None
    if kind == "ssm":
        h = apply_norm(params["ln1"], x, cfg)
        out, new_cache = ssm_mod.ssm_decode_step(params["ssm"], h, cache, cfg)
        return x + out, new_cache, _metrics_like(metrics)

    positions = cur_len[:, None]  # (B, 1)
    h = apply_norm(params["ln1"], x, cfg)
    q, k_new, v_new = attn_mod.qkv_project(params["attn"], h, cfg, positions)
    k_cache, v_cache = attn_mod.update_kv_cache(cache["k"], cache["v"], k_new, v_new, positions)
    out = attn_mod.decode_attention(q, k_cache, v_cache, cur_len + 1)
    x = x + attn_mod.attn_output(params["attn"], out)

    h = apply_norm(params["ln2"], x, cfg)
    if kind == "moe":
        y, metrics = moe_mod.apply_moe(params["moe"], h, cfg, rules)
    else:
        y = apply_mlp(params["mlp"], h, cfg)
    x = x + y
    new_cache = {"k": k_cache, "v": v_cache}
    return x, new_cache, _metrics_like(metrics)


def apply_block_decode_paged(
    params,
    x: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    cur_len: jax.Array,
    write_kv: bool = True,
):
    """Single-token block step against one layer's page arena slice.

    Same math as :func:`apply_block_decode` but the KV cache is
    ``(num_pages, page, KV, hd)`` shared across requests, addressed through
    the batch's block table: scatter the new token's K/V into its page,
    then attend through the table (kernel indirection on TPU, contiguous
    gather elsewhere). Attention kinds only — SSM state is recurrent, not
    length-indexed, so it has no pages.

    ``write_kv=False`` runs a FROZEN step: the new token's K/V is assumed
    already resident at position ``cur_len`` (a shared-prefix-cache hit)
    and nothing is written — the engine uses this to recover first-token
    logits for a whole-prompt hit without touching shared pages."""
    if kind == "ssm":
        raise ValueError("paged decode applies to attention caches only")
    metrics = None
    positions = cur_len[:, None]  # (B, 1)
    h = apply_norm(params["ln1"], x, cfg)
    q, k_new, v_new = attn_mod.qkv_project(params["attn"], h, cfg, positions)
    if write_kv:
        k_pages, v_pages = attn_mod.update_paged_kv(
            k_pages, v_pages, k_new, v_new, block_table, cur_len
        )
    out = attn_mod.paged_decode_attention(q, k_pages, v_pages, block_table, cur_len + 1)
    x = x + attn_mod.attn_output(params["attn"], out)

    h = apply_norm(params["ln2"], x, cfg)
    if kind == "moe":
        y, metrics = moe_mod.apply_moe(params["moe"], h, cfg, rules)
    else:
        y = apply_mlp(params["mlp"], h, cfg)
    x = x + y
    return x, k_pages, v_pages, _metrics_like(metrics)


def apply_block_prefill_chunk_paged(
    params,
    x: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    start: jax.Array,
    valid: jax.Array,
):
    """One prefill CHUNK's block step against a layer's page arena slice.

    ``x``: (1, C, d) — C chunk rows whose absolute positions begin at
    ``start`` (shape (1,)); ``valid`` (shape (1,)) counts the real rows
    (the rest are compile-cache padding whose K/V writes route to the
    scratch page). The chunk's K/V is scattered BEFORE attention so chunk
    tokens attend to themselves and each other, exactly like the matching
    rows of a dense causal prefill — chunked prefill is bit-exact vs dense
    on the gather path."""
    if kind == "ssm":
        raise ValueError("paged prefill applies to attention caches only")
    metrics = None
    c = x.shape[1]
    positions = start[:, None] + jnp.arange(c)[None, :]  # (1, C)
    h = apply_norm(params["ln1"], x, cfg)
    q, k_new, v_new = attn_mod.qkv_project(params["attn"], h, cfg, positions)
    k_pages, v_pages = attn_mod.update_paged_kv_chunk(
        k_pages, v_pages, k_new, v_new, block_table, start, valid
    )
    out = attn_mod.paged_chunk_attention(q, k_pages, v_pages, block_table, start)
    x = x + attn_mod.attn_output(params["attn"], out)

    h = apply_norm(params["ln2"], x, cfg)
    if kind == "moe":
        y, metrics = moe_mod.apply_moe(params["moe"], h, cfg, rules)
    else:
        y = apply_mlp(params["mlp"], h, cfg)
    x = x + y
    return x, k_pages, v_pages, _metrics_like(metrics)


# ------------------------------------------------------------------ stacks


def stack_block_defs(cfg: ModelConfig, kind: str, n_layers: int):
    return stack_defs(block_defs(cfg, kind), n_layers)


def apply_stack_full(
    stacked_params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    positions: jax.Array,
    causal: bool = True,
    collect_cache: bool = False,
    unroll: bool | None = None,
):
    """Full-sequence pass through the stack.

    Returns (x, stacked cache pytree (leading 'layers' dim) or None, metrics
    summed). For attention kinds the cache is {'k','v'}; for ssm it is the
    ssm state dict.

    ``unroll`` exists for experimentation; the scan path is the default for
    all passes (unrolled loops lose cross-layer buffer reuse)."""
    if unroll is None:
        unroll = False
    if unroll:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        entries = []
        metrics = None
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], stacked_params)
            x, entry, m = apply_block_full(lp, x, cfg, kind, rules, positions, causal, collect_cache)
            metrics = m if metrics is None else jax.tree.map(jnp.add, metrics, m)
            if collect_cache:
                entries.append(entry)
        cache = None
        if collect_cache and entries and entries[0] is not None:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *entries)
            cache = {"k": stacked[0], "v": stacked[1]} if isinstance(stacked, tuple) else stacked
        return x, cache, metrics

    def body(carry, layer_params):
        h, entry, metrics = apply_block_full(
            layer_params, carry, cfg, kind, rules, positions, causal, collect_cache
        )
        ys = (entry if collect_cache else None, metrics)
        return h, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (entries, metrics) = jax.lax.scan(body_fn, x, stacked_params)
    cache = None
    if collect_cache and entries is not None:
        cache = {"k": entries[0], "v": entries[1]} if isinstance(entries, tuple) else entries
    return x, cache, jax.tree.map(jnp.sum, metrics)


def apply_stack_decode(
    stacked_params,
    x: jax.Array,
    caches,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    cur_len: jax.Array,
    mode: str = "carry",
):
    """One decode step through the stack; caches have a leading 'layers' dim.

    mode='carry' (default): the cache rides in the scan CARRY and each layer
    does an in-place dynamic-update at its index — ONE cache buffer total.
    Passing the cache as scan xs/ys instead makes XLA double-buffer it
    (in + out copies; measured +2x cache temp on the 34B decode cells), and
    a python-unrolled loop is worse still (no cross-layer buffer reuse).
    mode='xs' keeps the plain xs/ys formulation for comparison."""
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    if mode == "carry":
        def body(carry, inp):
            i, layer_params = inp
            h, caches_c = carry
            cache_i = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), caches_c)
            h, new_cache, metrics = apply_block_decode(layer_params, h, cache_i, cfg, kind, rules, cur_len)
            caches_c = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u.astype(a.dtype), i, 0),
                caches_c, new_cache,
            )
            return (h, caches_c), metrics

        (x, new_caches), metrics = jax.lax.scan(
            body, (x, caches), (jnp.arange(n), stacked_params)
        )
        return x, new_caches, jax.tree.map(jnp.sum, metrics)

    def body(carry, inp):
        layer_params, cache = inp
        h, new_cache, metrics = apply_block_decode(layer_params, carry, cache, cfg, kind, rules, cur_len)
        return h, (new_cache, metrics)

    x, (new_caches, metrics) = jax.lax.scan(body, x, (stacked_params, caches))
    return x, new_caches, jax.tree.map(jnp.sum, metrics)


def apply_stack_decode_paged(
    stacked_params,
    x: jax.Array,
    arena: dict,
    block_table: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    cur_len: jax.Array,
    write_kv: bool = True,
):
    """One decode step through the stack against a paged arena.

    ``arena``: ``{'k','v'}`` of shape (L, num_pages, page, KV, hd) — the
    stage's slice of the shared pool. Like :func:`apply_stack_decode`'s
    carry mode, the arena rides in the scan CARRY with per-layer in-place
    dynamic updates, so the whole pool stays ONE buffer through the stack
    instead of double-buffering per layer. ``write_kv=False`` is the frozen
    step (see :func:`apply_block_decode_paged`): nothing is scattered and
    the arena comes back unchanged."""
    n = jax.tree.leaves(stacked_params)[0].shape[0]

    def body(carry, inp):
        i, layer_params = inp
        h, arena_c = carry
        k_pages = jax.lax.dynamic_index_in_dim(arena_c["k"], i, 0, keepdims=False)
        v_pages = jax.lax.dynamic_index_in_dim(arena_c["v"], i, 0, keepdims=False)
        h, k_pages, v_pages, metrics = apply_block_decode_paged(
            layer_params, h, k_pages, v_pages, block_table, cfg, kind, rules,
            cur_len, write_kv,
        )
        if write_kv:
            arena_c = {
                "k": jax.lax.dynamic_update_index_in_dim(
                    arena_c["k"], k_pages.astype(arena_c["k"].dtype), i, 0
                ),
                "v": jax.lax.dynamic_update_index_in_dim(
                    arena_c["v"], v_pages.astype(arena_c["v"].dtype), i, 0
                ),
            }
        return (h, arena_c), metrics

    (x, new_arena), metrics = jax.lax.scan(
        body, (x, arena), (jnp.arange(n), stacked_params)
    )
    return x, new_arena, jax.tree.map(jnp.sum, metrics)


def apply_stack_prefill_chunk_paged(
    stacked_params,
    x: jax.Array,
    arena: dict,
    block_table: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rules: LogicalRules | None,
    start: jax.Array,
    valid: jax.Array,
):
    """One prefill chunk through the stack against a paged arena — same
    single-buffer carry pattern as :func:`apply_stack_decode_paged`, with
    the chunk block step (scatter C rows, attend causally from ``start``)
    in place of the single-token one."""
    n = jax.tree.leaves(stacked_params)[0].shape[0]

    def body(carry, inp):
        i, layer_params = inp
        h, arena_c = carry
        k_pages = jax.lax.dynamic_index_in_dim(arena_c["k"], i, 0, keepdims=False)
        v_pages = jax.lax.dynamic_index_in_dim(arena_c["v"], i, 0, keepdims=False)
        h, k_pages, v_pages, metrics = apply_block_prefill_chunk_paged(
            layer_params, h, k_pages, v_pages, block_table, cfg, kind, rules,
            start, valid,
        )
        arena_c = {
            "k": jax.lax.dynamic_update_index_in_dim(
                arena_c["k"], k_pages.astype(arena_c["k"].dtype), i, 0
            ),
            "v": jax.lax.dynamic_update_index_in_dim(
                arena_c["v"], v_pages.astype(arena_c["v"].dtype), i, 0
            ),
        }
        return (h, arena_c), metrics

    (x, new_arena), metrics = jax.lax.scan(
        body, (x, arena), (jnp.arange(n), stacked_params)
    )
    return x, new_arena, jax.tree.map(jnp.sum, metrics)
