"""Shared neural-net layers: norms, rotary embedding, MLPs, embeddings.

Pure-functional style: ``*_defs(cfg)`` returns a ParamDef tree, ``fn(params,
x, ...)`` applies it. Compute is bf16 with fp32 accumulation in norms,
softmax and the loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef

# ---------------------------------------------------------------- norms


def norm_defs(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamDef((d,), ("embed",), init="ones")}
    return {
        "scale": ParamDef((d,), ("embed",), init="ones"),
        "bias": ParamDef((d,), ("embed",), init="zeros"),
    }


def apply_norm(params, x: jax.Array, cfg: ModelConfig, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in params:  # LayerNorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # RMSNorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_1d(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Headwise RMSNorm (QK-norm): normalizes the trailing dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- rotary


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu":  # SwiGLU: gate + up + down
        return {
            "wi_gate": ParamDef((d, f), ("embed_fsdp", "ff")),
            "wi_up": ParamDef((d, f), ("embed_fsdp", "ff")),
            "wo": ParamDef((f, d), ("ff", "embed_fsdp")),
        }
    return {
        "wi": ParamDef((d, f), ("embed_fsdp", "ff")),
        "wo": ParamDef((f, d), ("ff", "embed_fsdp")),
    }


def apply_mlp(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "wi_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, params["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---------------------------------------------------------------- embeddings


def embedding_defs(cfg: ModelConfig):
    defs = {"table": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed_fsdp"), init="embed")}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed_fsdp", "vocab"))
    return defs


def embed_tokens(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x: jax.Array) -> jax.Array:
    """Returns fp32 logits (vocab sharded over 'model' via the head kernel)."""
    if "head" in params:
        return jnp.einsum("...d,dv->...v", x, params["head"]).astype(jnp.float32)
    return jnp.einsum("...d,vd->...v", x, params["table"]).astype(jnp.float32)
