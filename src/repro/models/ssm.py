"""Mamba-2 (SSD — state-space duality) block. [arXiv:2405.21060]

Train/prefill uses the *chunked dual form*: intra-chunk attention-like
matmuls (MXU work) + an inter-chunk state recurrence carried by ``lax.scan``
— O(T * Q) compute/memory instead of O(T^2). Decode is the O(1) recurrent
step: state (B, H, P, N) update + readout; this is what makes `long_500k`
runnable for the SSM/hybrid archs.

TPU adaptation (DESIGN.md §2): chunk length defaults to 256 so the
intra-chunk (Q x Q) decay matrices and (Q x N/P) GEMMs are 128-multiple MXU
tiles; the inter-chunk recurrence stays as a scan (ICI-free, per-device).
The per-chunk core is also available as a Pallas kernel
(`repro.kernels.ssd_scan`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef
from repro.sharding.specs import LogicalRules, shard_as


def ssm_defs(cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    h, p, n, grp = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    return {
        "in_z": ParamDef((d, di), ("embed_fsdp", "ssm_inner")),
        "in_x": ParamDef((d, di), ("embed_fsdp", "ssm_inner")),
        "in_B": ParamDef((d, grp, n), ("embed_fsdp", None, "ssm_state")),
        "in_C": ParamDef((d, grp, n), ("embed_fsdp", None, "ssm_state")),
        "in_dt": ParamDef((d, h), ("embed_fsdp", "ssm_heads")),
        "conv_x": ParamDef((cfg.conv_kernel, di), ("conv_k", "ssm_inner")),
        "conv_B": ParamDef((cfg.conv_kernel, grp, n), ("conv_k", None, "ssm_state")),
        "conv_C": ParamDef((cfg.conv_kernel, grp, n), ("conv_k", None, "ssm_state")),
        "A_log": ParamDef((h,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": ParamDef((h,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "gate_norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out": ParamDef((di, d), ("ssm_inner", "embed_fsdp")),
    }


def ssm_cache_shapes(cfg: ModelConfig, batch: int):
    """Decode-state shapes for ONE layer (stacked by the caller)."""
    return {
        "ssd": ((batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv_x": ((batch, cfg.conv_kernel - 1, cfg.d_inner), jnp.bfloat16),
        "conv_B": ((batch, cfg.conv_kernel - 1, cfg.ssm_groups, cfg.ssm_state), jnp.bfloat16),
        "conv_C": ((batch, cfg.conv_kernel - 1, cfg.ssm_groups, cfg.ssm_state), jnp.bfloat16),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. x: (B, T, C...), w: (K, C...)."""
    k = w.shape[0]
    orig = x.shape
    x2 = x.reshape(orig[0], orig[1], -1)
    w2 = w.reshape(k, -1)
    pad = jnp.zeros((orig[0], k - 1, x2.shape[-1]), x2.dtype)
    xp = jnp.concatenate([pad, x2], axis=1)
    out = sum(xp[:, i : i + orig[1]] * w2[i] for i in range(k))
    return out.reshape(orig)


def _project_inputs(params, u: jax.Array, cfg: ModelConfig):
    """u: (B, T, d) -> z, x, Bm, Cm, dt (pre-conv x/B/C; post-softplus dt)."""
    z = jnp.einsum("btd,de->bte", u, params["in_z"])
    x = jnp.einsum("btd,de->bte", u, params["in_x"])
    bm = jnp.einsum("btd,dgn->btgn", u, params["in_B"])
    cm = jnp.einsum("btd,dgn->btgn", u, params["in_C"])
    dt = jnp.einsum("btd,dh->bth", u, params["in_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])  # (B, T, H) fp32
    return z, x, bm, cm, dt


def _gated_out(params, y: jax.Array, z: jax.Array, cfg: ModelConfig, eps: float = 1e-5):
    """SiLU(z)-gated RMSNorm then output projection."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(ms + eps) * params["gate_norm"].astype(jnp.float32)
    return jnp.einsum("bte,ed->btd", yf.astype(y.dtype), params["out"])


def _final_state_only(x, bm, dt, a_log):
    """Closed-form final SSD state (B,H,P,N) without the output sweep."""
    h = x.shape[2]
    grp = bm.shape[2]
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * a
    cum = jnp.cumsum(dta, axis=1)  # (B,T,H)
    w_j = jnp.exp(cum[:, -1:, :] - cum) * dt.astype(jnp.float32)
    bh = jnp.repeat(bm, h // grp, axis=2).astype(jnp.float32)
    state = jnp.einsum("bthp,bthn->bhpn", x.astype(jnp.float32) * w_j[..., None], bh)
    return None, state


def ssd_chunked(x, bm, cm, dt, a_log, d_skip, chunk: int, init_state=None):
    """SSD dual form. x: (B,T,H,P); bm/cm: (B,T,G,N); dt: (B,T,H) fp32.

    Returns (y (B,T,H,P), final_state (B,H,P,N) fp32).
    """
    if init_state is None:
        from repro.kernels import ops as kops

        if kops._mode() == "kernel" and x.shape[1] % chunk == 0:
            # Pallas path (TPU): kernel returns y; recompute final state via
            # the cheap rank-Q closed form only when a cache is collected.
            y_k = kops.ssd(x, bm, cm, dt, a_log, d_skip, chunk=chunk)
            _, state_k = _final_state_only(x, bm, dt, a_log)
            return y_k, state_k
    b, t, h, p = x.shape
    grp = bm.shape[2]
    n = bm.shape[3]
    q = min(chunk, t)
    if t % q:
        q = t
    nc = t // q
    heads_per_group = h // grp

    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative
    dta = dt * a  # (B,T,H) log-decay per step
    xc = x.reshape(b, nc, q, h, p)
    bc = bm.reshape(b, nc, q, grp, n)
    cc = cm.reshape(b, nc, q, grp, n)
    dtc = dt.reshape(b, nc, q, h)
    dtac = dta.reshape(b, nc, q, h)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def chunk_body(state, inp):
        xq, bq, cq, dtq, dtaq = inp  # (B,Q,H,P), (B,Q,G,N), ..., (B,Q,H)
        cum = jnp.cumsum(dtaq, axis=1)  # (B,Q,H) log-decay prefix
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) * dt_j  for i >= j
        li = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Qi,Qj,H)
        iq = jnp.arange(q)
        causal = iq[:, None] >= iq[None, :]
        decay = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        lmat = decay * dtq[:, None, :, :]  # (B,Qi,Qj,H)
        scores = jnp.einsum("bigm,bjgm->bijg", cq.astype(jnp.float32), bq.astype(jnp.float32))
        scores = jnp.repeat(scores, heads_per_group, axis=3) * lmat  # (B,Qi,Qj,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xq.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        state_decay = jnp.exp(cum)  # (B,Q,H)
        cqh = jnp.repeat(cq, heads_per_group, axis=2)  # (B,Q,H,N)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", cqh.astype(jnp.float32), state) * state_decay[..., None]
        y = y_intra + y_inter
        # state update: S' = S * exp(sum dta) + sum_j exp(cum_Q - cum_j) dt_j B_j x_j
        total = cum[:, -1, :]  # (B,H)
        w_j = jnp.exp(total[:, None, :] - cum) * dtq  # (B,Q,H)
        bqh = jnp.repeat(bq, heads_per_group, axis=2)  # (B,Q,H,N)
        ds = jnp.einsum("bqhp,bqhn->bhpn", xq.astype(jnp.float32) * w_j[..., None], bqh.astype(jnp.float32))
        state = state * jnp.exp(total)[:, :, None, None] + ds
        return state, y.astype(xq.dtype)  # stack in model dtype (memory)

    inputs = tuple(jnp.moveaxis(v, 1, 0) for v in (xc, bc, cc, dtc, dtac))
    final_state, ys = jax.lax.scan(chunk_body, init_state, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, p)
    skip = (x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]).astype(x.dtype)
    return y + skip, final_state


def apply_ssm(params, u: jax.Array, cfg: ModelConfig, rules: LogicalRules | None = None, init_state=None, return_cache: bool = False):
    """Full-sequence Mamba-2 mixer. u: (B, T, d) -> (B, T, d).

    With ``return_cache`` also returns the decode-continuation state
    (matches :func:`ssm_cache_shapes`)."""
    b, t, _ = u.shape
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    z, x0, bm0, cm0, dt = _project_inputs(params, u, cfg)
    x = jax.nn.silu(_causal_conv(x0, params["conv_x"]).astype(jnp.float32)).astype(x0.dtype)
    bm = jax.nn.silu(_causal_conv(bm0, params["conv_B"]).astype(jnp.float32)).astype(bm0.dtype)
    cm = jax.nn.silu(_causal_conv(cm0, params["conv_C"]).astype(jnp.float32)).astype(cm0.dtype)
    x = shard_as(x, ("batch", "seq", "ssm_inner"), rules)
    xh = x.reshape(b, t, h, p)
    y, state = ssd_chunked(xh, bm, cm, dt, params["A_log"], params["D"], cfg.ssm_chunk, init_state)
    out = _gated_out(params, y.reshape(b, t, -1), z, cfg)
    if return_cache:
        km1 = cfg.conv_kernel - 1
        cache = {
            "ssd": state,
            "conv_x": x0[:, -km1:].astype(jnp.bfloat16),
            "conv_B": bm0[:, -km1:].astype(jnp.bfloat16),
            "conv_C": cm0[:, -km1:].astype(jnp.bfloat16),
        }
        return out, cache
    return out


def ssm_decode_step(params, u: jax.Array, cache: dict, cfg: ModelConfig):
    """One-token recurrent step. u: (B, 1, d); cache per ssm_cache_shapes.

    Returns (out (B, 1, d), new_cache).
    """
    b = u.shape[0]
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    grp = cfg.ssm_groups
    z, x, bm, cm, dt = _project_inputs(params, u, cfg)

    def conv_step(state, new, w):
        # state: (B, K-1, C...), new: (B, 1, C...), w: (K, C...)
        hist = jnp.concatenate([state, new], axis=1)  # (B, K, C...)
        k = w.shape[0]
        h2 = hist.reshape(b, k, -1)
        out = jnp.einsum("bkc,kc->bc", h2, w.reshape(k, -1))
        return out.reshape(new.shape[0], *new.shape[2:]), hist[:, 1:]

    x1, conv_x = conv_step(cache["conv_x"], x, params["conv_x"])
    b1, conv_b = conv_step(cache["conv_B"], bm, params["conv_B"])
    c1, conv_c = conv_step(cache["conv_C"], cm, params["conv_C"])
    x1 = jax.nn.silu(x1.astype(jnp.float32))  # (B, di)
    b1 = jax.nn.silu(b1.astype(jnp.float32))  # (B, G, N)
    c1 = jax.nn.silu(c1.astype(jnp.float32))

    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)
    dt1 = dt[:, 0]  # (B, H)
    da = jnp.exp(dt1 * a)  # (B, H)
    xh = x1.reshape(b, h, p)
    heads_per_group = h // grp
    bh = jnp.repeat(b1, heads_per_group, axis=1)  # (B, H, N)
    ch = jnp.repeat(c1, heads_per_group, axis=1)
    state = cache["ssd"] * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh * dt1[..., None], bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, ch) + xh * params["D"].astype(jnp.float32)[None, :, None]
    out = _gated_out(params, y.reshape(b, 1, -1).astype(u.dtype), z, cfg)
    new_cache = {"ssd": state, "conv_x": conv_x, "conv_B": conv_b, "conv_C": conv_c}
    return out, new_cache
