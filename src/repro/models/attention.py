"""GQA/MQA attention: train/prefill (chunked causal), decode (KV cache),
and cross-attention for enc-dec.

The full-sequence path is *query-chunked* (``lax.scan`` over query blocks) so
the lowered program never materializes a (T, S) score tensor — the jnp
analogue of the flash-attention memory profile. On TPU the Pallas kernels in
``repro.kernels`` replace this path (see kernels/ops.py dispatch); the
lowering structure (FLOPs/bytes) is equivalent for roofline purposes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rms_norm_1d
from repro.models.params import ParamDef

DEFAULT_Q_CHUNK = 512


def attn_defs(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("embed_fsdp", "heads", "head_dim")),
        "wk": ParamDef((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed_fsdp")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
        defs["k_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
    return defs


def qkv_project(params, x: jax.Array, cfg: ModelConfig, positions: jax.Array | None):
    """x: (B, T, d) -> q (B,T,H,hd), k/v (B,T,KV,hd); applies QK-norm + RoPE."""
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if "q_norm" in params:
        q = rms_norm_1d(params["q_norm"], q)
        k = rms_norm_1d(params["k_norm"], k)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Tq,KV,G,hd), k: (B,S,KV,hd) -> (B,KV,G,Tq,S) fp32."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("btkgh,bskh->bkgts", q, k, preferred_element_type=jnp.float32)
    return s * scale


def _group_q(q: jax.Array, num_kv: int) -> jax.Array:
    b, t, h, hd = q.shape
    return q.reshape(b, t, num_kv, h // num_kv, hd)


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_chunk: int = DEFAULT_Q_CHUNK,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """Query-chunked attention. q: (B,T,H,hd); k,v: (B,S,KV,hd) -> (B,T,H,hd).

    ``q_offset``: absolute position of q[0] (for prefill-continuation /
    chunked-prefill the query block may start past 0).
    """
    from repro.kernels import ops as kops

    if kops._mode() == "kernel" and isinstance(q_offset, int) and q_offset == 0:
        if q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
            return kops.attention(q, k, v, causal=causal)  # Pallas on TPU
    b, t, h, hd = q.shape
    num_kv = k.shape[2]
    qg = _group_q(q, num_kv)
    s_len = k.shape[1]
    chunk = min(q_chunk, t)
    if t % chunk != 0:  # fall back to one block for odd lengths (tests)
        chunk = t
    n_chunks = t // chunk
    qg = qg.reshape(b, n_chunks, chunk, num_kv, h // num_kv, hd)
    k_idx = jnp.arange(s_len)

    def body(carry, inp):
        q_blk, blk_i = inp  # (B, chunk, KV, G, hd)
        scores = _gqa_scores(q_blk, k)  # (B,KV,G,chunk,S) fp32
        if causal:
            q_idx = blk_i * chunk + jnp.arange(chunk) + q_offset
            mask = k_idx[None, :] <= q_idx[:, None]
            scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v)
        return carry, out

    _, outs = jax.lax.scan(body, None, (jnp.moveaxis(qg, 1, 0), jnp.arange(n_chunks)))
    # outs: (n_chunks, B, chunk, KV, G, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, hd)
    return out


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
) -> jax.Array:
    """One-token attention over a (possibly sequence-sharded) KV cache.

    q: (B,1,H,hd); caches: (B,S,KV,hd); cur_len: (B,) valid lengths
    (positions < cur_len attend). GSPMD turns the softmax reduction over a
    'model'-sharded S into the flash-decoding partial-softmax all-reduce.
    """
    from repro.kernels import ops as kops

    if k_cache.dtype != q.dtype:
        # quantized (e.g. fp8) KV cache: HBM reads happen at the narrow
        # dtype; the upconvert fuses into the attention kernel on TPU
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
    if kops._mode() == "kernel" and k_cache.shape[1] % 512 == 0:
        return kops.decode_attention(q[:, 0], k_cache, v_cache, cur_len)[:, None]
    b, _, h, hd = q.shape
    num_kv = k_cache.shape[2]
    qg = _group_q(q, num_kv)  # (B,1,KV,G,hd)
    scores = _gqa_scores(qg, k_cache)  # (B,KV,G,1,S) fp32
    s_idx = jnp.arange(k_cache.shape[1])
    mask = s_idx[None, :] < cur_len[:, None]  # (B,S)
    scores = jnp.where(mask[:, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)


def paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    cur_len: jax.Array,
) -> jax.Array:
    """One-token attention over a paged KV arena.

    q: (B,1,H,hd); pages: (P, page, KV, hd); block_table: (B, n) int32 rows
    of physical page ids (padded entries point at the arena's scratch page);
    cur_len: (B,) valid lengths. On TPU the block-table-indirect split-K
    kernel reads pages directly; elsewhere ONE advanced-indexing gather
    rebuilds the contiguous (B, n*page, KV, hd) view and the dense
    ``decode_attention`` runs on it — when ``n*page`` equals the dense
    path's ``max_len`` the two are the same program on the same values
    (masked positions contribute exactly zero), so paging is bit-exact."""
    from repro.kernels import ops as kops
    from repro.kernels.paged_attention import gather_pages

    if kops._mode() == "kernel" and k_pages.shape[1] % 128 == 0:
        return kops.paged_decode_attention(q[:, 0], k_pages, v_pages, block_table, cur_len)[:, None]
    k_cache = gather_pages(k_pages, block_table)
    v_cache = gather_pages(v_pages, block_table)
    return decode_attention(q, k_cache, v_cache, cur_len)


def paged_chunk_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    start: jax.Array,
) -> jax.Array:
    """Causal attention for one chunked-prefill block over a paged arena.

    q: (1, C, H, hd) — C chunk rows whose absolute positions begin at
    ``start`` (shape (1,) int32); pages: (P, page, KV, hd); block_table:
    (1, n). Each chunk row attends to every position <= its own absolute
    position, exactly like the matching rows of a dense causal prefill. On
    TPU the block-table-indirect chunk kernel reads pages directly;
    elsewhere one gather rebuilds the contiguous view and the q-chunked
    ``full_attention`` runs with ``q_offset=start`` — masked positions
    contribute exactly zero, so chunked prefill is bit-exact vs dense."""
    from repro.kernels import ops as kops

    out = kops.paged_chunk_attention(q, k_pages, v_pages, block_table, start)
    if out is not None:
        return out
    k_cache = gather_pages_cast(k_pages, block_table, q.dtype)
    v_cache = gather_pages_cast(v_pages, block_table, q.dtype)
    return full_attention(q, k_cache, v_cache, causal=True, q_offset=start[0])


def gather_pages_cast(pages: jax.Array, block_table: jax.Array, dtype) -> jax.Array:
    from repro.kernels.paged_attention import gather_pages

    out = gather_pages(pages, block_table)
    return out.astype(dtype) if out.dtype != dtype else out


def attn_output(params, attn: jax.Array) -> jax.Array:
    return jnp.einsum("bthk,hkd->btd", attn, params["wo"])


def update_kv_cache(
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    positions: jax.Array,
):
    """Scatter new K/V rows (B, T_new, KV, hd) into caches at ``positions``
    (B, T_new) — per-example positions support continuous batching."""
    b = k_cache.shape[0]
    batch_idx = jnp.broadcast_to(jnp.arange(b)[:, None], positions.shape)
    k_cache = k_cache.at[batch_idx, positions].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[batch_idx, positions].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache


def update_paged_kv(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    block_table: jax.Array,
    cur_len: jax.Array,
):
    """Scatter one new K/V token (B, 1, KV, hd) into the page arena at each
    sequence's write position: physical page ``bt[b, cur//page]``, row
    ``cur % page``. Masked slots carry an all-scratch block-table row with
    ``cur_len == 0``, so their write lands in the reserved scratch page."""
    page = k_pages.shape[1]
    b = block_table.shape[0]
    logical = cur_len // page
    phys = block_table[jnp.arange(b), logical]  # (B,) physical page ids
    slot = cur_len % page
    k_pages = k_pages.at[phys, slot].set(k_new[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[phys, slot].set(v_new[:, 0].astype(v_pages.dtype))
    return k_pages, v_pages


def update_paged_kv_chunk(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    block_table: jax.Array,
    start: jax.Array,
    valid: jax.Array,
):
    """Scatter one prefill chunk's K/V rows (1, C, KV, hd) into the page
    arena: chunk row i lands at logical position ``start + i`` -> physical
    page ``bt[0, (start+i)//page]``, slot ``(start+i) % page``. Rows at
    ``i >= valid`` are padding (the chunk is padded to a power of two for
    compile-cache reuse): their writes are routed to the reserved scratch
    page, same contract as a masked decode slot."""
    page = k_pages.shape[1]
    c = k_new.shape[1]
    idx = jnp.arange(c)
    pos = start[0] + idx
    logical = jnp.clip(pos // page, 0, block_table.shape[1] - 1)
    phys = jnp.where(idx < valid[0], block_table[0, logical], 0)  # (C,)
    slot = pos % page
    k_pages = k_pages.at[phys, slot].set(k_new[0].astype(k_pages.dtype))
    v_pages = v_pages.at[phys, slot].set(v_new[0].astype(v_pages.dtype))
    return k_pages, v_pages
