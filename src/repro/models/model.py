"""build_model(cfg, rules) — uniform Model API over all 10 arch families.

A Model exposes three *programs* (pure functions of pytrees — exactly what
the Provuse platform deploys as FaaS functions and what the dry-run lowers):

  loss_fn(params, batch)            -> (loss, metrics)          [train]
  prefill_fn(params, batch)         -> (last_logits, cache)     [serve]
  decode_fn(params, batch, cache)   -> (logits, new_cache)      [serve]

plus symbolic builders (``param_defs`` / ``cache_defs`` / ``input_defs``) so
dry-runs construct sharded ShapeDtypeStructs without allocating anything.

Modality frontends (audio frames / VQ image patches) are STUBS per the
assignment: ``input_defs`` provides precomputed embeddings for those archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ed
from repro.models import hybrid as hy
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import embed_tokens, embedding_defs, norm_defs, apply_norm, unembed
from repro.models.params import ParamDef, init_params
from repro.sharding.specs import LogicalRules, shard_as

ENCDEC_TGT_CACHE = 4096  # decoder self-cache length for enc-dec decode cells
CE_CHUNK = 512


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    param_defs: Any
    init: Callable[[jax.Array], Any]
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    cache_defs: Callable[[ShapeConfig], Any]
    input_defs: Callable[[ShapeConfig], Any]
    make_inputs: Callable[[ShapeConfig, jax.Array], Any]


# ------------------------------------------------------------------ loss


def chunked_ce(emb_params, hidden: jax.Array, targets: jax.Array, cfg: ModelConfig, rules, chunk: int = CE_CHUNK):
    """Cross-entropy via scan over sequence chunks: the (B, chunk, V) logits
    buffer replaces the (B, T, V) one — the full-vocab logits tensor for
    train_4k would otherwise be the largest buffer in the program."""
    b, t, _ = hidden.shape
    c = min(chunk, t)
    if t % c:
        c = t
    nc = t // c
    hc = jnp.moveaxis(hidden.reshape(b, nc, c, -1), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)

    def body(tot, inp):
        h, y = inp
        logits = unembed(emb_params, h)  # (B, c, V) fp32
        logits = shard_as(logits, ("batch", None, "vocab_out"), rules)
        lz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lz - ll), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    tot, _ = jax.lax.scan(body_fn, jnp.zeros((), jnp.float32), (hc, tc))
    return tot / (b * t)


# ------------------------------------------------------------------ builder


def build_model(cfg: ModelConfig, rules: LogicalRules | None = None, *, layout: str = "stacked") -> Model:
    """layout='stacked' (default): layer params stacked on a leading axis,
    applied with lax.scan — O(1) HLO, the right shape for training (remat,
    FSDP gathers amortize).

    layout='perlayer': every layer is a separate pytree subtree and the
    forward is a python loop — the right shape for SERVING programs: no
    stacked-xs double-buffering and no param/cache slice copies (measured
    ~0.36 GB/layer of dead temp on the 34B decode cells otherwise), and each
    layer's cache leaf aliases its donated input in place. Only affects the
    blocks families (dense/moe/vlm/ssm); hybrid/enc-dec keep their layouts.
    """
    fam = cfg.family
    L = cfg.num_layers
    blk_kind = "moe" if fam == "moe" else ("ssm" if fam == "ssm" else "dense")
    perlayer = layout == "perlayer" and fam in ("dense", "moe", "vlm", "ssm")

    # ---------------- param defs ----------------
    defs: dict = {"embed": embedding_defs(cfg), "ln_f": norm_defs(cfg)}
    if fam in ("dense", "moe", "vlm", "ssm"):
        if perlayer:
            defs["blocks"] = {f"l{i:03d}": tfm.block_defs(cfg, blk_kind) for i in range(L)}
        else:
            defs["blocks"] = tfm.stack_block_defs(cfg, blk_kind, L)
    elif fam == "hybrid":
        defs["hybrid"] = hy.hybrid_defs(cfg)
    elif fam == "audio":
        defs["encdec"] = ed.encdec_defs(cfg)
    else:
        raise ValueError(f"unknown family {fam}")

    # ---------------- forward helpers ----------------
    def _in_embeds(params, batch):
        if "embeds" in batch:
            return batch["embeds"]
        return embed_tokens(params["embed"], batch["tokens"])

    def _hidden_full(params, x, collect_cache: bool):
        positions = jnp.arange(x.shape[1])[None, :]
        if fam in ("dense", "moe", "vlm", "ssm"):
            if perlayer:
                h = x
                cache = {} if collect_cache else None
                metrics = None
                for key in sorted(params["blocks"]):
                    h, entry, m = tfm.apply_block_full(
                        params["blocks"][key], h, cfg, blk_kind, rules, positions,
                        causal=True, collect_cache=collect_cache,
                    )
                    metrics = m if metrics is None else jax.tree.map(jnp.add, metrics, m)
                    if collect_cache:
                        cache[key] = {"k": entry[0], "v": entry[1]} if isinstance(entry, tuple) else entry
            else:
                h, cache, metrics = tfm.apply_stack_full(
                    params["blocks"], x, cfg, blk_kind, rules, positions,
                    causal=True, collect_cache=collect_cache,
                )
        elif fam == "hybrid":
            h, cache, metrics = hy.apply_hybrid_full(
                params["hybrid"], x, cfg, rules, positions, collect_cache=collect_cache
            )
        else:
            raise AssertionError(fam)
        return apply_norm(params["ln_f"], h, cfg), cache, metrics

    # ---------------- train ----------------
    def loss_fn(params, batch):
        if fam == "audio":
            enc, m1 = ed.encode(params["encdec"], batch["src_embeds"], cfg, rules)
            tgt = embed_tokens(params["embed"], batch["tgt_tokens"])
            h, m2 = ed.decode_train(params["encdec"], tgt, enc, cfg, rules)
            metrics = jax.tree.map(jnp.add, m1, m2)
            h = apply_norm(params["ln_f"], h, cfg)
        else:
            x = _in_embeds(params, batch)
            h, _, metrics = _hidden_full(params, x, collect_cache=False)
        ce = chunked_ce(params["embed"], h, batch["targets"], cfg, rules)
        loss = ce + cfg.router_aux_weight * metrics["moe_aux"]
        out = dict(metrics)
        out.update(ce=ce, loss=loss)
        return loss, out

    # ---------------- serve: prefill ----------------
    def prefill_fn(params, batch):
        if fam == "audio":
            enc, _ = ed.encode(params["encdec"], batch["src_embeds"], cfg, rules)
            cross = ed.cross_kv_from_enc(params["encdec"], enc)
            b = enc.shape[0]
            kvh, hd = cfg.num_kv_heads, cfg.head_dim
            self_cache = {
                "k": jnp.zeros((cfg.num_decoder_layers, b, ENCDEC_TGT_CACHE, kvh, hd), jnp.bfloat16),
                "v": jnp.zeros((cfg.num_decoder_layers, b, ENCDEC_TGT_CACHE, kvh, hd), jnp.bfloat16),
            }
            x = embed_tokens(params["embed"], batch["tokens"])  # BOS (B, 1)
            cur = jnp.zeros((b,), jnp.int32)
            src_len = jnp.full((b,), enc.shape[1], jnp.int32)
            h, new_self, _ = ed.decoder_step(params["encdec"], x, self_cache, cross, cfg, rules, cur, src_len)
            h = apply_norm(params["ln_f"], h, cfg)
            logits = unembed(params["embed"], h)[:, 0]
            return logits, {"self": new_self, "cross": cross}
        x = _in_embeds(params, batch)
        h, cache, _ = _hidden_full(params, x, collect_cache=True)
        logits = unembed(params["embed"], h[:, -1:])[:, 0]  # last position only
        logits = shard_as(logits, ("batch", "vocab_out"), rules)
        return logits, cache

    # ---------------- serve: decode ----------------
    def decode_fn(params, batch, cache):
        cur_len = batch["cur_len"]
        x = embed_tokens(params["embed"], batch["tokens"])  # (B, 1, d)
        if fam in ("dense", "moe", "vlm", "ssm"):
            if perlayer:
                h = x
                new_cache = {}
                for key in sorted(params["blocks"]):
                    h, nc, _ = tfm.apply_block_decode(
                        params["blocks"][key], h, cache[key], cfg, blk_kind, rules, cur_len
                    )
                    new_cache[key] = nc
            else:
                h, new_cache, _ = tfm.apply_stack_decode(
                    params["blocks"], x, cache, cfg, blk_kind, rules, cur_len
                )
        elif fam == "hybrid":
            h, new_cache, _ = hy.apply_hybrid_decode(params["hybrid"], x, cache, cfg, rules, cur_len)
        elif fam == "audio":
            b = x.shape[0]
            src_len = jnp.full((b,), cache["cross"]["k"].shape[2], jnp.int32)
            h, new_self, _ = ed.decoder_step(
                params["encdec"], x, cache["self"], cache["cross"], cfg, rules, cur_len, src_len
            )
            new_cache = {"self": new_self, "cross": cache["cross"]}
        else:
            raise AssertionError(fam)
        h = apply_norm(params["ln_f"], h, cfg)
        logits = unembed(params["embed"], h)[:, 0]
        logits = shard_as(logits, ("batch", "vocab_out"), rules)
        return logits, new_cache

    # ---------------- symbolic cache / input defs ----------------
    def _attn_cache_defs(n_apps: int | None, batch: int, seq: int, lead: str = "layers"):
        """n_apps=None -> single-layer (perlayer layout) defs."""
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        cache_dt = jnp.dtype(cfg.kv_cache_dtype)
        if n_apps is None:
            sh: tuple = (batch, seq, kvh, hd)
            lg: tuple = ("batch", "cache_seq", "cache_kv_heads", "head_dim")
        else:
            sh = (n_apps, batch, seq, kvh, hd)
            lg = (lead, "batch", "cache_seq", "cache_kv_heads", "head_dim")
        return {
            "k": ParamDef(sh, lg, init="zeros", dtype=cache_dt),
            "v": ParamDef(sh, lg, init="zeros", dtype=cache_dt),
        }

    def _ssm_cache_defs(stack_dims: tuple[int, ...], stack_logical: tuple[str, ...], batch: int):
        shapes = ssm_mod.ssm_cache_shapes(cfg, batch)
        logical = {
            "ssd": ("batch", "ssm_heads", None, None),
            "conv_x": ("batch", "conv_k", "ssm_inner"),
            "conv_B": ("batch", "conv_k", None, "ssm_state"),
            "conv_C": ("batch", "conv_k", None, "ssm_state"),
        }
        return {
            name: ParamDef(stack_dims + sh, stack_logical + logical[name], init="zeros", dtype=dt)
            for name, (sh, dt) in shapes.items()
        }

    def cache_defs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if fam in ("dense", "moe", "vlm"):
            if perlayer:
                return {f"l{i:03d}": _attn_cache_defs(None, b, s) for i in range(L)}
            return _attn_cache_defs(L, b, s)
        if fam == "ssm":
            if perlayer:
                return {f"l{i:03d}": _ssm_cache_defs((), (), b) for i in range(L)}
            return _ssm_cache_defs((L,), ("layers",), b)
        if fam == "hybrid":
            n_groups, every, tail = hy.split_layers(cfg)
            out = {
                "groups": _ssm_cache_defs((n_groups, every), ("groups", "inner"), b),
                "attn": _attn_cache_defs(n_groups, b, s, lead="groups"),
            }
            if tail:
                out["tail"] = _ssm_cache_defs((tail,), ("inner",), b)
            return out
        if fam == "audio":
            return {
                "self": _attn_cache_defs(cfg.num_decoder_layers, b, min(ENCDEC_TGT_CACHE, s)),
                "cross": _attn_cache_defs(cfg.num_decoder_layers, b, s),
            }
        raise AssertionError(fam)

    def input_defs(shape: ShapeConfig):
        b, s, kind = shape.global_batch, shape.seq_len, shape.kind
        tok = lambda t: ParamDef((b, t), ("batch", "seq"), init="zeros", dtype=jnp.int32)
        emb = lambda t: ParamDef((b, t, cfg.d_model), ("batch", "seq", None), init="normal", dtype=jnp.bfloat16)
        if kind == "train":
            if fam == "audio":
                return {"src_embeds": emb(s), "tgt_tokens": tok(s), "targets": tok(s)}
            if fam == "vlm":
                return {"embeds": emb(s), "targets": tok(s)}
            return {"tokens": tok(s), "targets": tok(s)}
        if kind == "prefill":
            if fam == "audio":
                return {"src_embeds": emb(s), "tokens": ParamDef((b, 1), ("batch", None), init="zeros", dtype=jnp.int32)}
            if fam == "vlm":
                return {"embeds": emb(s)}
            return {"tokens": tok(s)}
        # decode: one new token against a cache of length s
        return {
            "tokens": ParamDef((b, 1), ("batch", None), init="zeros", dtype=jnp.int32),
            "cur_len": ParamDef((b,), ("batch",), init="zeros", dtype=jnp.int32),
        }

    def make_inputs(shape: ShapeConfig, rng: jax.Array):
        defs_in = input_defs(shape)
        keys = jax.random.split(rng, 8)
        out = {}
        for i, (name, d) in enumerate(sorted(defs_in.items())):
            if d.dtype == jnp.int32:
                if name == "cur_len":
                    out[name] = jnp.full(d.shape, max(0, shape.seq_len - 2), jnp.int32)
                else:
                    hi = max(2, cfg.vocab_size or 2)
                    out[name] = jax.random.randint(keys[i], d.shape, 0, hi, jnp.int32)
            else:
                out[name] = (jax.random.normal(keys[i], d.shape, jnp.float32) * 0.02).astype(d.dtype)
        return out

    return Model(
        cfg=cfg,
        param_defs=defs,
        init=lambda rng: init_params(defs, rng),
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        cache_defs=cache_defs,
        input_defs=input_defs,
        make_inputs=make_inputs,
    )
