"""Top-k token-choice Mixture-of-Experts with expert parallelism.

Why not the GShard dispatch einsum: its (S, E, C) one-hot contraction costs
``N*S*k*cf*d`` FLOPs — for qwen3-moe (E=128, top-8) that is ~5x the *useful*
expert FLOPs, wrecking the MODEL_FLOPS/HLO_FLOPs roofline ratio. Instead we
use the Switch-Transformer capacity formulation with real gather/scatter:

  1. route: router logits -> top-k experts + normalized weights per token
  2. position: cumulative count per expert (capacity C, overflow dropped)
  3. dispatch: scatter token vectors into a (G, E, C, d) buffer
  4. compute: dense per-expert GEMMs (MXU-friendly; E sharded over 'model'
     = expert parallelism; weight d dim FSDP over 'data')
  5. combine: gather each token's k expert outputs, weighted sum

Tokens are processed in G groups aligned with the data-parallel sharding so
the scatter/gather stays group-local: per group XLA emits one all-gather of
the group's tokens over 'model' (the SP axis) and one reduce-scatter back —
the classic a2a-free EP schedule.

Differentiable end-to-end (indices are stop-gradient; weights flow through
softmax/top-k values). Load-balance aux loss per Switch [arXiv:2101.03961].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef
from repro.sharding.specs import LogicalRules, shard_as


def moe_defs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    return {
        "router": ParamDef((d, e), ("embed_fsdp", None), dtype=jnp.float32),
        "wi_gate": ParamDef((e, d, f), ("experts", "embed_fsdp", "expert_ff")),
        "wi_up": ParamDef((e, d, f), ("experts", "embed_fsdp", "expert_ff")),
        "wo": ParamDef((e, f, d), ("experts", "expert_ff", "embed_fsdp")),
    }


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.num_experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(8, _round_up(c, 8))


def num_groups(n_tokens: int, batch: int, cfg: ModelConfig, rules: LogicalRules | None) -> int:
    """Groups = data-parallel shard count when per-group token counts stay
    healthy (>= ~4 slots/expert); halved otherwise (tiny decode batches)."""
    target = cfg.moe_min_group_tokens or 4 * cfg.num_experts
    if rules is None:
        g = 1
    else:
        g = rules.mesh_axis_sizes.get("pod", 1) * rules.mesh_axis_sizes.get("data", 1)
    while g > 1 and ((n_tokens // g) < target or n_tokens % g or (g > batch and g % batch)):
        g //= 2
    return max(1, g)


def apply_moe(params, x: jax.Array, cfg: ModelConfig, rules: LogicalRules | None = None):
    """x: (B, T, d) -> (y (B, T, d), metrics dict)."""
    b, t, d = x.shape
    n = b * t
    k, e = cfg.num_experts_per_tok, cfg.num_experts
    g = num_groups(n, b, cfg, rules)
    nl = n // g
    cap = capacity(nl, cfg)

    xg = x.reshape(g, nl, d)

    # --- route on the UN-reshaped (B, T, d) stream. The (g, nl, d) reshape
    # merges batch x seq and is not expressible as a block sharding, so any
    # fp32 routing math placed after it forces a full-token fp32 all-gather
    # over 'model' (measured 2 GB/op x 576 ops on qwen3 — EXPERIMENTS §Perf).
    # Routing stays SP-sharded here; only the tiny (.., k) top-k outputs get
    # reshaped into groups. ---
    x_sp = shard_as(x, ("batch", "seq", None), rules)
    logits = jnp.einsum("btd,de->bte", x_sp.astype(jnp.float32), params["router"])
    logits = shard_as(logits, ("batch", "seq", None), rules)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (B, T, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    idx = jax.lax.stop_gradient(idx)
    w = w.reshape(g, nl, k)
    idx = idx.reshape(g, nl, k)

    # --- slot positions, sort-based: pos[i] = #{j <= i : e[j] == e[i]}.
    # O(N) int32 buffers (a (tokens, E) one-hot cumsum would be 4 TB here).
    e_flat = idx.reshape(g, nl * k)
    order = jnp.argsort(e_flat, axis=1, stable=True)  # (g, nl*k)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    pos_in_row = jnp.broadcast_to(jnp.arange(nl * k, dtype=jnp.int32), sorted_e.shape)
    is_start = jnp.concatenate(
        [jnp.ones((g, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1
    )
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos_in_row, 0), axis=1
    )
    pos_sorted = pos_in_row - run_start
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], e_flat.shape)
    pos_flat = jnp.zeros_like(e_flat).at[g_idx, order].set(pos_sorted)
    pos_flat = jax.lax.stop_gradient(pos_flat)
    kept = pos_flat < cap
    pos_flat = jnp.where(kept, pos_flat, cap)  # cap == out-of-bounds -> dropped

    # --- dispatch: scatter tokens into (G, E, C, d) expert buffers ---
    # The group index participates in the scatter, so under plain GSPMD the
    # scattered dim-0 forces an operand ALL-GATHER (measured: ~130 GB/device
    # at qwen3 scale). shard_map over the dp axes makes the scatter
    # group-LOCAL by construction; the E-dim (expert-parallel) reshard
    # happens after, as a plain slice.
    def _dispatch_local(xg_l, e_l, pos_l):
        g_loc = xg_l.shape[0]
        xr_l = jnp.repeat(xg_l, k, axis=1)
        gi = jnp.broadcast_to(jnp.arange(g_loc)[:, None], e_l.shape)
        return jnp.zeros((g_loc, e, cap, d), xg_l.dtype).at[gi, e_l, pos_l].set(xr_l, mode="drop")

    def _combine_local(ye_l, e_l, pos_l, w_l):
        g_loc = e_l.shape[0]
        gi = jnp.broadcast_to(jnp.arange(g_loc)[:, None], e_l.shape)
        yk_l = ye_l.at[gi, e_l, pos_l].get(mode="fill", fill_value=0)  # (g_loc, nl*k, d)
        nl_l = e_l.shape[1] // k
        return jnp.sum(yk_l.reshape(g_loc, nl_l, k, d) * w_l.reshape(g_loc, nl_l, k, 1).astype(ye_l.dtype), axis=2)

    dp = rules.dp_axes() if rules is not None else ()
    dp_size = 1
    for ax in dp:
        dp_size *= rules.mesh_axis_sizes.get(ax, 1)
    use_sm = bool(dp) and rules is not None and rules.mesh is not None and g % dp_size == 0
    msize = rules.mesh_axis_sizes.get("model", 1) if rules is not None else 1
    use_ep_local = (
        cfg.moe_impl == "dropping_ep"
        and use_sm
        and msize > 1
        and e % msize == 0
    )
    if use_ep_local:
        # ---- beyond-baseline EP schedule (see EXPERIMENTS.md §Perf):
        # dispatch + combine run INSIDE shard_map over (dp, model); each
        # model shard builds/serves only ITS E/msize experts' buffers, and
        # the combine reduces partial token outputs with psum_scatter —
        # per-layer collective traffic drops from O(E*cap*d) all-gathers to
        # one token all-gather + one token reduce-scatter.
        from jax.sharding import PartitionSpec as P

        dp_spec = dp if len(dp) > 1 else dp[0]
        e_loc = e // msize
        manual = set(dp) | {"model"}
        xg_c = shard_as(xg, ("batch", None, None), rules)
        e_c = shard_as(e_flat, ("batch", None), rules)
        pos_c = shard_as(pos_flat, ("batch", None), rules)
        w_c = shard_as(w, ("batch", None, None), rules)

        def _rel(e_l, pos_l):
            e0 = jax.lax.axis_index("model") * e_loc
            rel = e_l - e0
            ok = (rel >= 0) & (rel < e_loc)
            return jnp.where(ok, rel, e_loc), jnp.where(ok, pos_l, cap)

        def disp_local(xg_l, e_l, pos_l):
            g_loc = xg_l.shape[0]
            rel, pos2 = _rel(e_l, pos_l)
            xr_l = jnp.repeat(xg_l, k, axis=1)
            gi = jnp.broadcast_to(jnp.arange(g_loc)[:, None], e_l.shape)
            return jnp.zeros((g_loc, e_loc, cap, d), xg_l.dtype).at[gi, rel, pos2].set(xr_l, mode="drop")

        xe = jax.shard_map(
            disp_local,
            mesh=rules.mesh,
            in_specs=(P(dp_spec), P(dp_spec), P(dp_spec)),
            out_specs=P(dp_spec, "model"),
            axis_names=manual,
            check_vma=False,
        )(xg_c, e_c, pos_c)
        xe = shard_as(xe, ("batch", "experts", None, None), rules)

        gate = jnp.einsum("gecd,edf->gecf", xe, params["wi_gate"])
        up = jnp.einsum("gecd,edf->gecf", xe, params["wi_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
        ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])
        ye = shard_as(ye, ("batch", "experts", None, None), rules)

        scatter_tiled = nl % msize == 0

        def comb_local(ye_l, e_l, pos_l, w_l):
            g_loc = e_l.shape[0]
            rel, pos2 = _rel(e_l, pos_l)
            gi = jnp.broadcast_to(jnp.arange(g_loc)[:, None], e_l.shape)
            yk_l = ye_l.at[gi, rel, pos2].get(mode="fill", fill_value=0)
            y_part = jnp.sum(
                yk_l.reshape(g_loc, nl, k, d) * w_l.reshape(g_loc, nl, k, 1).astype(ye_l.dtype), axis=2
            )
            if scatter_tiled:
                return jax.lax.psum_scatter(y_part, "model", scatter_dimension=1, tiled=True)
            return jax.lax.psum(y_part, "model")

        y = jax.shard_map(
            comb_local,
            mesh=rules.mesh,
            in_specs=(P(dp_spec, "model"), P(dp_spec), P(dp_spec), P(dp_spec)),
            out_specs=P(dp_spec, "model" if scatter_tiled else None),
            axis_names=manual,
            check_vma=False,
        )(ye, e_c, pos_c, w_c)
        y = shard_as(y, ("batch", "seq", None), rules)
        y = y.reshape(b, t, d)
        gia = jnp.broadcast_to(jnp.arange(g)[:, None], e_flat.shape)
        counts = jnp.zeros((g, e), jnp.float32).at[gia, e_flat].add(1.0)
        f_e = jnp.sum(counts, axis=0) / (g * nl)
        p_e = jnp.mean(probs, axis=(0, 1))
        aux = e * jnp.sum(f_e / k * p_e)
        dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
        return y, {"moe_aux": aux, "moe_dropped": dropped}
    if use_sm:
        from jax.sharding import PartitionSpec as P

        dp_spec = dp if len(dp) > 1 else dp[0]
        xg_d = shard_as(xg, ("batch", None, None), rules)
        e_d = shard_as(e_flat, ("batch", None), rules)
        pos_d = shard_as(pos_flat, ("batch", None), rules)
        xe = jax.shard_map(
            _dispatch_local,
            mesh=rules.mesh,
            in_specs=(P(dp_spec), P(dp_spec), P(dp_spec)),
            out_specs=P(dp_spec),
            axis_names=set(dp),
            check_vma=False,
        )(xg_d, e_d, pos_d)
    else:
        gi0 = jnp.broadcast_to(jnp.arange(g)[:, None], e_flat.shape)
        xr = jnp.repeat(xg, k, axis=1)
        xe = jnp.zeros((g, e, cap, d), x.dtype).at[gi0, e_flat, pos_flat].set(xr, mode="drop")
    xe = shard_as(xe, ("batch", "experts", None, None), rules)

    # --- expert compute (dense GEMMs; E is the EP axis) ---
    from repro.kernels import ops as kops

    if kops._mode() == "kernel" and g == 1 and cap % 128 == 0 and d % 128 == 0 and cfg.moe_d_ff % 128 == 0:
        gate = kops.gmm(xe[0], params["wi_gate"])[None]
        up = kops.gmm(xe[0], params["wi_up"])[None]
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
        ye = kops.gmm(h[0], params["wo"])[None]  # wo: (E, f, d)
    else:
        gate = jnp.einsum("gecd,edf->gecf", xe, params["wi_gate"])
        up = jnp.einsum("gecd,edf->gecf", xe, params["wi_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
        ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    ye = shard_as(ye, ("batch", "experts", None, None), rules)

    # --- combine: one explicit all-gather of ye over 'model' (E-dim), then a
    # group-local gather + weighted sum — mirrors the dispatch ---
    ye = shard_as(ye, ("batch", None, None, None), rules)
    if use_sm:
        from jax.sharding import PartitionSpec as P

        dp_spec = dp if len(dp) > 1 else dp[0]
        w_d = shard_as(w, ("batch", None, None), rules)
        y = jax.shard_map(
            _combine_local,
            mesh=rules.mesh,
            in_specs=(P(dp_spec), P(dp_spec), P(dp_spec), P(dp_spec)),
            out_specs=P(dp_spec),
            axis_names=set(dp),
            check_vma=False,
        )(ye, e_d, pos_d, w_d)
    else:
        gi1 = jnp.broadcast_to(jnp.arange(g)[:, None], e_flat.shape)
        yk = ye.at[gi1, e_flat, pos_flat].get(mode="fill", fill_value=0)  # (g, nl*k, d)
        y = jnp.sum(yk.reshape(g, nl, k, d) * w.reshape(g, nl, k, 1).astype(ye.dtype), axis=2)
    y = shard_as(y, ("batch", None, None), rules)
    y = y.reshape(b, t, d)

    # --- Switch load-balance aux: E * sum_e f_e * P_e (counts via
    # scatter-add; no (tokens, E) one-hot materialized) ---
    gia = jnp.broadcast_to(jnp.arange(g)[:, None], e_flat.shape)
    counts = jnp.zeros((g, e), jnp.float32).at[gia, e_flat].add(1.0)
    f_e = jnp.sum(counts, axis=0) / (g * nl)
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e / k * p_e)
    dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
    metrics = {"moe_aux": aux, "moe_dropped": dropped}
    return y, metrics
