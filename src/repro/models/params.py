"""Parameter definition trees.

A model is described by a pytree of :class:`ParamDef` leaves. From one defs
tree we derive three things:

* ``init_params``   — materialized arrays (smoke tests, real training)
* ``param_structs`` — ``jax.ShapeDtypeStruct`` with ``NamedSharding`` attached
                      (dry-run lowering: zero allocation)
* ``param_pspecs``  — ``PartitionSpec`` tree (``in_shardings`` for pjit)

Keeping the defs symbolic is what lets the multi-pod dry-run lower a 34B
model on a 1-CPU container.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.specs import LogicalRules, to_pspec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale_axis: int | None = None  # fan-in axis for 'normal' (default: -2)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"ParamDef rank mismatch: {self.shape} vs {self.logical}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(d: ParamDef) -> int:
    if not d.shape:
        return 1
    ax = d.scale_axis
    if ax is None:
        ax = -2 if len(d.shape) >= 2 else 0
    return max(1, d.shape[ax])


def _init_leaf(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)
    std = 1.0 / math.sqrt(_fan_in(d))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_params(defs, rng: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, max(1, len(leaves)))
    out = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_pspecs(defs, rules: LogicalRules):
    return jax.tree_util.tree_map(
        lambda d: to_pspec(d.shape, d.logical, rules, strict=True), defs, is_leaf=is_def
    )


def param_structs(defs, mesh, rules: LogicalRules):
    from jax.sharding import NamedSharding

    def one(d: ParamDef):
        # strict: array shardings must divide exactly (uneven dims — e.g. a
        # 50280 vocab on a 16-way axis — drop that axis instead)
        return jax.ShapeDtypeStruct(
            d.shape, d.dtype,
            sharding=NamedSharding(mesh, to_pspec(d.shape, d.logical, rules, strict=True)),
        )

    return jax.tree_util.tree_map(one, defs, is_leaf=is_def)


def param_count(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_def)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def param_bytes(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_def)
    return int(sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves))


def map_defs(fn: Callable[[ParamDef], ParamDef], defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_def)


def stack_defs(defs, n: int, logical: str = "layers"):
    """Prepend a stacking axis (for scan-over-layers stacked params)."""
    return map_defs(
        lambda d: dataclasses.replace(
            d,
            shape=(n, *d.shape),
            logical=(logical, *d.logical),
            scale_axis=None if d.scale_axis is None else (d.scale_axis if d.scale_axis < 0 else d.scale_axis + 1),
        ),
        defs,
    )
