"""Zamba2-style hybrid: Mamba-2 backbone + ONE shared transformer block
applied every `shared_attn_every` layers. [arXiv:2411.15242]

81 layers = 13 groups of 6 + a tail of 3 (config-derived). Structure is a
two-level scan — outer over groups, inner over the group's mamba layers —
so HLO stays O(1) in depth. The shared block's *weights* are reused at every
application, but each application has its own KV cache (n_groups leading dim).

Deviation noted (DESIGN.md §2): the real Zamba2 feeds concat(hidden,
embedding) through per-application LoRA on the shared block; we apply the
shared block to the hidden state directly — same compute/communication
shape, simpler plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.params import stack_defs
from repro.sharding.specs import LogicalRules


def split_layers(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, tail)."""
    every = cfg.shared_attn_every
    n_groups, tail = divmod(cfg.num_layers, every)
    return n_groups, every, tail


def hybrid_defs(cfg: ModelConfig):
    n_groups, every, tail = split_layers(cfg)
    defs = {
        "groups": stack_defs(stack_defs(tfm.block_defs(cfg, "ssm"), every, "inner"), n_groups, "groups"),
        "shared": tfm.block_defs(cfg, "dense"),
    }
    if tail:
        defs["tail"] = stack_defs(tfm.block_defs(cfg, "ssm"), tail, "inner")
    return defs


def apply_hybrid_full(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    rules: LogicalRules | None,
    positions: jax.Array,
    collect_cache: bool = False,
):
    """Returns (x, caches, metrics). caches (collect_cache=True) =
    {'groups': ssm states (n_groups, every, ...), 'attn': {'k','v'}
    (n_groups, B, S, KV, hd), 'tail': ssm states (tail, ...)}."""
    n_groups, every, tail = split_layers(cfg)

    def group_body(carry, group_params):
        h = carry
        h, ssm_cache, m_inner = tfm.apply_stack_full(
            group_params, h, cfg, "ssm", rules, positions, collect_cache=collect_cache
        )
        h, kv, m_attn = tfm.apply_block_full(
            params["shared"], h, cfg, "dense", rules, positions, causal=True, collect_cache=collect_cache
        )
        metrics = jax.tree.map(jnp.add, m_inner, m_attn)
        return h, ((ssm_cache, kv) if collect_cache else None, metrics)

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, (entries, metrics) = jax.lax.scan(body, x, params["groups"])
    metrics = jax.tree.map(jnp.sum, metrics)
    tail_cache = None
    if tail:
        x, tail_cache, m_tail = tfm.apply_stack_full(
            params["tail"], x, cfg, "ssm", rules, positions, collect_cache=collect_cache
        )
        metrics = jax.tree.map(jnp.add, metrics, m_tail)
    caches = None
    if collect_cache and entries is not None:
        ssm_caches, kvs = entries
        caches = {"groups": ssm_caches, "attn": {"k": kvs[0], "v": kvs[1]}}
        if tail:
            caches["tail"] = tail_cache
    return x, caches, metrics


def apply_hybrid_decode(
    params,
    x: jax.Array,
    caches: dict,
    cfg: ModelConfig,
    rules: LogicalRules | None,
    cur_len: jax.Array,
):
    """caches: {'groups': ssm-state stacked (n_groups, every, ...),
    'attn': {'k','v'} (n_groups, B, S, KV, hd), 'tail': (tail, ...)}."""
    n_groups, every, tail = split_layers(cfg)

    def group_body(carry, inp):
        group_params, group_cache, attn_cache = inp
        h = carry
        h, new_ssm, m1 = tfm.apply_stack_decode(group_params, h, group_cache, cfg, "ssm", rules, cur_len)
        h, new_attn, m2 = tfm.apply_block_decode(params["shared"], h, attn_cache, cfg, "dense", rules, cur_len)
        return h, ((new_ssm, new_attn), jax.tree.map(jnp.add, m1, m2))

    x, ((new_groups, new_attn), metrics) = jax.lax.scan(
        group_body, x, (params["groups"], caches["groups"], caches["attn"])
    )
    metrics = jax.tree.map(jnp.sum, metrics)
    new_caches = {"groups": new_groups, "attn": new_attn}
    if tail:
        x, new_tail, m_tail = tfm.apply_stack_decode(params["tail"], x, caches["tail"], cfg, "ssm", rules, cur_len)
        metrics = jax.tree.map(jnp.add, metrics, m_tail)
        new_caches["tail"] = new_tail
    return x, new_caches, metrics
