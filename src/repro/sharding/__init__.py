from repro.sharding.specs import (  # noqa: F401
    ALLOW_UNEVEN,
    LogicalRules,
    decode_rules,
    infer_rules,
    shard_as,
    to_named_sharding,
    to_pspec,
    train_rules,
)
