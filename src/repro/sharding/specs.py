"""Logical-axis -> mesh-axis sharding rules.

Every tensor in the framework is annotated with *logical* axis names
(e.g. ``('batch', 'seq', 'embed')``); a :class:`LogicalRules` table maps each
logical name to zero or more mesh axes. This is the single place where the
parallelism strategy (DP / FSDP / TP / EP / SP, multi-pod DP) is decided, so
hillclimbing a sharding change is a one-line rules edit.

Axis conventions (see DESIGN.md §5):
  'pod'   — cross-pod data parallelism (multi-pod mesh only)
  'data'  — in-pod data parallelism + FSDP param sharding
  'model' — tensor parallelism (heads / ff / vocab), expert parallelism,
            and sequence parallelism for the residual stream & long KV
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical names where uneven (padded) sharding is accepted rather than
# dropping the mesh axis: q-heads (starcoder2 has 24 heads on a 16-way TP
# axis) and vocab (tokenizer sizes are rarely multiples of 16).
ALLOW_UNEVEN = frozenset({"heads", "vocab"})


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    """Mapping of logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Mapping[str, tuple[str, ...] | str | None]
    mesh_axis_sizes: Mapping[str, int]
    mesh: Mesh | None = None

    def dp_axes(self) -> tuple[str, ...]:
        return tuple(ax for ax in ("pod", "data") if self.mesh_axis_sizes.get(ax, 1) > 1)

    def mesh_axes_for(self, logical: str) -> tuple[str, ...]:
        got = self.rules.get(logical)
        if got is None:
            return ()
        if isinstance(got, str):
            return (got,)
        return tuple(got)

    def spec_entry(self, logical: str | None, dim: int, *, strict: bool = False) -> tuple[str, ...] | str | None:
        """Resolve one logical axis to a PartitionSpec entry, honouring
        divisibility. ``strict=True`` (array/struct shardings — must divide
        exactly) always drops non-dividing axes; the lenient path keeps
        ALLOW_UNEVEN names (with_sharding_constraint pads internally)."""
        if logical is None:
            return None
        axes = self.mesh_axes_for(logical)
        if not axes:
            return None
        if not strict and logical in ALLOW_UNEVEN:
            return axes if len(axes) > 1 else axes[0]
        keep: list[str] = []
        remaining = dim
        for ax in axes:
            size = self.mesh_axis_sizes.get(ax, 1)
            if size > 1 and remaining % size == 0:
                keep.append(ax)
                remaining //= size
            elif size == 1:
                # axis of extent 1 — harmless, keep it out for clean specs
                continue
        if not keep:
            return None
        return tuple(keep) if len(keep) > 1 else keep[0]


def _mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def train_rules(mesh: Mesh) -> LogicalRules:
    """Sharding rules for train / prefill programs.

    Params: FSDP over ('pod','data') on the embed dim + TP over 'model'.
    Activations: batch over ('pod','data'), residual-stream seq over 'model'
    (Megatron-style sequence parallelism — GSPMD inserts the all-gather before
    attention/MLP TP regions and the reduce-scatter after).
    """
    has_pod = "pod" in mesh.axis_names
    dp: tuple[str, ...] = ("pod", "data") if has_pod else ("data",)
    rules = {
        # --- activations ---
        "batch": dp,
        "seq": "model",          # sequence-parallel residual stream
        "seq_full": None,        # inside attention (post all-gather)
        "embed": None,
        "act_heads": "model",
        "act_kv_heads": None,    # GQA KV usually replicated across TP
        "act_ff": "model",
        "head_dim": None,
        "vocab_out": "model",    # logits vocab dim
        # --- params: FSDP axis + TP axis ---
        "embed_fsdp": dp,        # every big param's embed dim
        "heads": "model",
        "kv_heads": "model",     # dropped automatically when not divisible
        "ff": "model",
        "experts": "model",      # EP: expert dim over 'model'
        "expert_ff": None,       # per-expert ff dim (model axis is taken by EP)
        "vocab": "model",
        # --- SSM ---
        "ssm_inner": "model",    # d_inner sharded over TP
        "ssm_heads": "model",
        "ssm_state": None,
        "conv_k": None,
        # --- misc ---
        "layers": None,
        "groups": None,
        "inner": None,
        "cache_seq": None,
        "cache_kv_heads": None,
        "expert_cap": None,
    }
    return LogicalRules(rules, _mesh_sizes(mesh), mesh)


def _cache_rules(sizes: Mapping[str, int], kv_heads: int | None) -> dict:
    model_size = sizes.get("model", 1)
    shard_kv = kv_heads is not None and kv_heads % model_size == 0 and kv_heads >= model_size
    return {
        "cache_seq": None if shard_kv else "model",
        "cache_kv_heads": "model" if shard_kv else None,
    }


def infer_rules(mesh: Mesh, *, kv_heads: int | None = None) -> LogicalRules:
    """PREFILL rules: params stay FSDP-sharded (ZeRO-inference) — the
    per-layer weight all-gather amortizes over the whole prompt batch
    (1M tokens for prefill_32k) and per-device weights drop 16x, which is
    what lets the 34-42B archs prefill within 16 GB/chip. The prefill-built
    KV cache is sharded like the decode cache (heads over 'model' when
    divisible, else sequence)."""
    base = train_rules(mesh)
    rules = dict(base.rules)
    rules.update(_cache_rules(base.mesh_axis_sizes, kv_heads))
    return LogicalRules(rules, base.mesh_axis_sizes, mesh)


def decode_rules(mesh: Mesh, *, kv_heads: int | None = None, batch: int | None = None) -> LogicalRules:
    """Sharding rules for decode programs (single-token step, big KV cache).

    Params: TP-only (see infer_rules). The KV cache is the dominant tensor:
    if the arch has enough KV heads to split over the TP axis we shard
    heads; otherwise (MQA / small-GQA: granite kv=1, qwen3 kv=4, ...) we
    shard the cache *sequence* dim over 'model' — flash-decoding style;
    GSPMD inserts the partial-softmax all-reduce for the attention
    reduction.
    """
    base = infer_rules(mesh, kv_heads=kv_heads)
    sizes = base.mesh_axis_sizes
    # DECODE params: TP-only (vLLM layout). FSDP'd decode weights would be
    # all-gathered EVERY token (~100 ms/step at 34B) — unacceptable latency.
    rules_patch = {"embed_fsdp": None}
    model_size = sizes.get("model", 1)
    dp_size = sizes.get("pod", 1) * sizes.get("data", 1)
    shard_kv_heads = kv_heads is not None and kv_heads % model_size == 0 and kv_heads >= model_size
    # single-stream long-context decode (batch < data axis): the data axis
    # would sit idle — use it for the cache sequence dim instead
    seq_over_data = batch is not None and batch < dp_size
    rules = dict(base.rules)
    if seq_over_data:
        cache_seq: tuple[str, ...] | str | None = ("pod", "data") if "pod" in sizes else ("data",)
        if not shard_kv_heads:
            cache_seq = (*cache_seq, "model")
        rules["cache_seq"] = cache_seq
        rules["batch"] = None
    rules.update(rules_patch)
    rules.update(
        {
            "seq": None,          # q_len == 1: nothing to shard
            "act_kv_heads": "model" if shard_kv_heads else None,
        }
    )
    return LogicalRules(rules, sizes, mesh)


def to_pspec(shape: Sequence[int], logical: Sequence[str | None], rules: LogicalRules, *, strict: bool = False) -> P:
    if len(shape) != len(logical):
        raise ValueError(f"rank mismatch: shape {shape} vs logical {logical}")
    entries = [rules.spec_entry(l, d, strict=strict) for l, d in zip(logical, shape)]
    # PartitionSpec must not name one mesh axis twice; keep first occurrence.
    seen: set[str] = set()
    cleaned: list = []
    for e in entries:
        if e is None:
            cleaned.append(None)
            continue
        group = (e,) if isinstance(e, str) else e
        kept = tuple(ax for ax in group if ax not in seen)
        seen.update(kept)
        if not kept:
            cleaned.append(None)
        elif len(kept) == 1:
            cleaned.append(kept[0])
        else:
            cleaned.append(kept)
    return P(*cleaned)


def to_named_sharding(mesh: Mesh, shape: Sequence[int], logical: Sequence[str | None], rules: LogicalRules) -> NamedSharding:
    return NamedSharding(mesh, to_pspec(shape, logical, rules, strict=True))


def shard_as(x: jax.Array, logical: Sequence[str | None], rules: LogicalRules | None) -> jax.Array:
    """``with_sharding_constraint`` by logical axes; no-op when rules is None
    (single-device smoke-test path)."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, to_pspec(x.shape, logical, rules))


def shard_as_bf16_grad(x: jax.Array, logical: Sequence[str | None], rules: LogicalRules | None) -> jax.Array:
    """shard_as whose BACKWARD casts the cotangent to bf16 first.

    Cotangents of the residual stream otherwise ride in fp32 (upcasts leak
    from the loss/norm/router fp32 islands), so every TP/SP boundary
    reduction in the backward moves 2x the bytes (measured 252 MB/op fp32
    activation all-reduces on qwen3 train — EXPERIMENTS §Perf #4).
    bf16 gradient reductions are standard practice (Megatron-LM)."""
    if rules is None:
        return x
    dtype = x.dtype  # static at trace time

    @jax.custom_vjp
    def f(y):
        return shard_as(y, logical, rules)

    def fwd(y):
        return shard_as(y, logical, rules), None

    def bwd(_, g):
        g = g.astype(jnp.bfloat16).astype(dtype)
        return (shard_as(g, logical, rules),)

    f.defvjp(fwd, bwd)
    return f(x)
