"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler monitoring.

Failure model (what a 1000-node job actually sees):
* process crash / node loss  -> restart from the latest atomic checkpoint
  (exercised here by :class:`FailureInjector`, which raises at configured
  steps; the loop restores and continues — the test asserts bit-exact
  continuation thanks to the deterministic pipeline);
* stragglers                 -> per-step wall times are tracked; steps
  slower than ``straggler_factor`` x the trailing median are logged as
  straggler events. On a real pod this signal drives hot-spare swap /
  re-meshing; here it is recorded and surfaced in the loop summary;
* elastic resize             -> restore() re-shards onto whatever mesh the
  restarted job brings up (see CheckpointManager docstring).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax

from repro.checkpointing.manager import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises InjectedFailure the first time each configured step is reached."""

    def __init__(self, fail_at_steps: list[int]):
        self.pending = set(fail_at_steps)
        self.fired: list[int] = []

    def maybe_fail(self, step: int) -> None:
        if step in self.pending:
            self.pending.discard(step)
            self.fired.append(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    median_seconds: float


class TrainLoop:
    def __init__(
        self,
        train_step: Callable,
        make_data: Callable[[int], Any],
        manager: CheckpointManager,
        *,
        ckpt_every: int = 50,
        straggler_factor: float = 3.0,
        window: int = 20,
        jit_step: bool = True,
    ):
        """``make_data(start_batch)`` returns an iterator positioned at that
        batch (restart resumes the stream exactly where it crashed)."""
        self.train_step = train_step
        self.make_data = make_data
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.window = window
        self.jit_step = jit_step
        self.straggler_events: list[StragglerEvent] = []
        self.restarts = 0

    def run(self, init_state, num_steps: int, failure_injector: FailureInjector | None = None):
        history: list[dict] = []
        step_times: list[float] = []

        latest = self.manager.latest_step()
        if latest is not None:
            state = self.manager.restore(init_state, latest)
            step = latest
        else:
            state = init_state
            step = 0
        data = self.make_data(step)

        jitted = jax.jit(self.train_step) if self.jit_step else self.train_step
        while step < num_steps:
            try:
                batch = next(data)
                if failure_injector is not None:
                    failure_injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = jitted(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                step += 1
                step_times.append(dt)
                if len(step_times) > 3:
                    med = statistics.median(step_times[-self.window :])
                    if dt > self.straggler_factor * med:
                        self.straggler_events.append(StragglerEvent(step, dt, med))
                history.append({"step": step, "seconds": dt, **{k: float(v) for k, v in metrics.items()}})
                if self.ckpt_every and step % self.ckpt_every == 0:
                    self.manager.save(step, state)
            except InjectedFailure:
                # simulated crash: drop in-memory state, restore, reposition data
                self.restarts += 1
                if hasattr(data, "close"):
                    data.close()
                latest = self.manager.latest_step()
                if latest is None:
                    state = init_state
                    step = 0
                else:
                    state = self.manager.restore(init_state, latest)
                    step = latest
                data = self.make_data(step)
        self.manager.wait()
        if hasattr(data, "close"):
            data.close()
        return state, history
