"""train_step: loss -> grads -> clipped AdamW update, with optional
gradient-accumulation microbatching (scan over microbatches — the per-
microbatch backward overlaps its gradient reduce with the next microbatch's
compute under XLA's scheduler)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.params import ParamDef
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_state_defs, adamw_update


def make_train_state_defs(model: Model):
    return {"params": model.param_defs, "opt": adamw_state_defs(model.param_defs)}


def init_train_state(model: Model, rng: jax.Array):
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(model: Model, opt_cfg: AdamWConfig | None = None, lr_schedule=None):
    opt_cfg = opt_cfg or AdamWConfig()
    cfg = model.cfg
    n_micro = max(1, cfg.microbatches)

    def loss_for_grad(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                (l, m), g = grad_fn(params, mb)
                g_acc, l_acc, m_acc = carry
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, l_acc + l, m_acc), None

            # accumulate in the grad dtype (bf16): a fp32 accumulator makes
            # XLA hoist the f32 convert BEFORE the per-microbatch TP grad
            # all-reduce -> 2x collective bytes (EXPERIMENTS §Perf #5)
            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            first_mb = jax.tree.map(lambda x: x[0], micro)
            (_, m0), _ = jax.eval_shape(grad_fn, params, first_mb)
            zeros_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, loss, metrics), _ = jax.lax.scan(
                acc_body, (zeros_g, jnp.zeros((), jnp.float32), zeros_m), micro
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree.map(lambda m: m / n_micro, metrics)

        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg, lr_schedule)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
