from repro.training.loop import FailureInjector, InjectedFailure, TrainLoop  # noqa: F401
from repro.training.train_step import make_train_step, make_train_state_defs  # noqa: F401
