"""Multi-level SLO classes for admission control.

PR 2's two-level ``priority=PRIORITY_HIGH`` admission generalizes to N
*classes*, each carrying a latency target: ``invoke_async(..., slo=
SLOClass("interactive", target_p95_ms=50.0))``. The class rides with the
request into its own per-(function, shape, class) admission lane, where the
window controller turns the target into a batching window via the queueing
model (see :mod:`repro.scheduler.adaptive`): strict targets buy small
windows (low added delay), loose or absent targets buy big ones
(throughput). Batches never mix classes — a best-effort convoy can never
drag a strict request's latency with it.

Class semantics:

* ``target_p95_ms`` is the class's end-to-end (admission -> completion) p95
  target. ``inf`` means *best effort*: no target, window tuned purely for
  occupancy — exactly the pre-SLO behavior.
* A class with target ``0`` never waits: its window is always zero (greedy
  drain), and its arrival preempts open windows of looser classes on the
  same (function, shape) — this is what ``PRIORITY_HIGH`` maps to, so the
  old two-level API keeps its exact semantics.
* Ordering is by target: tighter targets are admitted first when multiple
  classes contend, and only a *strictly tighter* arrival preempts an open
  window.

Classes are identified by name; two SLOClass values with the same name must
carry the same target (the scheduler keys lanes and metrics by name).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One admission class: a name and a p95 latency target (ms).

    ``math.inf`` (the default) marks best-effort traffic — no deadline, the
    window controller optimizes occupancy. Finite targets make the class
    *strict*: the controller spends the target's slack (target minus
    predicted queue wait minus service) on batching and nothing more.
    """

    name: str
    target_p95_ms: float = math.inf

    def __post_init__(self):
        if self.target_p95_ms < 0:
            raise ValueError(f"SLO target must be >= 0, got {self.target_p95_ms}")

    @property
    def best_effort(self) -> bool:
        return not math.isfinite(self.target_p95_ms)

    @property
    def target_s(self) -> float:
        return self.target_p95_ms / 1e3

    def tighter_than(self, other: "SLOClass") -> bool:
        return self.target_p95_ms < other.target_p95_ms


#: The default class for untagged traffic: no deadline, occupancy-tuned
#: window — byte-for-byte the pre-SLO scheduler behavior.
BEST_EFFORT = SLOClass("best-effort", math.inf)

#: What ``priority=PRIORITY_HIGH`` maps to: a zero-slack class that never
#: waits out a window and preempts open looser-class windows on its key.
IMMEDIATE = SLOClass("immediate", 0.0)


def slo_for_priority(priority: int) -> SLOClass:
    """Back-compat shim for the PR 2 two-level API."""
    return IMMEDIATE if priority > 0 else BEST_EFFORT
