"""Multi-level SLO classes for admission control.

PR 2's two-level ``priority=PRIORITY_HIGH`` admission generalizes to N
*classes*, each carrying a latency target: ``invoke_async(..., slo=
SLOClass("interactive", target_p95_ms=50.0))``. The class rides with the
request into its own per-(function, shape, class) admission lane, where the
window controller turns the target into a batching window via the queueing
model (see :mod:`repro.scheduler.adaptive`): strict targets buy small
windows (low added delay), loose or absent targets buy big ones
(throughput). Batches never mix classes — a best-effort convoy can never
drag a strict request's latency with it.

Class semantics:

* ``target_p95_ms`` is the class's end-to-end (admission -> completion) p95
  target. ``inf`` means *best effort*: no target, window tuned purely for
  occupancy — exactly the pre-SLO behavior.
* A class with target ``0`` never waits: its window is always zero (greedy
  drain), and its arrival preempts open windows of looser classes on the
  same (function, shape) — this is what ``PRIORITY_HIGH`` maps to, so the
  old two-level API keeps its exact semantics.
* Ordering is by target: tighter targets are admitted first when multiple
  classes contend, and only a *strictly tighter* arrival preempts an open
  window.

Classes are identified by name; two SLOClass values with the same name must
carry the same target (the scheduler keys lanes and metrics by name).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One admission class: a name and a p95 latency target (ms).

    ``math.inf`` (the default) marks best-effort traffic — no deadline, the
    window controller optimizes occupancy. Finite targets make the class
    *strict*: the controller spends the target's slack (target minus
    predicted queue wait minus service) on batching and nothing more.
    """

    name: str
    target_p95_ms: float = math.inf

    def __post_init__(self):
        if self.target_p95_ms < 0:
            raise ValueError(f"SLO target must be >= 0, got {self.target_p95_ms}")

    @property
    def best_effort(self) -> bool:
        return not math.isfinite(self.target_p95_ms)

    @property
    def target_s(self) -> float:
        return self.target_p95_ms / 1e3

    def tighter_than(self, other: "SLOClass") -> bool:
        return self.target_p95_ms < other.target_p95_ms


#: The default class for untagged traffic: no deadline, occupancy-tuned
#: window — byte-for-byte the pre-SLO scheduler behavior.
BEST_EFFORT = SLOClass("best-effort", math.inf)

#: What ``priority=PRIORITY_HIGH`` maps to: a zero-slack class that never
#: waits out a window and preempts open looser-class windows on its key.
IMMEDIATE = SLOClass("immediate", 0.0)


def slo_for_priority(priority: int) -> SLOClass:
    """Back-compat shim for the PR 2 two-level API."""
    return IMMEDIATE if priority > 0 else BEST_EFFORT


class ClassLanes:
    """Per-SLO-class FIFO lanes with strictest-target-first pop — the
    slot-assignment analogue of the admission queues.

    The continuous batcher feeds its fixed-capacity decode batch from
    these: when an in-flight slot frees, ``pop()`` hands out the waiting
    request of the *tightest* class first (FIFO within a class), so a
    strict arrival preempts best-effort traffic for slot assignment exactly
    the way it preempts batching windows in the admission queues. Not
    thread-safe by itself — callers hold their own lock."""

    def __init__(self):
        self._lanes: dict[str, list] = {}
        self._classes: dict[str, SLOClass] = {}

    def push(self, item, slo: SLOClass = BEST_EFFORT) -> None:
        known = self._classes.get(slo.name)
        if known is not None and known.target_p95_ms != slo.target_p95_ms:
            raise ValueError(
                f"SLO class {slo.name!r} redefined: target "
                f"{slo.target_p95_ms} != {known.target_p95_ms}"
            )
        self._classes[slo.name] = slo
        self._lanes.setdefault(slo.name, []).append(item)

    def pop(self):
        """The next (item, slo) by class tightness, or None when empty."""
        for name in sorted(
            (n for n, lane in self._lanes.items() if lane),
            key=lambda n: self._classes[n].target_p95_ms,
        ):
            lane = self._lanes[name]
            return lane.pop(0), self._classes[name]
        return None

    def requeue(self, item, slo: SLOClass) -> None:
        """Put an item back at the FRONT of its lane (e.g. admission failed
        transiently — arena full — and must retry first next round)."""
        self._classes[slo.name] = slo
        self._lanes.setdefault(slo.name, []).insert(0, item)

    def depth(self, class_name: str | None = None) -> int:
        if class_name is not None:
            return len(self._lanes.get(class_name, ()))
        return sum(len(l) for l in self._lanes.values())

    def best_effort_depth(self) -> int:
        """Queued items across best-effort (targetless) lanes only — the
        backlog an overload shed bound applies to."""
        return sum(
            len(lane)
            for name, lane in self._lanes.items()
            if self._classes[name].best_effort
        )

    def counts(self) -> dict[str, int]:
        return {n: len(l) for n, l in self._lanes.items() if l}
