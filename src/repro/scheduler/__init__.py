"""Concurrent request scheduling: admission queues + micro-batched dispatch.

The paper measures one request at a time; this package is the platform layer
that turns *concurrent* external invocations into batched XLA executions
(ProFaaStinate-style delayed grouping in front of Provuse's fused units),
with per-key feedback-retuned batching windows (Fusionize++-style iteration)
and two-level SLO-priority admission.
"""
from repro.scheduler.adaptive import (  # noqa: F401
    PRIORITY_HIGH,
    PRIORITY_NORMAL,
    AdaptiveConfig,
    AdaptiveWindow,
    SchedulerSignals,
)
from repro.scheduler.batching import (  # noqa: F401
    next_batch_bucket,
    request_key,
    split_results,
    stack_requests,
)
from repro.scheduler.coalescer import AdmissionQueue, PendingRequest  # noqa: F401
from repro.scheduler.metrics import LatencyWindow, percentiles_ms  # noqa: F401
from repro.scheduler.scheduler import RequestScheduler  # noqa: F401
