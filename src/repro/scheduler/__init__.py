"""Concurrent request scheduling: admission queues + micro-batched dispatch.

The paper measures one request at a time; this package is the platform layer
that turns *concurrent* external invocations into batched XLA executions
(ProFaaStinate-style delayed grouping in front of Provuse's fused units),
with N-level SLO-class admission (per-(function, shape, class) lanes, no
cross-class batches), per-lane windows set by a queueing model (EWMA
arrival rate x EWMA batch service time -> predicted wait -> window from
the class's slack), and an injectable clock that makes every timing
behavior testable on a deterministic virtual clock.
"""
from repro.scheduler.adaptive import (  # noqa: F401
    PRIORITY_HIGH,
    PRIORITY_NORMAL,
    AdaptiveConfig,
    AdaptiveWindow,
    QueueingWindow,
    SchedulerSignals,
    ServiceTimeEstimate,
    static_window_s,
)
from repro.scheduler.batching import (  # noqa: F401
    next_batch_bucket,
    request_key,
    split_results,
    stack_requests,
)
from repro.scheduler.clock import (  # noqa: F401
    SYSTEM_CLOCK,
    SystemClock,
    VirtualClock,
)
from repro.scheduler.coalescer import AdmissionQueue, PendingRequest  # noqa: F401
from repro.scheduler.metrics import LatencyWindow, percentiles_ms  # noqa: F401
from repro.scheduler.scheduler import OverloadShedError, RequestScheduler  # noqa: F401
from repro.scheduler.slo import (  # noqa: F401
    BEST_EFFORT,
    IMMEDIATE,
    ClassLanes,
    SLOClass,
    slo_for_priority,
)
