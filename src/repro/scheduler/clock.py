"""Injectable time source for every timing-sensitive scheduler component.

The scheduler stack (admission queues, window controllers, quiesce barrier,
trough detector, lifecycle reconciler) used to call ``time.perf_counter`` /
``time.sleep`` / ``Condition.wait(timeout)`` directly, which made its tests
pay every window and idle-timeout in wall-clock time — and made sub-ms
timing assertions flaky on loaded CI boxes. Everything now reads time
through a :class:`Clock`:

* :class:`SystemClock` — production: ``perf_counter`` + real waits. The
  module-level :data:`SYSTEM_CLOCK` singleton is the default everywhere, so
  no behavior changes unless a test injects something else.
* :class:`VirtualClock` — deterministic simulation: time only moves when the
  test calls :meth:`~VirtualClock.advance`. Threads that block through
  ``wait_on``/``sleep`` park on real condition variables (no busy spin, no
  real sleeps) and are woken by ``advance``; each wake re-checks its virtual
  deadline. A test can therefore drive hours of scripted traffic through
  real dispatcher threads in milliseconds of wall time, and the
  ``elapsed_real``/:meth:`~VirtualClock.assert_elapsed_real_below` guard
  proves no real sleeping happened.

The contract for blocking code: never call ``cond.wait(timeout)`` directly —
call ``clock.wait_on(cond, timeout)`` while holding ``cond``'s lock, and
treat every return as a possibly-spurious wake (loop and re-check the
predicate against ``clock.now()``). That is exactly the discipline
``Condition.wait`` already requires, so SystemClock adds nothing.
"""
from __future__ import annotations

import threading
import time

#: Real-time safety net for VirtualClock waits: if a test forgets to
#: advance, parked threads still wake occasionally so a failing test's own
#: (real) timeouts can fire instead of the whole process wedging.
_REAL_GUARD_S = 60.0


class SystemClock:
    """Wall-clock time: the production default. Stateless and shared."""

    virtual = False

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def wait_on(self, cond: threading.Condition, timeout: float | None) -> None:
        """``cond.wait`` with the caller holding ``cond``'s lock. May return
        early (notify or spurious wake); callers must loop on their predicate."""
        cond.wait(timeout)


#: Shared default instance — every component's ``clock=None`` resolves here.
SYSTEM_CLOCK = SystemClock()


class VirtualClock:
    """Deterministic time for simulation tests.

    ``now()`` returns simulated seconds; only :meth:`advance` moves it.
    Worker threads blocking via :meth:`wait_on` / :meth:`sleep` park on
    their real condition variables and are notified by ``advance`` — they
    re-check their virtual deadlines on every wake, so a window timer
    "expires" the instant the test advances past it, never by real waiting.

    :meth:`wait_for_waiters` is the test-side handshake: it blocks (real
    time, event-driven — no polling sleeps) until at least ``n`` threads are
    parked in a clock wait *and* the parked set has stopped churning, which
    is the moment an ``advance`` is guaranteed to be observed by everyone
    the test cares about.
    """

    virtual = True

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._mu = threading.Lock()
        # cond objects with at least one parked waiter -> waiter count
        self._parked: dict[int, tuple[threading.Condition, int]] = {}
        self._transitions = 0  # total park/unpark events (stabilization)
        self._state_cv = threading.Condition(self._mu)
        self._created_real = time.perf_counter()
        self._sleep_cv = threading.Condition()

    # ------------------------------------------------------------ time API

    def now(self) -> float:
        return self._t  # float read is atomic under the GIL

    def sleep(self, seconds: float) -> None:
        """Park until virtual time reaches ``now + seconds`` (woken only by
        ``advance``). Never blocks on wall-clock time."""
        deadline = self._t + max(0.0, seconds)
        with self._sleep_cv:
            while self._t < deadline:
                self.wait_on(self._sleep_cv, None)

    def wait_on(self, cond: threading.Condition, timeout: float | None) -> None:
        """Virtual-aware ``cond.wait``: returns on a real ``notify``, or as
        soon as ``advance`` moves virtual time past ``now + timeout``.
        Spurious returns are allowed (callers re-check predicates)."""
        if timeout is not None and timeout <= 0:
            return
        key = id(cond)
        with self._mu:
            prev, n = self._parked.get(key, (cond, 0))
            self._parked[key] = (cond, n + 1)
            self._transitions += 1
            self._state_cv.notify_all()
        try:
            # Parked on the caller's own condition: a real notify (producer
            # put, shutdown) wakes it exactly like the system clock; advance()
            # notifies every parked condition so virtual deadlines re-check.
            cond.wait(_REAL_GUARD_S)
        finally:
            with self._mu:
                c, n = self._parked[key]
                if n <= 1:
                    del self._parked[key]
                else:
                    self._parked[key] = (c, n - 1)
                self._transitions += 1
                self._state_cv.notify_all()

    # ----------------------------------------------------------- test API

    def advance(self, seconds: float) -> float:
        """Move virtual time forward and wake every parked waiter so timers
        can re-check their deadlines. Returns the new ``now``."""
        if seconds < 0:
            raise ValueError("virtual time cannot go backwards")
        with self._mu:
            self._t += seconds
            conds = [c for (c, _) in self._parked.values()]
        for cond in conds:
            with cond:
                cond.notify_all()
        return self._t

    def wait_for_waiters(self, n: int = 1, timeout: float = 5.0) -> int:
        """Block (real, bounded) until >= ``n`` threads are parked in a clock
        wait and the parked set is stable. Event-driven — the wait wakes on
        every park/unpark transition, so quiet systems settle immediately.
        Returns the parked-thread count; raises on (real) timeout."""
        deadline = time.perf_counter() + timeout
        with self._mu:
            while True:
                count = sum(n_ for (_, n_) in self._parked.values())
                if count >= n:
                    # stabilization: give in-flight threads one short grace
                    # window to re-park; if nothing transitions, we're settled
                    gen = self._transitions
                    self._state_cv.wait(0.005)
                    if self._transitions == gen:
                        return count
                    continue
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    raise TimeoutError(
                        f"only {count}/{n} threads parked on the virtual clock"
                    )
                self._state_cv.wait(min(remaining, 0.25))

    def elapsed_real(self) -> float:
        """Real seconds since construction — the no-real-sleeps guard."""
        return time.perf_counter() - self._created_real

    def assert_elapsed_real_below(self, seconds: float) -> None:
        """Assert the whole simulation ran in under ``seconds`` of wall time
        (i.e. nothing actually slept out a virtual duration)."""
        real = self.elapsed_real()
        if real >= seconds:
            raise AssertionError(
                f"virtual-clock run used {real:.3f}s of real time "
                f"(budget {seconds:.3f}s) — something slept on the wall clock"
            )
