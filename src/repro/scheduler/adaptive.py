"""Queueing-model micro-batch window control with per-class SLO targets.

PR 2's controller grew and shrank each admission window with *gap
heuristics* — multiplicative nudges toward ``(target_occupancy * max_batch
- 1) * gap``. This revision replaces the growth rules with an explicit
M/G/1-style model per (function, shape, class) lane, fed by two EWMAs the
lane already observes:

* **arrival rate** ``lambda = 1 / ewma_gap`` (per class — each class's
  arrival process is its own),
* **batch service time** ``S`` (measured wall time of the lane's dispatches).

From those, the predicted queue wait behind the lane's own backlog is the
classic utilization blow-up::

    k_hat = clamp(1 + lambda * window, 1, max_batch)   # expected batch fill
    rho   = lambda * S / k_hat                         # offered / capacity
    W_q   = S * rho / (1 - rho)                        # M/G/1-flavored wait
                                                       # (rho >= 1 -> inf)

and the window decision is class-driven:

* **best-effort** (no target): window = time to fill ``target_occupancy *
  max_batch`` at the observed rate — the same steady-state the old
  heuristics converged to, now computed directly instead of approached by
  multiplicative steps.
* **strict** (finite ``target_p95_ms``): window = ``min(fill time, slack)``
  where ``slack = target - W_q - S``. The lane spends the target's slack on
  batching and *nothing more*; when load (or an unachievable target) eats
  the slack, the window collapses to zero and the class degrades to greedy
  FIFO draining — the old pre-SLO behavior.
* **trickle** (either kind): if the observed gap exceeds the window cap, no
  second arrival can be caught by waiting; the window goes to the minimum.

A relative hysteresis dead-band plus bounded multiplicative steps are kept
from PR 2 so noisy arrivals still cannot flap the window batch-to-batch.

:class:`SchedulerSignals` grows per-class tail latencies: the fusion policy
promotes merges whose removed sync-wait would un-violate a class's target,
and treats a sustained violated class on a fused group as regret (fission).
"""
from __future__ import annotations

import dataclasses
import math
import threading

from repro.scheduler.slo import BEST_EFFORT, SLOClass

#: Priority levels for the PR 2 two-level API (kept working: HIGH maps to
#: the zero-target ``IMMEDIATE`` class — see :mod:`repro.scheduler.slo`).
PRIORITY_NORMAL = 0
PRIORITY_HIGH = 1


class ServiceTimeEstimate:
    """Batch-service-time EWMA shared across one function's SLO lanes.

    Service time is a property of the FUNCTION (its compiled batch
    program), not of the admission class — but each lane used to keep its
    own EWMA, so every new class lane cold-started its M/G/1 model with no
    service estimate and spent its first batches flying blind. Sharing one
    estimate per function means a fresh strict lane prices its slack
    correctly from its very first window.

    Thread-safe: lanes' dispatcher threads update concurrently."""

    # provlint: the `value` property reads _value unlocked by design — a
    # GIL-atomic reference read of a float; only writes take the lock.
    GUARDED_WRITES = {"_value": "_lock"}

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._lock = threading.Lock()
        self._value: float | None = None

    @property
    def value(self) -> float | None:
        return self._value

    def observe(self, service_s: float) -> None:
        if service_s < 0:
            return
        a = self.alpha
        with self._lock:
            v = self._value
            self._value = service_s if v is None else (1 - a) * v + a * service_s

    def reset(self) -> None:
        with self._lock:
            self._value = None


@dataclasses.dataclass(frozen=True)
class SchedulerSignals:
    """Live scheduler state for one (caller, callee) chain, consumed by the
    fusion policy: hot-but-saturated chains deprioritize merges (the stall
    hurts most exactly when batching is already absorbing the load), cold
    chains with long waits promote them, and per-class tail violations both
    promote merges that would remove the violating wait and count as regret
    against merges that caused one."""

    queue_depth: int = 0        # pending requests across the chain's keys
    mean_occupancy: float = 0.0  # mean batch size / max_batch, 0..1
    p95_ms: float = 0.0          # worst per-function p95 latency in the chain
    # RECENT per-class tails across the chain: (class name, p95_ms,
    # target_ms) over the scheduler's trailing window. Classes with a
    # finite POSITIVE target only: best-effort has no target to violate,
    # and a zero target (the IMMEDIATE / PRIORITY_HIGH shim) promises zero
    # *admission* delay, not zero end-to-end latency — service time alone
    # would read it as violated forever and flap fission on every group.
    class_p95_ms: tuple[tuple[str, float, float], ...] = ()

    def worst_violation(self) -> tuple[str, float, float] | None:
        """The violated class with the largest p95/target overshoot, or None
        when every class with traffic is meeting its target."""
        worst = None
        worst_ratio = 1.0
        for name, p95, target in self.class_p95_ms:
            if target > 0 and math.isfinite(target) and p95 > target:
                ratio = p95 / target
                if ratio > worst_ratio:
                    worst, worst_ratio = (name, p95, target), ratio
        return worst


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs for the per-lane window controller.

    target_occupancy: fill fraction best-effort lanes steer batches toward;
        the fill time is how long that many arrivals take at the EWMA rate.
    min_delay_s / max_delay_s: hard bounds of the retuned window.
    alpha: EWMA smoothing for arrival gaps, occupancy, and service time.
    grow / shrink: bounded multiplicative step per retune.
    hysteresis: relative dead-band — desired values within ±hysteresis of
        the current window leave it untouched (no per-batch flapping).
    floor_s: windows shrinking below this snap to min_delay_s (a
        sub-floor window buys nothing but timer churn).
    slack_fraction: the share of a strict class's modeled slack the window
        may spend (the rest absorbs model error — an EWMA under-estimating
        the queue wait must not convert the whole target into batching
        delay and violate it by construction).
    """

    target_occupancy: float = 0.75
    min_delay_s: float = 0.0
    max_delay_s: float = 0.020
    alpha: float = 0.3
    grow: float = 1.6
    shrink: float = 0.6
    hysteresis: float = 0.2
    floor_s: float = 0.00025
    slack_fraction: float = 0.5


def static_window_s(slo: SLOClass, max_delay_s: float) -> float:
    """The non-adaptive (static) window for a class: best-effort lanes use
    the configured window unchanged; a zero-target class never waits; other
    strict classes bound the added delay to a quarter of their target (no
    estimates exist without a controller, so the bound is structural)."""
    if slo.best_effort:
        return max_delay_s
    return min(max_delay_s, 0.25 * slo.target_s)


class QueueingWindow:
    """One admission lane's window controller. Single-writer: only the
    lane's dispatcher thread calls :meth:`observe_batch`; ``snapshot()``
    readers see torn-free floats under the GIL. Pure — it never reads a
    clock; every timestamp it sees arrived via ``observe_batch``, which is
    what makes it drivable by a scripted virtual-clock trace."""

    def __init__(
        self,
        max_batch: int,
        initial_delay_s: float,
        config: AdaptiveConfig | None = None,
        slo: SLOClass = BEST_EFFORT,
        service: ServiceTimeEstimate | None = None,
    ):
        self.cfg = config or AdaptiveConfig()
        self.max_batch = max(1, int(max_batch))
        self.slo = slo
        # service time is per FUNCTION: the scheduler hands every lane of a
        # function the same estimate, so new class lanes start warm; a
        # standalone controller owns a private one (same behavior as before)
        self.service = service if service is not None else ServiceTimeEstimate(self.cfg.alpha)
        self.delay_s = self._clamp_seed(initial_delay_s)
        self.retunes = 0
        self._ewma_gap_s: float | None = None
        self._ewma_intra_s: float | None = None
        self._ewma_occupancy: float | None = None
        self._last_arrival_t: float | None = None

    def _clamp_seed(self, delay_s: float) -> float:
        seed = min(max(float(delay_s), self.cfg.min_delay_s), self.cfg.max_delay_s)
        if not self.slo.best_effort:
            # a strict lane's first window must already respect the target:
            # with no estimates yet the structural static bound governs
            seed = min(seed, static_window_s(self.slo, self.cfg.max_delay_s))
        return seed

    def reset(self, initial_delay_s: float | None = None) -> None:
        """Forget learned traffic state (benchmark warmup isolation);
        optionally re-seed the window."""
        if initial_delay_s is not None:
            self.delay_s = self._clamp_seed(initial_delay_s)
        self._ewma_gap_s = None
        self._ewma_intra_s = None
        self._ewma_occupancy = None
        self.service.reset()
        self._last_arrival_t = None

    # ------------------------------------------------------------- model

    @property
    def arrival_rate_rps(self) -> float:
        gap = self._ewma_gap_s
        return 1.0 / gap if gap and gap > 0 else 0.0

    def offered_rho(self) -> float:
        """This lane's offered load vs its batched capacity:
        ``lambda * S / k_hat``. >= 1 means the lane cannot keep up."""
        lam = self.arrival_rate_rps
        svc = self.service.value or 0.0
        if lam <= 0 or svc <= 0:
            return 0.0
        k_hat = min(float(self.max_batch), max(1.0, 1.0 + lam * self.delay_s))
        return lam * svc / k_hat

    def predicted_wait_s(self) -> float:
        """M/G/1-style queue-wait prediction behind this lane's backlog:
        ``S * rho / (1 - rho)`` with ``rho = lambda * S / k_hat``. Infinite
        once the lane is offered more than its batched capacity."""
        svc = self.service.value or 0.0
        rho = self.offered_rho()
        if rho <= 0.0:
            return 0.0
        if rho >= 1.0:
            return math.inf
        return svc * rho / (1.0 - rho)

    def observe_batch(
        self,
        arrival_ts: list[float],
        closed_full: bool,
        service_s: float | None = None,
    ) -> float:
        """Feed one closed batch's arrival timestamps (and the batch's
        measured service wall time); returns the retuned window (seconds).
        Gaps are measured across batch boundaries too, so a string of
        singleton batches still yields a rate estimate."""
        a = self.cfg.alpha
        ts = sorted(arrival_ts)
        gaps = []
        if self._last_arrival_t is not None and ts:
            gaps.append(max(0.0, ts[0] - self._last_arrival_t))
        gaps.extend(t1 - t0 for t0, t1 in zip(ts, ts[1:]))
        if ts:
            self._last_arrival_t = ts[-1]
        for g in gaps:
            self._ewma_gap_s = g if self._ewma_gap_s is None else (1 - a) * self._ewma_gap_s + a * g
            if g < self.cfg.max_delay_s:
                # "catchable" gaps only: the intra-burst spacing estimate that
                # drives idle_close_s — burst-boundary gaps would inflate it
                self._ewma_intra_s = (
                    g if self._ewma_intra_s is None else (1 - a) * self._ewma_intra_s + a * g
                )
        occ = len(ts) / self.max_batch
        self._ewma_occupancy = occ if self._ewma_occupancy is None else (1 - a) * self._ewma_occupancy + a * occ
        if service_s is not None and service_s >= 0:
            self.service.observe(service_s)
        new = self._retune(closed_full)
        if new != self.delay_s:
            self.retunes += 1
            self.delay_s = new
        return self.delay_s

    def _desired_window(self) -> float | None:
        """The model's raw window choice, before hysteresis/steps. None when
        no rate estimate exists yet (the seed window governs)."""
        cfg = self.cfg
        if not self.slo.best_effort and self.slo.target_p95_ms == 0.0:
            # zero-target (IMMEDIATE / PRIORITY_HIGH shim): never waits, by
            # contract — even an operator min_delay_s floor (a best-effort
            # timer-churn knob) must not re-open a window on this lane
            return 0.0
        gap = self._ewma_gap_s
        if gap is None:
            return None
        if gap >= cfg.max_delay_s:
            # trickle: even the longest window can't catch one more arrival,
            # for ANY class — waiting buys queueing delay and nothing else
            return cfg.min_delay_s if self.slo.best_effort else 0.0
        # time to fill target_occupancy * max_batch; the first request opens
        # the window, so one fewer arrival is needed
        need = max(0.0, cfg.target_occupancy * self.max_batch - 1.0)
        fill_s = need * gap
        desired = min(cfg.max_delay_s, max(cfg.min_delay_s, fill_s))
        if not self.slo.best_effort:
            svc = self.service.value or 0.0
            slack = self.slo.target_s - self.predicted_wait_s() - svc
            budget = cfg.slack_fraction * slack
            if budget <= cfg.min_delay_s:
                # no slack left: degrade to greedy FIFO. Explicitly 0, not
                # min_delay_s — a strict lane out of slack must stop adding
                # delay entirely
                return 0.0
            desired = min(desired, budget)
        return desired

    def _retune(self, closed_full: bool) -> float:
        cfg, cur = self.cfg, self.delay_s
        desired = self._desired_window()
        if desired is None:
            return cur
        if (
            desired > cur
            and self._ewma_occupancy is not None
            and self._ewma_occupancy >= cfg.target_occupancy
        ):
            desired = cur  # batches already fill to target: growth buys nothing
        step_floor = cfg.max_delay_s / 32.0
        if desired > cur * (1.0 + cfg.hysteresis):
            return min(desired, max(cur * cfg.grow, step_floor))
        if desired < cur * (1.0 - cfg.hysteresis) or (desired < cur and closed_full):
            new = max(desired, cur * cfg.shrink)
            # sub-floor windows buy nothing but timer churn: snap straight
            # to the model's floor — min_delay_s for best-effort trickle,
            # 0.0 for a strict lane that must stop waiting (desired <= new,
            # so the snap never moves the window up)
            return desired if new < cfg.floor_s else new
        return cur

    def idle_close_s(self) -> float | None:
        """Early-close cutoff for an OPEN window: when no arrival lands
        within ~3 smoothed intra-burst gaps, the burst this window was
        grown for is over — holding the collected requests for the rest of
        the window is pure convoy tax. None until a spacing estimate exists
        (then the window alone governs)."""
        if self._ewma_intra_s is None:
            return None
        return min(self.cfg.max_delay_s, max(3.0 * self._ewma_intra_s, 1e-3))

    def snapshot(self) -> dict:
        idle = self.idle_close_s()
        wait = self.predicted_wait_s()
        return {
            "window_ms": self.delay_s * 1e3,
            "ewma_gap_ms": (self._ewma_gap_s or 0.0) * 1e3,
            "ewma_occupancy": self._ewma_occupancy or 0.0,
            "idle_close_ms": (idle or 0.0) * 1e3,
            "retunes": self.retunes,
            "slo": self.slo.name,
            "target_ms": self.slo.target_p95_ms,
            "arrival_rps": round(self.arrival_rate_rps, 3),
            "service_ms": (self.service.value or 0.0) * 1e3,
            "predicted_wait_ms": wait * 1e3 if math.isfinite(wait) else math.inf,
            "rho": round(self.offered_rho(), 4),
        }


#: PR 2 name, kept importable: the controller API (observe_batch/ delay_s /
#: snapshot / idle_close_s / reset) is unchanged; only the retune model is new.
AdaptiveWindow = QueueingWindow
