"""Feedback-driven micro-batch window control (Fusionize++-style iteration).

The static ``max_delay_ms`` knob from PR 1 forces one trade-off on every
traffic shape: a long window taxes trickling clients with queueing delay
they buy nothing for, a short window lets bursts slip through in fragments.
:class:`AdaptiveWindow` closes the loop instead — each admission key owns a
controller that watches what its batches actually looked like (EWMA of
inter-arrival gaps and batch occupancy) and retunes the key's window after
every batch:

* **serial trickle** — the smoothed gap exceeds even the largest allowed
  window, so waiting cannot catch a second request: the window decays
  multiplicatively to ``min_delay_s`` (~0 added latency, greedy draining);
* **dense arrivals, low occupancy** — batches close before enough requests
  arrive: the window grows toward the gap-derived target
  ``(target_occupancy * max_batch - 1) * gap``, bounded by ``max_delay_s``;
* **batches close full** — the gap estimate is tiny, so the same target
  shrinks the window back: a saturated key never holds requests longer
  than it takes to fill a batch.

A relative hysteresis dead-band plus bounded multiplicative steps keep the
window from flapping batch-to-batch on noisy arrivals.

:class:`SchedulerSignals` is the packet of live scheduler state (queue
depth, occupancy, per-function tail latency) the platform feeds into
``FusionPolicy.decide`` — the paper's sync-edge counts decide *what* could
fuse; these signals decide *when* a merge is worth the control-plane stall.
"""
from __future__ import annotations

import dataclasses


#: Priority levels for SLO-aware admission. A request submitted at
#: ``PRIORITY_HIGH`` is served ahead of queued normal traffic and closes the
#: current micro-batch window early instead of waiting it out.
PRIORITY_NORMAL = 0
PRIORITY_HIGH = 1


@dataclasses.dataclass(frozen=True)
class SchedulerSignals:
    """Live scheduler state for one (caller, callee) chain, consumed by the
    fusion policy: hot-but-saturated chains deprioritize merges (the stall
    hurts most exactly when batching is already absorbing the load), cold
    chains with long waits promote them."""

    queue_depth: int = 0        # pending requests across the chain's keys
    mean_occupancy: float = 0.0  # mean batch size / max_batch, 0..1
    p95_ms: float = 0.0          # worst per-function p95 latency in the chain


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs for the per-key window controller.

    target_occupancy: fill fraction the controller steers batches toward;
        the window target is the time for that many arrivals at the
        smoothed rate.
    min_delay_s / max_delay_s: hard bounds of the retuned window.
    alpha: EWMA smoothing for arrival gaps and occupancy.
    grow / shrink: bounded multiplicative step per retune.
    hysteresis: relative dead-band — desired values within ±hysteresis of
        the current window leave it untouched (no per-batch flapping).
    floor_s: windows shrinking below this snap to min_delay_s (a
        sub-floor window buys nothing but timer churn).
    """

    target_occupancy: float = 0.75
    min_delay_s: float = 0.0
    max_delay_s: float = 0.020
    alpha: float = 0.3
    grow: float = 1.6
    shrink: float = 0.6
    hysteresis: float = 0.2
    floor_s: float = 0.00025


class AdaptiveWindow:
    """One admission key's window controller. Single-writer: only the key's
    dispatcher thread calls :meth:`observe_batch`; ``snapshot()`` readers see
    torn-free floats under the GIL."""

    def __init__(self, max_batch: int, initial_delay_s: float, config: AdaptiveConfig | None = None):
        self.cfg = config or AdaptiveConfig()
        self.max_batch = max(1, int(max_batch))
        self.delay_s = min(max(float(initial_delay_s), self.cfg.min_delay_s), self.cfg.max_delay_s)
        self.retunes = 0
        self._ewma_gap_s: float | None = None
        self._ewma_intra_s: float | None = None
        self._ewma_occupancy: float | None = None
        self._last_arrival_t: float | None = None

    def reset(self, initial_delay_s: float | None = None) -> None:
        """Forget learned traffic state (benchmark warmup isolation);
        optionally re-seed the window."""
        if initial_delay_s is not None:
            self.delay_s = min(max(float(initial_delay_s), self.cfg.min_delay_s), self.cfg.max_delay_s)
        self._ewma_gap_s = None
        self._ewma_intra_s = None
        self._ewma_occupancy = None
        self._last_arrival_t = None

    def observe_batch(self, arrival_ts: list[float], closed_full: bool) -> float:
        """Feed one closed batch's arrival timestamps; returns the retuned
        window (seconds). Gaps are measured across batch boundaries too, so
        a string of singleton batches still yields a rate estimate."""
        a = self.cfg.alpha
        ts = sorted(arrival_ts)
        gaps = []
        if self._last_arrival_t is not None and ts:
            gaps.append(max(0.0, ts[0] - self._last_arrival_t))
        gaps.extend(t1 - t0 for t0, t1 in zip(ts, ts[1:]))
        if ts:
            self._last_arrival_t = ts[-1]
        for g in gaps:
            self._ewma_gap_s = g if self._ewma_gap_s is None else (1 - a) * self._ewma_gap_s + a * g
            if g < self.cfg.max_delay_s:
                # "catchable" gaps only: the intra-burst spacing estimate that
                # drives idle_close_s — burst-boundary gaps would inflate it
                self._ewma_intra_s = (
                    g if self._ewma_intra_s is None else (1 - a) * self._ewma_intra_s + a * g
                )
        occ = len(ts) / self.max_batch
        self._ewma_occupancy = occ if self._ewma_occupancy is None else (1 - a) * self._ewma_occupancy + a * occ
        new = self._retune(closed_full)
        if new != self.delay_s:
            self.retunes += 1
            self.delay_s = new
        return self.delay_s

    def _retune(self, closed_full: bool) -> float:
        cfg, cur = self.cfg, self.delay_s
        gap = self._ewma_gap_s
        if gap is None:
            return cur
        if gap >= cfg.max_delay_s:
            # trickle: even the longest window can't catch one more arrival
            desired = cfg.min_delay_s
        else:
            # time for (target_occupancy * max_batch) arrivals; the first
            # request opens the window, so one fewer gap
            need = max(0.0, cfg.target_occupancy * self.max_batch - 1.0)
            desired = min(cfg.max_delay_s, max(cfg.min_delay_s, need * gap))
            if (
                desired > cur
                and self._ewma_occupancy is not None
                and self._ewma_occupancy >= cfg.target_occupancy
            ):
                desired = cur  # batches already fill to target: growth buys nothing
        step_floor = cfg.max_delay_s / 32.0
        if desired > cur * (1.0 + cfg.hysteresis):
            return min(desired, max(cur * cfg.grow, step_floor))
        if desired < cur * (1.0 - cfg.hysteresis) or (desired < cur and closed_full):
            new = max(desired, cur * cfg.shrink)
            return cfg.min_delay_s if new < cfg.floor_s else new
        return cur

    def idle_close_s(self) -> float | None:
        """Early-close cutoff for an OPEN window: when no arrival lands
        within ~3 smoothed intra-burst gaps, the burst this window was
        grown for is over — holding the collected requests for the rest of
        the window is pure convoy tax. None until a spacing estimate exists
        (then the window alone governs)."""
        if self._ewma_intra_s is None:
            return None
        return min(self.cfg.max_delay_s, max(3.0 * self._ewma_intra_s, 1e-3))

    def snapshot(self) -> dict:
        idle = self.idle_close_s()
        return {
            "window_ms": self.delay_s * 1e3,
            "ewma_gap_ms": (self._ewma_gap_s or 0.0) * 1e3,
            "ewma_occupancy": self._ewma_occupancy or 0.0,
            "idle_close_ms": (idle or 0.0) * 1e3,
            "retunes": self.retunes,
        }
