"""Per-key admission queue with a micro-batching coalescer thread.

Each (function, request-shape) key owns one queue and one dispatcher thread.
The dispatcher blocks for the first request, then keeps the batch open for up
to ``max_delay_s`` past that first arrival (ProFaaStinate's "briefly delay to
group" window), closing early when ``max_batch`` requests have been admitted.
With ``max_delay_s == 0`` the window degenerates to greedy draining: whatever
is already queued rides along, nothing waits — batching then costs zero added
latency under bursty load and the scheduler behaves like serial dispatch when
requests trickle in one at a time.

A dispatcher that sees no traffic for ``idle_timeout_s`` offers itself back
via ``on_idle`` (the scheduler drops the queue under its lock unless a
request raced in) and exits — shape-diverse workloads don't leak threads.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable


@dataclasses.dataclass
class PendingRequest:
    args: tuple
    future: Future
    t_enqueue: float


_STOP = object()


class AdmissionQueue:
    """One key's queue + dispatcher. ``dispatch`` receives (name, [args...])
    and must return one result per request, in order."""

    def __init__(
        self,
        name: str,
        dispatch: Callable[[str, list[tuple]], list],
        *,
        key: tuple = (),
        max_batch: int,
        max_delay_s: float,
        idle_timeout_s: float = 60.0,
        on_batch_done: Callable[[str, list[PendingRequest], float], None] | None = None,
        on_idle: Callable[["AdmissionQueue"], bool] | None = None,
    ):
        self.name = name
        self.key = key
        self._dispatch = dispatch
        self.max_batch = max(1, int(max_batch))
        self.max_delay_s = max(0.0, float(max_delay_s))
        self.idle_timeout_s = idle_timeout_s
        self._on_batch_done = on_batch_done
        self._on_idle = on_idle
        self._q: "queue.Queue" = queue.Queue()
        self.thread = threading.Thread(target=self._loop, daemon=True, name=f"coalesce-{name}")
        self.thread.start()

    def put(self, req: PendingRequest) -> None:
        self._q.put(req)

    def empty(self) -> bool:
        return self._q.empty()

    def stop(self) -> None:
        self._q.put(_STOP)

    # ------------------------------------------------------------- internals

    def _collect(self, first: PendingRequest) -> tuple[list[PendingRequest], bool]:
        """Admit up to max_batch requests within max_delay_s of the first."""
        batch = [first]
        deadline = time.perf_counter() + self.max_delay_s
        stopped = False
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                if remaining > 0:
                    item = self._q.get(timeout=remaining)
                else:
                    item = self._q.get_nowait()  # window closed: drain only
            except queue.Empty:
                break
            if item is _STOP:
                stopped = True
                break
            batch.append(item)
        return batch, stopped

    def _loop(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=self.idle_timeout_s)
            except queue.Empty:
                # idle: ask the scheduler to retire us; a concurrent submit
                # makes it refuse, and we keep serving
                if self._on_idle is not None and self._on_idle(self):
                    return
                continue
            if item is _STOP:
                return
            batch, stopped = self._collect(item)
            self._run_batch(batch)
            if stopped:
                return

    def _run_batch(self, batch: list[PendingRequest]) -> None:
        try:
            results = self._dispatch(self.name, [r.args for r in batch])
            if len(results) != len(batch):
                raise RuntimeError(
                    f"batched dispatch for {self.name!r} returned {len(results)} "
                    f"results for {len(batch)} requests"
                )
        except BaseException as exc:  # noqa: BLE001 — every caller must hear about it
            for r in batch:
                _resolve(r.future, exc=exc)
        else:
            t_done = time.perf_counter()
            if self._on_batch_done is not None:
                self._on_batch_done(self.name, batch, t_done)
            for r, out in zip(batch, results):
                _resolve(r.future, result=out)


def _resolve(future: Future, *, result=None, exc=None) -> None:
    """Deliver to a future that the client may have cancelled meanwhile —
    an InvalidStateError must not kill the dispatcher thread (it would
    orphan the rest of the batch and permanently hang the key's queue)."""
    try:
        if exc is not None:
            future.set_exception(exc)
        else:
            future.set_result(result)
    except Exception:
        if not future.cancelled():
            raise
