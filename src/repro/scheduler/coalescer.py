"""Per-key admission queue with a micro-batching coalescer thread.

Each (function, request-shape) key owns one queue and one dispatcher thread.
The dispatcher blocks for the first request, then keeps the batch open for up
to ``max_delay_s`` past that first arrival (ProFaaStinate's "briefly delay to
group" window), closing early when ``max_batch`` requests have been admitted.
With ``max_delay_s == 0`` the window degenerates to greedy draining: whatever
is already queued rides along, nothing waits — batching then costs zero added
latency under bursty load and the scheduler behaves like serial dispatch when
requests trickle in one at a time.

Admission is a two-level priority queue: requests submitted at
``PRIORITY_HIGH`` are popped ahead of queued normal traffic, and their
arrival *closes the window early* — an SLO-bound request never waits out a
batching delay tuned for throughput. With an :class:`AdaptiveWindow`
attached, the dispatcher feeds every closed batch back to the controller and
picks up the retuned ``max_delay_s`` for the next window.

A dispatcher that sees no traffic for ``idle_timeout_s`` offers itself back
via ``on_idle`` (the scheduler drops the queue under its lock unless a
request raced in) and exits — shape-diverse workloads don't leak threads.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable

from repro.scheduler.adaptive import AdaptiveWindow


@dataclasses.dataclass
class PendingRequest:
    args: tuple
    future: Future
    t_enqueue: float
    priority: int = 0


_STOP = object()
#: Sort key priority for the stop sentinel: below every real request, so a
#: shutdown drains already-admitted traffic before the dispatcher exits.
_STOP_PRIORITY = -1


class AdmissionQueue:
    """One key's queue + dispatcher. ``dispatch`` receives (name, [args...])
    and must return one result per request, in order."""

    def __init__(
        self,
        name: str,
        dispatch: Callable[[str, list[tuple]], list],
        *,
        key: tuple = (),
        max_batch: int,
        max_delay_s: float,
        idle_timeout_s: float = 60.0,
        adaptive: AdaptiveWindow | None = None,
        on_batch_done: Callable[[str, list[PendingRequest], float], None] | None = None,
        on_idle: Callable[["AdmissionQueue"], bool] | None = None,
    ):
        self.name = name
        self.key = key
        self._dispatch = dispatch
        self.max_batch = max(1, int(max_batch))
        self.max_delay_s = max(0.0, float(max_delay_s))
        self.idle_timeout_s = idle_timeout_s
        self.adaptive = adaptive
        self._on_batch_done = on_batch_done
        self._on_idle = on_idle
        # Two-level admission: entries order by (-priority, seq) — high
        # priority first, FIFO within a level. The seq tiebreak is unique, so
        # comparison never reaches the (uncomparable) PendingRequest payload.
        self._q: "queue.PriorityQueue" = queue.PriorityQueue()
        self._seq = itertools.count()
        self.thread = threading.Thread(target=self._loop, daemon=True, name=f"coalesce-{name}")
        self.thread.start()

    def put(self, req: PendingRequest) -> None:
        self._q.put((-req.priority, next(self._seq), req))

    def empty(self) -> bool:
        return self._q.empty()

    def depth(self) -> int:
        return self._q.qsize()

    def stop(self) -> None:
        self._q.put((-_STOP_PRIORITY, next(self._seq), _STOP))

    # ------------------------------------------------------------- internals

    def _collect(self, first: PendingRequest) -> tuple[list[PendingRequest], bool]:
        """Admit up to max_batch requests within max_delay_s of the first.
        A high-priority request — leading or admitted mid-window — closes
        the window immediately: the already-collected batch dispatches now."""
        batch = [first]
        delay = 0.0 if first.priority > 0 else self.max_delay_s
        deadline = time.perf_counter() + delay
        stopped = False
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            timeout = remaining
            if self.adaptive is not None and timeout > 0:
                # idle-close: a grown window is for catching a burst in
                # flight; once arrivals pause longer than the smoothed
                # intra-burst spacing allows, waiting out the rest of the
                # window just convoys the collected requests
                idle_cut = self.adaptive.idle_close_s()
                if idle_cut is not None and idle_cut < timeout:
                    timeout = idle_cut
            try:
                if timeout > 0:
                    item = self._q.get(timeout=timeout)[2]
                else:
                    item = self._q.get_nowait()[2]  # window closed: drain only
            except queue.Empty:
                break  # window expired or burst went quiet: serve the batch
            if item is _STOP:
                stopped = True
                break
            batch.append(item)
            if item.priority > 0:
                # SLO early close: stop WAITING. The deadline collapses to
                # now, so already-queued requests still drain in (free
                # batching) but nothing holds the urgent request further.
                deadline = time.perf_counter()
        return batch, stopped

    def _loop(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=self.idle_timeout_s)[2]
            except queue.Empty:
                # idle: ask the scheduler to retire us; a concurrent submit
                # makes it refuse, and we keep serving
                if self._on_idle is not None and self._on_idle(self):
                    return
                continue
            if item is _STOP:
                return
            batch, stopped = self._collect(item)
            if self.adaptive is not None:
                self.max_delay_s = self.adaptive.observe_batch(
                    [r.t_enqueue for r in batch], len(batch) >= self.max_batch
                )
            self._run_batch(batch)
            if stopped:
                return

    def _run_batch(self, batch: list[PendingRequest]) -> None:
        try:
            results = self._dispatch(self.name, [r.args for r in batch])
            if len(results) != len(batch):
                raise RuntimeError(
                    f"batched dispatch for {self.name!r} returned {len(results)} "
                    f"results for {len(batch)} requests"
                )
        except BaseException as exc:  # noqa: BLE001 — every caller must hear about it
            for r in batch:
                _resolve(r.future, exc=exc)
        else:
            t_done = time.perf_counter()
            # Futures FIRST, metrics second: a raising metrics sink must
            # never strand a batch of clients blocked on unresolved futures.
            for r, out in zip(batch, results):
                _resolve(r.future, result=out)
            if self._on_batch_done is not None:
                try:
                    self._on_batch_done(self.name, batch, t_done)
                except Exception:  # noqa: BLE001 — observability is best-effort
                    pass


def _resolve(future: Future, *, result=None, exc=None) -> None:
    """Deliver to a future that the client may have cancelled meanwhile —
    an InvalidStateError must not kill the dispatcher thread (it would
    orphan the rest of the batch and permanently hang the key's queue)."""
    try:
        if exc is not None:
            future.set_exception(exc)
        else:
            future.set_result(result)
    except Exception:
        if not future.cancelled():
            raise
