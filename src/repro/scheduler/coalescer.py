"""Per-(function, shape, class) admission lane with a micro-batching
coalescer thread.

Each (function, request-shape, SLO-class) key owns one queue and one
dispatcher thread. The dispatcher blocks for the first request, then keeps
the batch open for up to the lane's window past that first arrival
(ProFaaStinate's "briefly delay to group", with the window set per class by
the queueing-model controller — see :mod:`repro.scheduler.adaptive`),
closing early when ``max_batch`` requests have been admitted, when the
burst goes quiet (idle-close), or when a *preempt* lands. With a zero
window the lane degenerates to greedy draining: whatever is already queued
rides along, nothing waits.

Batches are single-class by construction — the class is part of the queue
key — so a strict request can never be convoyed by best-effort traffic.
Cross-class coupling happens through exactly one mechanism:
:meth:`AdmissionQueue.preempt_window`, called by the scheduler when a
strictly tighter-class request arrives for the same (function, shape). It
*preempts the in-flight coalesce timer*: the dispatcher parked on the
window wait wakes immediately, closes the window, and dispatches what it
has, so neither the urgent request (behind the platform's dispatch path)
nor the already-collected batch waits out a residual throughput window.
The preempt is edge-triggered and only armed while a window is actually
open — a preempt with no window in flight must not shorten the NEXT
window (regression-tested).

All blocking goes through the injected :class:`Clock`, which is what makes
every window/idle/priority behavior testable on a virtual clock with zero
real sleeps.

A dispatcher that sees no traffic for ``idle_timeout_s`` offers itself back
via ``on_idle`` (the scheduler drops the queue under its lock unless a
request raced in) and exits — shape-diverse workloads don't leak threads.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
from concurrent.futures import Future
from typing import Callable

from repro.scheduler.adaptive import QueueingWindow
from repro.scheduler.clock import SYSTEM_CLOCK, SystemClock
from repro.scheduler.slo import BEST_EFFORT, SLOClass


@dataclasses.dataclass
class PendingRequest:
    args: tuple
    future: Future
    t_enqueue: float
    # the admission class carries ALL priority semantics: lane selection,
    # window length, and cross-lane preemption (the old integer priority
    # field became write-only after the class-lane redesign and was removed)
    slo: SLOClass = BEST_EFFORT
    # per-request trace handle (obs.SpanContext) — None when tracing is off
    # or the caller predates the tracing layer; duck-typed so the scheduler
    # layer stays import-free of obs
    span: object = None


class AdmissionQueue:
    """One (function, shape, class) lane: queue + dispatcher. ``dispatch``
    receives (name, [args...]) and must return one result per request, in
    order."""

    GUARDED_FIELDS = {
        "_items": "_cv",
        "_stopped": "_cv",
        "_window_open": "_cv",
        "_preempted": "_cv",
    }

    def __init__(
        self,
        name: str,
        dispatch: Callable[[str, list[tuple]], list],
        *,
        key: tuple = (),
        max_batch: int,
        max_delay_s: float,
        idle_timeout_s: float = 60.0,
        slo: SLOClass = BEST_EFFORT,
        adaptive: QueueingWindow | None = None,
        on_batch_done: Callable[[str, list[PendingRequest], float], None] | None = None,
        on_idle: Callable[["AdmissionQueue"], bool] | None = None,
        clock: SystemClock | None = None,
        tracer=None,
    ):
        self.name = name
        self.key = key
        self.slo = slo
        self._tracer = tracer
        # window-open timestamp of the batch being collected; written and
        # read only by the single dispatcher thread
        self._t_open = 0.0
        self._dispatch = dispatch
        self.max_batch = max(1, int(max_batch))
        self.max_delay_s = max(0.0, float(max_delay_s))
        self.idle_timeout_s = idle_timeout_s
        self.adaptive = adaptive
        self.clock = clock or SYSTEM_CLOCK
        self._on_batch_done = on_batch_done
        self._on_idle = on_idle
        # One condition guards the lane state: items, stop flag, and the
        # window bookkeeping (open flag + preempt latch). Lock ordering is
        # scheduler._lock -> this cv (submit/stop hold the scheduler lock
        # while putting); the dispatcher NEVER takes the scheduler lock
        # while holding the cv (on_idle / on_batch_done run outside it).
        self._cv = threading.Condition()
        self._items: collections.deque[PendingRequest] = collections.deque()
        self._stopped = False
        self._window_open = False
        self._preempted = False
        self.thread = threading.Thread(target=self._loop, daemon=True, name=f"coalesce-{name}")
        self.thread.start()

    # ----------------------------------------------------------------- API

    def put(self, req: PendingRequest) -> None:
        with self._cv:
            self._items.append(req)
            self._cv.notify_all()

    def preempt_window(self) -> bool:
        """Close the currently open batching window, if any: the dispatcher
        parked on the window timer wakes and dispatches what it has
        collected NOW. Edge-triggered and armed only while a window is
        open — calling this on an idle lane is a no-op (the next window
        must open at full length). Returns whether a window was preempted."""
        with self._cv:
            if not self._window_open:
                return False
            self._preempted = True
            self._cv.notify_all()
            return True

    def empty(self) -> bool:
        with self._cv:
            return not self._items

    def depth(self) -> int:
        with self._cv:
            return len(self._items)

    def stop(self) -> None:
        """Stop after draining already-admitted traffic (a queued request
        must never be stranded behind a shutdown)."""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()

    # ------------------------------------------------------------- internals

    def _collect(self, first: PendingRequest) -> tuple[list[PendingRequest], bool]:
        """Admit up to max_batch requests within the lane's window of the
        first arrival. The window closes early on: max_batch reached, stop,
        idle-close (burst went quiet), or a cross-lane preempt (a tighter
        class arrived on this function+shape)."""
        clock = self.clock
        batch = [first]
        self._t_open = clock.now()
        deadline = self._t_open + self.max_delay_s
        stopped = False
        with self._cv:
            self._window_open = True
            self._preempted = False
            try:
                while len(batch) < self.max_batch:
                    while self._items and len(batch) < self.max_batch:
                        batch.append(self._items.popleft())
                    if len(batch) >= self.max_batch:
                        break
                    if self._stopped:
                        stopped = True
                        break
                    if self._preempted:
                        self._preempted = False
                        break  # tighter-class arrival: dispatch what we have
                    remaining = deadline - clock.now()
                    if remaining <= 0:
                        break  # window expired: serve the batch
                    timeout = remaining
                    if self.adaptive is not None:
                        # idle-close: a grown window is for catching a burst
                        # in flight; once arrivals pause longer than the
                        # smoothed intra-burst spacing allows, waiting out
                        # the rest of the window just convoys the batch
                        idle_cut = self.adaptive.idle_close_s()
                        if idle_cut is not None and idle_cut < timeout:
                            timeout = idle_cut
                    woke_at = clock.now()
                    clock.wait_on(self._cv, timeout)
                    if not self._items and self.adaptive is not None:
                        idle_cut = self.adaptive.idle_close_s()
                        if idle_cut is not None and clock.now() - woke_at >= idle_cut:
                            break  # burst went quiet: serve the batch
            finally:
                self._window_open = False
                self._preempted = False
        return batch, stopped

    def _loop(self) -> None:
        clock = self.clock
        while True:
            first = None
            with self._cv:
                idle_deadline = clock.now() + self.idle_timeout_s
                while not self._items:
                    if self._stopped:
                        return
                    remaining = idle_deadline - clock.now()
                    if remaining <= 0:
                        break
                    clock.wait_on(self._cv, remaining)
                if self._items:
                    first = self._items.popleft()
            if first is None:
                # idle: ask the scheduler to retire us (outside the cv — the
                # retire path re-enters empty()); a concurrent submit makes
                # it refuse, and we keep serving
                if self._on_idle is not None and self._on_idle(self):
                    return
                continue
            batch, stopped = self._collect(first)
            self._run_batch(batch)
            if stopped:
                with self._cv:
                    if not self._items:
                        return
                # stop raced new work in: keep draining (stop() is only
                # called under the scheduler lock after _closed is set, so
                # this tail is bounded)

    def _run_batch(self, batch: list[PendingRequest]) -> None:
        clock = self.clock
        t_exec = clock.now()
        # The batched dispatch gets its OWN trace (activated for the
        # duration so spans minted during execution — handler enters,
        # cross-function hops, resurrects — nest under it); each member
        # request's trace gets exact [enqueue, window-open, dispatch, done]
        # phase tiles referencing the batch trace, so per-request
        # attribution never double-counts the shared execution.
        tracer = self._tracer
        bctx = None
        if tracer is not None and any(r.span is not None for r in batch):
            bctx = tracer.begin_request(
                f"batch:{self.name}", "batch", t0=t_exec,
                attrs={
                    "lane": self.name,
                    "size": len(batch),
                    "slo": self.slo.name,
                    "members": [r.span.trace_id for r in batch if r.span is not None],
                },
            )
        activation = (tracer.activate(bctx) if tracer is not None
                      else contextlib.nullcontext())
        try:
            with activation:
                results = self._dispatch(self.name, [r.args for r in batch])
            if len(results) != len(batch):
                raise RuntimeError(
                    f"batched dispatch for {self.name!r} returned {len(results)} "
                    f"results for {len(batch)} requests"
                )
        except BaseException as exc:  # noqa: BLE001 — every caller must hear about it
            for r in batch:
                _resolve(r.future, exc=exc)
            t_fail = clock.now()
            service_s = t_fail - t_exec
            self._emit_phases(batch, t_exec, t_fail, bctx, error=type(exc).__name__)
        else:
            t_done = clock.now()
            service_s = t_done - t_exec
            # Futures FIRST, metrics second: a raising metrics sink must
            # never strand a batch of clients blocked on unresolved futures.
            for r, out in zip(batch, results):
                _resolve(r.future, result=out)
            if self._on_batch_done is not None:
                try:
                    self._on_batch_done(self.name, batch, t_done)
                except Exception:  # noqa: BLE001 — observability is best-effort
                    pass
            self._emit_phases(batch, t_exec, t_done, bctx)
        if self.adaptive is not None:
            # fed AFTER dispatch so the controller's service EWMA sees the
            # measured batch wall time (the queueing model's S)
            self.max_delay_s = self.adaptive.observe_batch(
                [r.t_enqueue for r in batch],
                len(batch) >= self.max_batch,
                service_s=service_s,
            )

    def _emit_phases(self, batch: list[PendingRequest], t_exec: float,
                     t_done: float, bctx, error: str | None = None) -> None:
        """Tile each traced member's wall interval exactly: queue-wait
        [enqueue, window-open], window-wait [open, dispatch], batch-compute
        [dispatch, done] — their sum IS the request's end-to-end latency
        (the conservation invariant the obs tests pin)."""
        t_open = self._t_open
        err_args = {"error": error} if error else None
        for r in batch:
            span = r.span
            if span is None:
                continue
            open_r = min(max(t_open, r.t_enqueue), t_exec)
            span.emit("queue-wait", "queue-wait", r.t_enqueue, open_r)
            span.emit("window-wait", "window-wait", open_r, t_exec)
            cargs = {"size": len(batch)}
            if bctx is not None:
                cargs["batch_trace"] = bctx.trace_id
            if error:
                cargs["error"] = error
            span.emit("batch-compute", "batch-compute", t_exec, t_done, args=cargs)
            span.finish(t_done, args=err_args)
        if bctx is not None:
            bctx.finish(t_done, args=err_args)


def _resolve(future: Future, *, result=None, exc=None) -> None:
    """Deliver to a future that the client may have cancelled meanwhile —
    an InvalidStateError must not kill the dispatcher thread (it would
    orphan the rest of the batch and permanently hang the key's queue)."""
    try:
        if exc is not None:
            future.set_exception(exc)
        else:
            future.set_result(result)
    except Exception:
        if not future.cancelled():
            raise
