"""RequestScheduler: the front door for concurrent invocations.

``submit(name, args)`` returns a Future immediately; behind it, requests are
routed to a per-(function, shape) :class:`AdmissionQueue` whose coalescer
groups them into micro-batches and hands each batch to the platform's batched
dispatch path. The scheduler is backend-agnostic — it only knows the dispatch
callable — and tracks end-to-end (admission -> completion) latency per
request plus batch-size occupancy, the numbers `stats()` reports as
p50/p95/p99 and throughput.

Queue lifecycle: dispatcher threads are created lazily on a key's first
request and retire themselves after ``idle_timeout_s`` without traffic, so
shape-diverse workloads don't accumulate idle threads. All queue-map
mutations (submit, retire, shutdown) serialize on one lock — a request can
never be enqueued behind a stop sentinel or into a retired queue.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from typing import Callable

from repro.scheduler.batching import request_key
from repro.scheduler.coalescer import AdmissionQueue, PendingRequest
from repro.scheduler.metrics import LatencyWindow, percentiles_ms  # noqa: F401 — re-exported

_BATCH_WINDOW = 200_000  # bounded batch-size history


class RequestScheduler:
    def __init__(
        self,
        dispatch_batch: Callable[[str, list[tuple]], list],
        *,
        max_batch: int = 8,
        max_delay_ms: float = 2.0,
        idle_timeout_s: float = 60.0,
        on_request_done: Callable[[str, float, int], None] | None = None,
    ):
        self._dispatch = dispatch_batch
        self.max_batch = max(1, int(max_batch))
        self.max_delay_s = max(0.0, float(max_delay_ms)) / 1e3
        self.idle_timeout_s = idle_timeout_s
        self._on_request_done = on_request_done
        self._queues: dict[tuple, AdmissionQueue] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._latency = LatencyWindow()
        self._batch_sizes: collections.deque = collections.deque(maxlen=_BATCH_WINDOW)
        self._batches = 0

    # ----------------------------------------------------------------- API

    def submit(self, name: str, args: tuple) -> Future:
        req = PendingRequest(args, Future(), time.perf_counter())
        key = request_key(name, args)
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is shut down")
            q = self._queues.get(key)
            if q is None:
                q = AdmissionQueue(
                    name,
                    self._dispatch,
                    key=key,
                    max_batch=self.max_batch,
                    max_delay_s=self.max_delay_s,
                    idle_timeout_s=self.idle_timeout_s,
                    on_batch_done=self._record_batch,
                    on_idle=self._retire_queue,
                )
                self._queues[key] = q
            q.put(req)  # same lock as retire/shutdown: never lands post-stop
        return req.future

    def shutdown(self, timeout: float = 10.0) -> None:
        with self._lock:
            self._closed = True
            queues = list(self._queues.values())
            for q in queues:
                q.stop()
        for q in queues:
            q.thread.join(timeout)

    # ------------------------------------------------------------ lifecycle

    def _retire_queue(self, q: AdmissionQueue) -> bool:
        """Idle-timeout callback from a dispatcher thread: drop the queue if
        no request snuck in; the dispatcher exits on True."""
        with self._lock:
            if not q.empty():
                return False
            if self._queues.get(q.key) is q:
                del self._queues[q.key]
            return True

    # ------------------------------------------------------------- metrics

    def _record_batch(self, name: str, batch: list[PendingRequest], t_done: float) -> None:
        k = len(batch)
        with self._lock:
            self._batch_sizes.append(k)
            self._batches += 1
        for r in batch:
            self._latency.observe(t_done - r.t_enqueue, t_done)
            if self._on_request_done is not None:
                self._on_request_done(name, t_done - r.t_enqueue, k)

    def stats(self) -> dict:
        with self._lock:
            sizes = list(self._batch_sizes)
            batches = self._batches
            n_keys = len(self._queues)
        out = self._latency.snapshot()
        out.update(
            {
                "batches": batches,
                "queues": n_keys,
                "mean_batch": (sum(sizes) / len(sizes)) if sizes else 0.0,
                "max_batch_seen": max(sizes) if sizes else 0,
            }
        )
        return out
