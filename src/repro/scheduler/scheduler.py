"""RequestScheduler: the front door for concurrent invocations.

``submit(name, args)`` returns a Future immediately; behind it, requests are
routed to a per-(function, shape, SLO-class) :class:`AdmissionQueue` whose
coalescer groups them into micro-batches and hands each batch to the
platform's batched dispatch path. The scheduler is backend-agnostic — it
only knows the dispatch callable — and tracks end-to-end (admission ->
completion) latency per request plus batch-size occupancy, the numbers
`stats()` reports as p50/p95/p99 and throughput.

Admission classes: ``submit(..., slo=SLOClass(name, target_p95_ms))`` keys
the request into its class's own lane — batches never mix classes — and
each lane's window comes from the queueing-model controller
(:class:`QueueingWindow`): best-effort lanes tune for occupancy, strict
lanes spend their target's modeled slack on batching and degrade to greedy
FIFO when load eats it. A strict-class arrival *preempts* open windows of
looser classes on the same (function, shape) — the in-flight coalesce
timer is closed immediately, never waited out (see
``AdmissionQueue.preempt_window``). The PR 2 two-level API still works:
``priority=PRIORITY_HIGH`` maps to the zero-target ``IMMEDIATE`` class.

The scheduler is also a *signal source* for the fusion policy:
``signals_for(names)`` snapshots queue depth, mean batch occupancy, the
worst per-function p95 across a chain, and per-class tails vs their targets
— the live feedback that decides whether a merge's control-plane stall is
worth paying right now, and whether a committed merge is violating a
class's target (fission regret).

Every timing operation goes through the injected :class:`Clock`
(``clock=None`` = wall clock), so windows, idle timeouts, quiesce barriers,
and trough detection are all drivable by a deterministic virtual clock in
tests — no real sleeps.

Queue lifecycle: dispatcher threads are created lazily on a key's first
request and retire themselves after ``idle_timeout_s`` without traffic, so
shape-diverse workloads don't accumulate idle threads. All queue-map
mutations (submit, retire, shutdown) serialize on one lock — a request can
never be enqueued behind a stop flag or into a retired queue.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
from concurrent.futures import Future
from typing import Callable

from repro.analysis.guards import guarded_by
from repro.scheduler.adaptive import (
    AdaptiveConfig,
    QueueingWindow,
    SchedulerSignals,
    ServiceTimeEstimate,
    static_window_s,
)
from repro.scheduler.batching import largest_pow2_le, request_key
from repro.scheduler.clock import SYSTEM_CLOCK
from repro.scheduler.coalescer import AdmissionQueue, PendingRequest
from repro.scheduler.metrics import LatencyWindow, percentiles_ms  # noqa: F401 — re-exported
from repro.scheduler.slo import SLOClass, slo_for_priority

_BATCH_WINDOW = 200_000  # bounded batch-size history
_PER_NAME_WINDOW = 8_192  # per-function latency history (tail estimate only)
_PER_CLASS_WINDOW = 8_192  # per-class latency history (SLO conformance)
_RECENT_BATCHES = 256  # per-function recent batch sizes: the "right now"
# occupancy the fusion policy's saturation guard keys on — an all-time
# average would stay cold for hours after traffic actually saturates
_SIGNALS_TTL_S = 0.05  # signals_for memo: a hot unfused edge asks on every
# sync observation; sorting the latency window per request would put an
# O(n log n) snapshot on the data path for a control-plane answer
_RECENT_LATS = 1024  # per-function (t_done, latency) pairs: the fission
# regret check compares post-merge tails against a pre-merge baseline, so it
# needs a p95 over the trailing seconds, not over the whole 8k-sample window
_CLASS_SIGNAL_WINDOW_S = 5.0  # lookback for the per-class tails handed to
# the fusion policy: SLO regret must see whether a class is violated NOW —
# an all-time window would keep reporting a long-recovered burst for
# thousands of samples (same discipline as recent_p95_ms)


class OverloadShedError(RuntimeError):
    """Best-effort request rejected at admission: the function's predicted
    offered load is at/over its batched capacity (rho >= 1) and the
    best-effort backlog already holds its bound — queueing more background
    traffic would only push strict classes toward misses. Fail fast so the
    client can back off."""


class RequestScheduler:
    # provlint: _cond is Condition(self._lock), so holding either counts.
    GUARDED_FIELDS = {
        "_queues": "_lock",
        "_lanes_by_base": "_lock",
        "_queues_by_name": "_lock",
        "_shed": "_lock",
        "_strict_fns": "_lock",
        "_slo_classes": "_lock",
        "_inflight": "_lock",
        "_per_name": "_lock",
        "_per_class": "_lock",
        "_recent_class_lats": "_lock",
        "_recent_by_name": "_lock",
        "_recent_lat_by_name": "_lock",
        "_batch_sizes": "_lock",
        "_batches": "_lock",
        "_signals_cache": "_lock",
        "_last_strict_submit_t": "_lock",
        "_closed": "_lock",
        "_service_by_fn": "_lock",
    }

    def __init__(
        self,
        dispatch_batch: Callable[[str, list[tuple]], list],
        *,
        max_batch: int = 8,
        max_delay_ms: float = 2.0,
        idle_timeout_s: float = 60.0,
        adaptive: bool = False,
        adaptive_config: AdaptiveConfig | None = None,
        on_request_done: Callable[[str, float, int], None] | None = None,
        be_shed_depth: int | None = None,
        clock=None,
        tracer=None,
    ):
        self._dispatch = dispatch_batch
        self.clock = clock or SYSTEM_CLOCK
        # obs.Tracer (duck-typed; scheduler stays import-free of obs): when
        # present, every submit mints a trace rooted at its enqueue time
        self._tracer = tracer
        # clamp to the largest power of two <= max_batch: the coalescer then
        # never forms a batch the pow2 bucket set can't serve in one
        # execution (a batch of 6 against buckets {1,2,4} would dispatch
        # twice, forever — worse than the one-off compile it avoids)
        self.max_batch = largest_pow2_le(max_batch)
        self.max_delay_s = max(0.0, float(max_delay_ms)) / 1e3
        self.idle_timeout_s = idle_timeout_s
        self.adaptive = bool(adaptive) or adaptive_config is not None
        if self.adaptive and adaptive_config is None:
            adaptive_config = AdaptiveConfig()
            if self.max_delay_s > adaptive_config.max_delay_s / 2:
                # a seed near/above the default cap must not be silently
                # clamped — leave headroom to grow past what was asked for
                adaptive_config = dataclasses.replace(
                    adaptive_config, max_delay_s=2.0 * self.max_delay_s
                )
        self.adaptive_config = adaptive_config
        self._on_request_done = on_request_done
        # Per-class overload shedding: when a function's predicted rho >= 1
        # (offered load at/over batched capacity, from the shared service
        # estimate), best-effort arrivals beyond this many queued requests
        # per function are failed fast instead of admitted — background
        # backlog must not grow without bound while strict classes fight
        # for the same capacity. None = auto (2 x max_batch). Armed ONLY for
        # functions that have seen strict-class traffic: shedding exists to
        # protect deadlines, and an all-best-effort overload is the fission
        # path's job (the churn scenario saturates on purpose). Only
        # adaptive schedulers shed (the rho estimate needs the controllers).
        self.be_shed_depth = be_shed_depth if be_shed_depth is not None else 2 * self.max_batch
        self._shed: dict[str, int] = {}
        self._strict_fns: set[str] = set()
        # one batch-service-time estimate per FUNCTION, shared by all of its
        # class lanes — a new lane starts with a warm M/G/1 model instead of
        # cold-starting its service EWMA (see ServiceTimeEstimate)
        self._service_by_fn: dict[str, ServiceTimeEstimate] = {}
        self._queues: dict[tuple, AdmissionQueue] = {}
        self._lock = threading.Lock()
        # Drain-barrier state: per-function in-flight batch counts, signalled
        # on completion so the control plane's quiesce() can wait for an
        # epoch's affected traffic to clear without polling the data path.
        self._cond = threading.Condition(self._lock)
        self._inflight: dict[str, int] = {}
        self._dispatch_tls = threading.local()  # name this thread is dispatching
        # Only strict-class (finite-target) arrivals are tracked for the
        # trough detector — a best-effort trickle has no deadline a
        # control-plane stall could violate, and letting it block troughs
        # kept deferred merges pinned behind low-priority background
        # traffic (the PR 3 reconciler's failure mode).
        self._last_strict_submit_t: float | None = None
        self._closed = False
        self._latency = LatencyWindow()
        self._per_name: dict[str, LatencyWindow] = {}
        self._per_class: dict[str, LatencyWindow] = {}
        # (function, class) -> recent (t_done, latency) pairs, kept ONLY for
        # classes with a finite positive target (the ones the policy can act
        # on): the signals' per-class p95 is computed over a trailing time
        # window, never all-time
        self._recent_class_lats: dict[tuple[str, str], collections.deque] = {}
        self._slo_classes: dict[str, SLOClass] = {}
        # (function, shape) base key -> lanes, so a strict submit preempts
        # its siblings without scanning every queue under the global lock
        self._lanes_by_base: dict[tuple, list[AdmissionQueue]] = {}
        # function -> lanes, so the shed check and rho prediction stay
        # O(lanes of this function) on the hot admission path
        self._queues_by_name: dict[str, list[AdmissionQueue]] = {}
        self._recent_by_name: dict[str, collections.deque] = {}
        self._recent_lat_by_name: dict[str, collections.deque] = {}
        self._batch_sizes: collections.deque = collections.deque(maxlen=_BATCH_WINDOW)
        self._batches = 0
        self._signals_cache: dict[tuple, tuple[float, SchedulerSignals]] = {}

    # ----------------------------------------------------------------- API

    def submit(
        self,
        name: str,
        args: tuple,
        *,
        priority: int = 0,
        slo: SLOClass | None = None,
    ) -> Future:
        """Admit one request. ``slo`` selects the admission class (defaults
        to best-effort; ``priority=PRIORITY_HIGH`` is the two-level shim for
        the zero-target class). Returns the request's Future."""
        if slo is None:
            slo = slo_for_priority(priority)
        elif priority > 0 and slo.best_effort:
            slo = slo_for_priority(priority)
        req = PendingRequest(args, Future(), self.clock.now(), slo=slo)
        if self._tracer is not None:
            req.span = self._tracer.begin_request(
                name, "invoke_async", t0=req.t_enqueue, attrs={"slo": slo.name})
        key = request_key(name, args, slo.name)
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is shut down")
            known = self._slo_classes.get(slo.name)
            if known is not None and known.target_p95_ms != slo.target_p95_ms:
                raise ValueError(
                    f"SLO class {slo.name!r} redefined: target "
                    f"{slo.target_p95_ms} != {known.target_p95_ms}"
                )
            self._slo_classes[slo.name] = slo
            if slo.best_effort and self.adaptive and name in self._strict_fns:
                # overload shedding: with the function predicted past its
                # batched capacity, bound the best-effort backlog and fail
                # fast past it — strict classes keep admitting. Armed only
                # once the function serves strict traffic (see __init__).
                be_depth = sum(
                    lane.depth()
                    for lane in self._queues_by_name.get(name, ())
                    if lane.slo.best_effort
                )
                if be_depth >= self.be_shed_depth and self._predicted_rho_locked(name) >= 1.0:
                    self._shed[slo.name] = self._shed.get(slo.name, 0) + 1
                    req.future.set_exception(OverloadShedError(
                        f"{name}: predicted rho >= 1 with {be_depth} best-effort "
                        f"queued (bound {self.be_shed_depth})"
                    ))
                    if req.span is not None:
                        req.span.finish(args={"error": "shed"})
                    return req.future
            if not slo.best_effort:
                self._last_strict_submit_t = req.t_enqueue
                self._strict_fns.add(name)
            q = self._queues.get(key)
            if q is None:
                q = self._make_queue(name, key, slo)
                self._queues[key] = q
                self._lanes_by_base.setdefault(key[:-1], []).append(q)
                self._queues_by_name.setdefault(name, []).append(q)
            q.put(req)  # same lock as retire/shutdown: never lands post-stop
            if not slo.best_effort:
                # Early-close preemption: a strict arrival must never leave
                # sibling lanes' open throughput windows running their full
                # residual delay — the platform is about to serve urgent
                # traffic, so collected batches dispatch now. Preempting the
                # in-flight coalesce timer (not just sorting the request
                # first) is what closes the residual-delay hole the
                # two-level port opened (see coalescer docstring). The
                # per-base index keeps this O(classes on this shape), not
                # O(all lanes), on the urgent path.
                for other in self._lanes_by_base.get(key[:-1], ()):
                    if other is not q and slo.tighter_than(other.slo):
                        other.preempt_window()
        return req.future

    @guarded_by("_lock")
    def _predicted_rho_locked(self, name: str) -> float:
        """Function-level offered load vs full-batch capacity:
        ``sum(lane arrival rates) x shared service / max_batch``. 0.0 until
        estimates exist. Caller holds the scheduler lock."""
        est = self._service_by_fn.get(name)
        svc = est.value if est is not None else None
        if not svc:
            return 0.0
        lam = sum(
            q.adaptive.arrival_rate_rps
            for q in self._queues_by_name.get(name, ())
            if q.adaptive is not None
        )
        return lam * svc / self.max_batch

    def predicted_rho(self, name: str) -> float:
        """Public snapshot of the M/G/1 offered-load prediction for ``name``
        (sum of lane arrival rates x shared service / max_batch) — the
        autoscaler's scale-out signal. 0.0 until adaptive estimates exist."""
        with self._lock:
            return self._predicted_rho_locked(name)

    @guarded_by("_lock")
    def _make_queue(self, name: str, key: tuple, slo: SLOClass) -> AdmissionQueue:
        controller = None
        if self.adaptive:
            est = self._service_by_fn.get(name)
            if est is None:
                alpha = (self.adaptive_config or AdaptiveConfig()).alpha
                est = self._service_by_fn[name] = ServiceTimeEstimate(alpha)
            controller = QueueingWindow(
                self.max_batch, self.max_delay_s, self.adaptive_config,
                slo=slo, service=est,
            )
        # the controller clamps its seed into [min, max] and under the
        # class's structural bound; a static lane applies the same bound
        first_delay = (
            controller.delay_s
            if controller is not None
            else static_window_s(slo, self.max_delay_s)
        )
        return AdmissionQueue(
            name,
            self._tracked_dispatch,
            key=key,
            max_batch=self.max_batch,
            max_delay_s=first_delay,
            idle_timeout_s=self.idle_timeout_s,
            slo=slo,
            adaptive=controller,
            on_batch_done=self._record_batch,
            on_idle=self._retire_queue,
            clock=self.clock,
            tracer=self._tracer,
        )

    def _tracked_dispatch(self, name: str, args_list: list[tuple]) -> list:
        """Dispatch wrapper that maintains the per-function in-flight batch
        count the drain barrier (quiesce) and trough detector key on."""
        with self._cond:
            self._inflight[name] = self._inflight.get(name, 0) + 1
        self._dispatch_tls.name = name
        try:
            return self._dispatch(name, args_list)
        finally:
            self._dispatch_tls.name = None
            with self._cond:
                n = self._inflight.get(name, 1) - 1
                if n <= 0:
                    self._inflight.pop(name, None)
                else:
                    self._inflight[name] = n
                self._cond.notify_all()

    def quiesce(self, names=None, timeout: float = 10.0, *, include_queued: bool = True) -> bool:
        """Drain barrier for epoch transitions: block until the named
        functions (all functions when ``names`` is None) have no batch in
        flight — and, with ``include_queued``, nothing queued either (any
        class: the barrier is about the pipe being empty, not about
        deadlines). The control plane's reconciler runs the in-flight-only
        form (bounded) before executing a deferred transition, so the
        control-plane stall starts on a drained pipe; queued requests never
        need draining because they re-resolve the NEW routes at dispatch
        time. A dispatcher thread's own in-flight batch is excluded — the
        redeploy retry path can reach a barrier from inside a dispatch, and
        waiting on one's own batch would deadlock until timeout. Returns
        False on timeout (traffic never went quiet)."""
        names = None if names is None else set((names,) if isinstance(names, str) else names)
        own = getattr(self._dispatch_tls, "name", None)
        deadline = self.clock.now() + timeout
        with self._cond:
            while True:
                busy = any(
                    c - (1 if n == own else 0) > 0
                    for n, c in self._inflight.items()
                    if names is None or n in names
                )
                depth = sum(
                    q.depth()
                    for key, q in self._queues.items()
                    if names is None or key[0] in names
                ) if include_queued else 0
                if not busy and depth == 0:
                    return True
                remaining = deadline - self.clock.now()
                if remaining <= 0:
                    return False
                # queue depth changes don't signal the condition, so bound
                # each wait: the barrier is control-plane-only, a few ms of
                # poll granularity is invisible next to a drain
                self.clock.wait_on(self._cond, min(remaining, 0.01))

    def is_trough(self, *, min_quiet_s: float = 0.01, gap_mult: float = 3.0) -> bool:
        """Trough detector for the control plane's reconciler: True when a
        control-plane stall would land on no deadline-bearing traffic.
        Strict-class (finite-target) traffic governs: nothing strict may be
        queued, the time since the last strict submit must exceed
        ``gap_mult`` smoothed strict inter-arrival gaps (from the strict
        lanes' controller EWMAs), and no batch of ANY class may be mid
        dispatch (stalling an execution in flight delays work already
        admitted). Queued or trickling BEST-EFFORT traffic does NOT defeat
        the trough — it has no target a deferral could violate, and letting
        it block kept deferred merges pinned behind background trickle."""
        now = self.clock.now()
        with self._lock:
            if any(self._inflight.values()):
                return False
            if any(
                q.depth() for q in self._queues.values() if not q.slo.best_effort
            ):
                return False
            last = self._last_strict_submit_t
            gaps = [
                q.adaptive.snapshot()["ewma_gap_ms"] / 1e3
                for q in self._queues.values()
                if q.adaptive is not None and not q.slo.best_effort
            ]
        if last is None:
            return True  # never saw strict traffic: always a trough
        need = max(min_quiet_s, gap_mult * max(gaps)) if any(g > 0 for g in gaps) else min_quiet_s
        return now - last >= need

    def shutdown(self, timeout: float = 10.0) -> None:
        with self._lock:
            self._closed = True
            queues = list(self._queues.values())
            for q in queues:
                q.stop()
        for q in queues:
            q.thread.join(timeout)

    # ------------------------------------------------------------ lifecycle

    def _retire_queue(self, q: AdmissionQueue) -> bool:
        """Idle-timeout callback from a dispatcher thread: drop the queue if
        no request snuck in; the dispatcher exits on True."""
        with self._lock:
            if not q.empty():
                return False
            if self._queues.get(q.key) is q:
                del self._queues[q.key]
                base = q.key[:-1]
                lanes = self._lanes_by_base.get(base)
                if lanes is not None:
                    lanes = [l for l in lanes if l is not q]
                    if lanes:
                        self._lanes_by_base[base] = lanes
                    else:
                        del self._lanes_by_base[base]
                by_name = self._queues_by_name.get(q.name)
                if by_name is not None:
                    by_name = [l for l in by_name if l is not q]
                    if by_name:
                        self._queues_by_name[q.name] = by_name
                    else:
                        del self._queues_by_name[q.name]
            return True

    # ------------------------------------------------------------- metrics

    def _record_batch(self, name: str, batch: list[PendingRequest], t_done: float) -> None:
        k = len(batch)
        slo = batch[0].slo  # lanes are single-class: one class per batch
        with self._lock:
            self._batch_sizes.append(k)
            self._batches += 1
            win = self._per_name.get(name)
            if win is None:
                win = self._per_name[name] = LatencyWindow(maxlen=_PER_NAME_WINDOW)
            cls_win = self._per_class.get(slo.name)
            if cls_win is None:
                cls_win = self._per_class[slo.name] = LatencyWindow(maxlen=_PER_CLASS_WINDOW)
            if not slo.best_effort and slo.target_p95_ms > 0:
                nc_key = (name, slo.name)
                nc_recent = self._recent_class_lats.get(nc_key)
                if nc_recent is None:
                    nc_recent = self._recent_class_lats[nc_key] = collections.deque(
                        maxlen=_RECENT_LATS
                    )
                for r in batch:
                    nc_recent.append((t_done, t_done - r.t_enqueue))
            recent = self._recent_by_name.get(name)
            if recent is None:
                recent = self._recent_by_name[name] = collections.deque(maxlen=_RECENT_BATCHES)
            recent.append(k)
            lat_recent = self._recent_lat_by_name.get(name)
            if lat_recent is None:
                lat_recent = self._recent_lat_by_name[name] = collections.deque(maxlen=_RECENT_LATS)
            for r in batch:
                lat_recent.append((t_done, t_done - r.t_enqueue))
        for r in batch:
            lat = t_done - r.t_enqueue
            self._latency.observe(lat, t_done)
            win.observe(lat, t_done)
            cls_win.observe(lat, t_done)
            if self._on_request_done is not None:
                try:
                    self._on_request_done(name, lat, k)
                except Exception:  # noqa: BLE001 — a raising billing/metrics sink
                    pass  # must not lose the rest of the batch's observations

    def signals_for(self, names) -> SchedulerSignals:
        """Live feedback for the fusion policy about the chain ``names``:
        summed queue depth over the chain's keys, mean occupancy of the
        chain's RECENT batches (last _RECENT_BATCHES per function — the
        saturation guard must see now, not an all-time average diluted by
        hours of idle history), the worst per-function p95, and each strict
        class's tail vs its target across the chain (the policy's
        SLO-violation promote/regret input)."""
        names = (names,) if isinstance(names, str) else tuple(names)
        now = self.clock.now()
        with self._lock:
            hit = self._signals_cache.get(names)
            if hit is not None and now - hit[0] < _SIGNALS_TTL_S:
                return hit[1]
            depth = sum(q.depth() for key, q in self._queues.items() if key[0] in names)
            sizes = [s for n in names for s in self._recent_by_name.get(n, ())]
            windows = [self._per_name[n] for n in names if n in self._per_name]
            cutoff = now - _CLASS_SIGNAL_WINDOW_S
            class_samples: dict[str, list[float]] = {}
            for (n, cls), recent in self._recent_class_lats.items():
                if n in names:
                    class_samples.setdefault(cls, []).extend(
                        lat for (t, lat) in recent if t >= cutoff
                    )
            targets = {cls: s.target_p95_ms for cls, s in self._slo_classes.items()}
        mean_occ = (sum(sizes) / len(sizes)) / self.max_batch if sizes else 0.0
        p95 = max((w.snapshot()["p95_ms"] for w in windows), default=0.0)
        class_p95 = tuple(
            sorted(
                (cls, percentiles_ms(samples, points=(95,))["p95_ms"],
                 targets.get(cls, math.inf))
                for cls, samples in class_samples.items()
                if samples
            )
        )
        sig = SchedulerSignals(
            queue_depth=depth, mean_occupancy=mean_occ, p95_ms=p95, class_p95_ms=class_p95
        )
        with self._lock:
            if len(self._signals_cache) > 256:  # bounded: chains are few
                self._signals_cache.clear()
            self._signals_cache[names] = (now, sig)
        return sig

    def recent_p95_ms(self, name: str, window_s: float = 5.0) -> float:
        """Nearest-rank p95 of the function's end-to-end latency over the
        trailing ``window_s`` seconds (0.0 with no recent samples). The
        fission regret check compares this against the pre-merge baseline
        snapshotted at commit — an all-time window would dilute a fresh
        regression with hours of healthy history."""
        cutoff = self.clock.now() - window_s
        with self._lock:
            recent = self._recent_lat_by_name.get(name)
            samples = [lat for (t, lat) in recent if t >= cutoff] if recent else []
        return percentiles_ms(samples, points=(95,))["p95_ms"] if samples else 0.0

    def reset_stats(self) -> None:
        """Forget latency/batch history and learned adaptive state; live
        queues keep serving and windows re-seed at (clamped) max_delay_s.
        Benchmarks call this after warmup so compiles and warmup bursts
        don't pollute the measured occupancy, tails, or the controllers'
        EWMAs. Call while traffic is quiescent (warmup responses collected):
        a dispatcher mid-batch would apply one retune from pre-reset state."""
        with self._lock:
            self._batch_sizes.clear()
            self._batches = 0
            self._per_name = {}
            self._per_class = {}
            self._recent_class_lats = {}
            self._recent_by_name = {}
            self._recent_lat_by_name = {}
            self._signals_cache = {}
            self._shed = {}
            # shedding re-arms only when strict traffic is seen again: a
            # strict request during a forgotten warmup must not leave
            # best-effort shedding armed forever (all-best-effort overloads
            # belong to the fission path)
            self._strict_fns = set()
            queues = list(self._queues.values())
        self._latency.reset()
        for q in queues:
            if q.adaptive is not None:
                q.adaptive.reset(self.max_delay_s)
                q.max_delay_s = q.adaptive.delay_s

    def window_snapshot(self) -> list[dict]:
        """Per-queue view of the (possibly retuned) batching windows."""
        with self._lock:
            queues = list(self._queues.values())
        out = []
        for q in queues:
            row = {
                "name": q.name,
                "slo": q.slo.name,
                "max_delay_ms": q.max_delay_s * 1e3,
                "depth": q.depth(),
            }
            if q.adaptive is not None:
                row.update(q.adaptive.snapshot())
            out.append(row)
        return out

    def class_stats(self) -> dict:
        """Per-class latency/conformance: percentiles, target, and whether
        the class's p95 currently meets it. ``met`` is None for classes
        without an actionable end-to-end target: best-effort (no target)
        and zero-target classes (IMMEDIATE promises zero *admission* delay;
        end-to-end latency always includes service time)."""
        with self._lock:
            windows = dict(self._per_class)
            classes = dict(self._slo_classes)
            shed = dict(self._shed)
        out = {}
        for cls_name, win in sorted(windows.items()):
            snap = win.snapshot()
            slo = classes.get(cls_name)
            target = slo.target_p95_ms if slo is not None else math.inf
            actionable = math.isfinite(target) and target > 0
            out[cls_name] = {
                **snap,
                "target_p95_ms": target,
                "met": (snap["p95_ms"] <= target) if actionable else None,
                "shed": shed.get(cls_name, 0),
            }
        for cls_name, n in shed.items():  # classes that ONLY shed still report
            if cls_name not in out:
                out[cls_name] = {"shed": n, "count": 0}
        return out

    def stats(self) -> dict:
        with self._lock:
            sizes = list(self._batch_sizes)
            batches = self._batches
            n_keys = len(self._queues)
            queues = list(self._queues.values())
        out = self._latency.snapshot()
        out.update(
            {
                "batches": batches,
                "queues": n_keys,
                "mean_batch": (sum(sizes) / len(sizes)) if sizes else 0.0,
                "max_batch_seen": max(sizes) if sizes else 0,
            }
        )
        classes = self.class_stats()
        if classes:
            out["classes"] = classes
        if self.adaptive:
            delays = [q.max_delay_s * 1e3 for q in queues]
            out["adaptive"] = {
                "window_min_ms": round(min(delays), 4) if delays else 0.0,
                "window_max_ms": round(max(delays), 4) if delays else 0.0,
                "retunes": sum(q.adaptive.retunes for q in queues if q.adaptive is not None),
            }
        return out
