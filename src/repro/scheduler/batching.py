"""Request-batching primitives: shape keys, stacking, bucketing.

Two concurrent requests are *compatible* (co-batchable) when they target the
same function with the same argument structure — same pytree treedef, same
leaf shapes and dtypes. Compatible requests stack along a NEW leading batch
axis and run as one vmapped execution; the batch axis is invisible to the
function's own code, so shape-polymorphic routes (prefill vs decode) keep
their per-request meaning.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _leaf_sig(leaf) -> tuple:
    shape = getattr(leaf, "shape", None)
    dtype = getattr(leaf, "dtype", None)
    if shape is None or dtype is None:  # plain Python scalar: 0-d weak type
        return (jnp.shape(leaf), str(jnp.result_type(leaf)))
    return (tuple(shape), str(dtype))


def request_key(name: str, args: tuple, slo_name: str | None = None) -> tuple:
    """Admission-queue key: (function, argument-structure[, SLO class]). On
    the hot path for every scheduled request — leaf signatures read
    `.shape`/`.dtype` directly and only fall back to jnp promotion for
    Python scalars. ``slo_name`` partitions admission per class so batches
    can never mix latency targets (a strict request must not ride in — or
    wait behind — a best-effort convoy)."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    key = (name, str(treedef), tuple(_leaf_sig(l) for l in leaves))
    return key if slo_name is None else key + (slo_name,)


def stack_requests(args_list: list[tuple]):
    """Stack k compatible requests' args along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *args_list)


def split_results(out, k: int) -> list:
    """Scatter a batched output pytree back into k per-request pytrees."""
    return [jax.tree.map(lambda x: x[i], out) for i in range(k)]


def largest_pow2_le(n: int) -> int:
    """Largest power of two <= n (n floored at 1). The shared clamp behind
    the bucket invariant: the scheduler's max_batch and the bucket cap must
    agree, or admitted batches outgrow the compiled bucket set."""
    return 1 << (max(1, int(n)).bit_length() - 1)


def next_batch_bucket(k: int, max_batch: int | None = None) -> int:
    """Round a batch size up to the next power-of-two bucket (optionally
    capped at max_batch) so an instance compiles O(log max_batch) batched
    programs instead of one per observed size; short batches pad up.

    The cap itself clamps to the largest power-of-two <= max_batch: a
    non-power-of-two cap (e.g. 6) must not mint a one-off bucket-6 program
    that no other batch size reuses — an extra mid-traffic compile for zero
    reuse. Batches larger than the clamped cap run as bucket-sized chunks
    (see FunctionInstance.execute_batch)."""
    b = 1 if k <= 1 else 1 << (k - 1).bit_length()
    if max_batch is not None:
        b = min(b, largest_pow2_le(max_batch))
    return b
