"""Shared latency metrics: bounded sliding window + percentile reporting.

One implementation serves both latency sinks — the BillingMeter (all external
traffic, serial and scheduled) and the RequestScheduler (queue-level view,
usable standalone without a platform).
"""
from __future__ import annotations

import collections
import math
import threading
import time


def percentiles_ms(samples_s, points=(50, 95, 99)) -> dict:
    """p50/p95/p99 (milliseconds) via the textbook nearest-rank definition:
    rank = ceil(p/100 * n), 1-indexed. Explicit ceil — Python's round() is
    half-even, which lands one rank low whenever p/100 * n hits an exact
    half (e.g. p50 of 5 samples picked the 2nd instead of the 3rd)."""
    out = {f"p{p}_ms": 0.0 for p in points}
    n = len(samples_s)
    if not n:
        return out
    ordered = sorted(samples_s)
    for p in points:
        rank = min(n, max(1, math.ceil(p / 100.0 * n)))
        out[f"p{p}_ms"] = ordered[rank - 1] * 1e3
    return out


class LatencyWindow:
    """Thread-safe bounded window of request latencies. Tracks the earliest
    request start and latest completion so `snapshot()` can report sustained
    throughput alongside tail percentiles. ``clock`` supplies the default
    completion timestamp when a caller doesn't pass one (virtual-clock
    tests drive latencies entirely in simulated time)."""

    def __init__(self, maxlen: int = 200_000, clock=None):
        self._lock = threading.Lock()
        self._clock = clock
        self._samples: collections.deque = collections.deque(maxlen=maxlen)
        self._count = 0
        self._t_first: float | None = None
        self._t_last = 0.0

    def observe(self, seconds: float, t_done: float | None = None) -> None:
        if t_done is None:
            t_done = self._clock.now() if self._clock is not None else time.perf_counter()
        with self._lock:
            self._samples.append(seconds)
            self._count += 1
            t_start = t_done - seconds
            if self._t_first is None or t_start < self._t_first:
                self._t_first = t_start
            if t_done > self._t_last:
                self._t_last = t_done

    def reset(self) -> None:
        with self._lock:
            self._samples.clear()
            self._count = 0
            self._t_first = None
            self._t_last = 0.0

    def snapshot(self) -> dict:
        with self._lock:
            samples = list(self._samples)
            count = self._count
            span = (self._t_last - self._t_first) if self._t_first is not None else 0.0
        out = {"requests": count, "throughput_rps": count / span if span > 0 else 0.0}
        out.update(percentiles_ms(samples))
        return out
