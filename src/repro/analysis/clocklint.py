"""Clock-and-sleep hygiene lint.

Two rules, both protecting the deterministic-simulation story and the
tier-1 wall-clock budget:

Source rule (``src/repro``): every timed primitive goes through the
injectable :class:`~repro.scheduler.clock.Clock`. Direct calls to
``time.time`` / ``time.monotonic`` / ``time.sleep`` and waits on
``threading.Condition`` objects (``.wait`` / ``.wait_for``) are banned
everywhere except ``scheduler/clock.py``, which is the one sanctioned
shim over the real clock. ``time.perf_counter`` is allowed — it is a
duration probe, not a scheduling decision, and virtual-clock runs do not
need to control it.

Test rule (``tests/``): a test function that calls ``time.sleep`` with a
literal ≥ 0.25 s must carry ``@pytest.mark.slow`` (directly or via module
``pytestmark``) so tier-1 CI's wall-clock budget is not silently eroded.

Either rule can be waived per-line with a ``provlint: ok`` comment.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding, waived

PASS_CLOCK = "clock-hygiene"
PASS_SLEEP = "test-sleep"

#: time.<fn> calls banned outside scheduler/clock.py. perf_counter is allowed.
BANNED_TIME_FNS = {"time", "monotonic", "sleep"}

#: literal sleeps at or above this (seconds) require @pytest.mark.slow
TEST_SLEEP_THRESHOLD_S = 0.25

_CLOCK_EXEMPT_SUFFIXES = ("scheduler/clock.py",)


def _is_exempt(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(norm.endswith(sfx) for sfx in _CLOCK_EXEMPT_SUFFIXES)


def _time_aliases(tree: ast.Module) -> tuple[set[str], dict[str, str]]:
    """(module aliases of ``time``, {local name: time fn} from-imports)."""
    mod_aliases: set[str] = set()
    fn_aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    mod_aliases.add(a.asname or "time")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                fn_aliases[a.asname or a.name] = a.name
    return mod_aliases, fn_aliases


def _condition_receivers(tree: ast.Module) -> set[str]:
    """Names/attr-paths assigned from ``threading.Condition(...)``.

    Tracks ``self._cv = threading.Condition(...)`` (-> ``self._cv``) and
    ``cv = threading.Condition(...)`` (-> ``cv``) so ``<recv>.wait()`` can
    be distinguished from unrelated ``.wait()`` methods (Event.wait,
    Thread.join-style helpers), which are fine.
    """
    recv: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if not (isinstance(val, ast.Call) and isinstance(val.func, ast.Attribute)
                and val.func.attr == "Condition"):
            continue
        for tgt in node.targets:
            dotted = _dotted(tgt)
            if dotted:
                recv.add(dotted)
    return recv


def _dotted(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def check_source(source: str, path: str) -> list[Finding]:
    """Clock-hygiene findings for one src module."""
    if _is_exempt(path):
        return []
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(PASS_CLOCK, path, exc.lineno or 1, f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    mod_aliases, fn_aliases = _time_aliases(tree)
    cond_recv = _condition_receivers(tree)
    findings: list[Finding] = []

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        banned: str | None = None
        if isinstance(func, ast.Attribute):
            base = _dotted(func.value)
            if base in mod_aliases and func.attr in BANNED_TIME_FNS:
                banned = f"time.{func.attr}"
            elif func.attr in ("wait", "wait_for") and base in cond_recv:
                banned = f"Condition.{func.attr} (on {base})"
        elif isinstance(func, ast.Name) and func.id in fn_aliases:
            if fn_aliases[func.id] in BANNED_TIME_FNS:
                banned = f"time.{fn_aliases[func.id]}"
        if banned and not waived(lines, node.lineno):
            findings.append(Finding(
                PASS_CLOCK, path, node.lineno,
                f"{banned} outside scheduler/clock.py — route timing through "
                f"the injectable Clock",
            ))
    return findings


# --------------------------------------------------------------------------
# test-sleep rule
# --------------------------------------------------------------------------


def _is_slow_mark(expr: ast.AST) -> bool:
    """True for ``pytest.mark.slow`` / ``mark.slow`` expressions."""
    dotted = _dotted(expr)
    return bool(dotted) and dotted.endswith("mark.slow")


def _module_is_slow(tree: ast.Module) -> bool:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "pytestmark":
                    vals = (node.value.elts
                            if isinstance(node.value, (ast.List, ast.Tuple))
                            else [node.value])
                    if any(_is_slow_mark(v) for v in vals):
                        return True
    return False


def _literal_seconds(call: ast.Call) -> float | None:
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, (int, float)):
        return float(call.args[0].value)
    return None


def check_test_source(source: str, path: str) -> list[Finding]:
    """Test-sleep findings for one test module."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(PASS_SLEEP, path, exc.lineno or 1, f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    mod_aliases, fn_aliases = _time_aliases(tree)
    if _module_is_slow(tree):
        return []
    findings: list[Finding] = []

    def is_sleep(call: ast.Call) -> bool:
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr == "sleep":
            return _dotted(func.value) in mod_aliases
        if isinstance(func, ast.Name):
            return fn_aliases.get(func.id) == "sleep"
        return False

    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) or not node.name.startswith("test"):
            continue
        if any(_is_slow_mark(d) for d in node.decorator_list):
            continue
        # nested helper defs inside the test count — they run in the test
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and is_sleep(sub):
                secs = _literal_seconds(sub)
                if secs is not None and secs >= TEST_SLEEP_THRESHOLD_S \
                        and not waived(lines, sub.lineno):
                    findings.append(Finding(
                        PASS_SLEEP, path, sub.lineno,
                        f"test '{node.name}' sleeps {secs:g}s without "
                        f"@pytest.mark.slow — mark it slow or shrink the sleep",
                    ))
    return findings


def check_file(path) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return check_source(f.read(), str(path))


def check_test_file(path) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return check_test_source(f.read(), str(path))
