"""Lock-order / deadlock analysis: static and dynamic halves.

Static half
-----------

Extracts, per class, the nested-``with self.<lock>`` acquisition graph:
an edge ``A -> B`` means some method acquires ``B`` while holding ``A``
(directly nested ``with``, or by calling a ``self`` method whose body
acquires ``B``). Condition-over-lock aliases (``Condition(self._lock)``)
collapse to one node, mirroring the lock-discipline pass. A cycle in the
graph is a potential ABBA deadlock and is reported as a finding anchored
at one participating acquisition site.

Dynamic half
------------

:class:`LockGraph` + :class:`InstrumentedLock` record the *observed*
acquisition order at runtime — including cross-class, cross-object edges
the static pass cannot see (scheduler lock -> lane cv, batcher cv ->
arena lock). Two ways to wire it:

* wrap specific locks after construction::

      g = LockGraph()
      arena._lock = InstrumentedLock(g, inner=arena._lock, name="KVArena._lock")

* or patch ``threading.Lock/RLock/Condition`` for a scope so every lock
  created inside is instrumented, named by its creation call site::

      with patched_locks(g):
          sched = RequestScheduler(...)   # all its locks now record edges
          ... run the fuzz round ...
      g.assert_acyclic()

The fuzz suites call ``assert_acyclic()`` every round, so any change that
inverts an acquisition order anywhere in the exercised paths fails the
existing randomized tests, not a future post-mortem.
"""
from __future__ import annotations

import ast
import sys
import threading
from contextlib import contextmanager

from repro.analysis.findings import Finding
from repro.analysis.lockcheck import ClassInfo, _is_self_attr, collect_classes

PASS = "lock-order"


# --------------------------------------------------------------------------
# static pass
# --------------------------------------------------------------------------


def _method_lock_summary(cls: ClassInfo) -> dict[str, set[str]]:
    """method name -> set of (canonical) locks its body acquires anywhere."""
    out: dict[str, set[str]] = {}
    for stmt in cls.node.body:
        if not isinstance(stmt, ast.FunctionDef):
            continue
        acquired: set[str] = set()
        for sub in ast.walk(stmt):
            if isinstance(sub, (ast.With, ast.AsyncWith)):
                for item in sub.items:
                    attr = _is_self_attr(item.context_expr)
                    if attr is not None:
                        acquired.add(cls.canon(attr))
        req = cls.guarded_methods.get(stmt.name)
        if req is not None:
            acquired.add(cls.canon(req))
        out[stmt.name] = acquired
    return out


def _collect_edges(cls: ClassInfo, path: str):
    """Yield (src_lock_node, dst_lock_node, path, line) acquisition edges."""
    summaries = _method_lock_summary(cls)

    def node_name(lock: str) -> str:
        return f"{cls.name}.{lock}"

    def walk(stmts, held: tuple):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                new_held = list(held)
                for item in stmt.items:
                    attr = _is_self_attr(item.context_expr)
                    if attr is None:
                        continue
                    lock = cls.canon(attr)
                    for h in new_held:
                        if h != lock:
                            yield node_name(h), node_name(lock), path, stmt.lineno
                    new_held.append(lock)
                yield from walk(stmt.body, tuple(new_held))
                continue
            # calls to self methods while holding locks: one-level summary
            if held:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        fattr = _is_self_attr(sub.func)
                        if fattr is not None and fattr in summaries:
                            for lock in summaries[fattr]:
                                for h in held:
                                    if h != lock:
                                        yield node_name(h), node_name(lock), path, sub.lineno
            for field in ("body", "orelse", "finalbody"):
                sub_body = getattr(stmt, field, None)
                if isinstance(sub_body, list) and sub_body and isinstance(sub_body[0], ast.stmt):
                    yield from walk(sub_body, held)
            for h in getattr(stmt, "handlers", []):
                yield from walk(h.body, held)

    for stmt in cls.node.body:
        if isinstance(stmt, ast.FunctionDef):
            start = ()
            req = cls.guarded_methods.get(stmt.name)
            if req is not None:
                start = (cls.canon(req),)
            yield from walk(stmt.body, start)


def find_cycle(edges: dict[str, set[str]]):
    """One cycle as a node list ``[a, b, ..., a]``, or None."""
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in edges}
    stack: list[str] = []

    def dfs(n):
        color[n] = GREY
        stack.append(n)
        for m in sorted(edges.get(n, ())):
            if color.get(m, WHITE) == GREY:
                return stack[stack.index(m):] + [m]
            if color.get(m, WHITE) == WHITE:
                got = dfs(m)
                if got:
                    return got
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(edges):
        if color[n] == WHITE:
            got = dfs(n)
            if got:
                return got
    return None


def check_source(source: str, path: str) -> list[Finding]:
    """Static lock-order findings for one module (per-class graphs)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(PASS, path, exc.lineno or 1, f"syntax error: {exc.msg}")]
    findings: list[Finding] = []
    for cls in collect_classes(tree):
        graph: dict[str, set[str]] = {}
        sites: dict[tuple, tuple] = {}
        for a, b, p, line in _collect_edges(cls, path):
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
            sites.setdefault((a, b), (p, line))
        cycle = find_cycle(graph)
        if cycle:
            site = sites.get((cycle[0], cycle[1]), (path, cls.node.lineno))
            findings.append(Finding(
                PASS, site[0], site[1],
                f"{cls.name}: lock acquisition cycle {' -> '.join(cycle)} "
                f"(potential ABBA deadlock)",
            ))
    return findings


def check_file(path) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return check_source(f.read(), str(path))


# --------------------------------------------------------------------------
# dynamic half
# --------------------------------------------------------------------------


class LockGraph:
    """Aggregated runtime lock-acquisition graph across all threads.

    Locks are aggregated by NAME (their creation site or an explicit
    wrapper name), so the graph stays small and an inversion between two
    instances of the same lock pair is still a cycle."""

    def __init__(self):
        self._mu = threading.Lock()
        self._edges: dict[str, set[str]] = {}
        self._tls = threading.local()

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def note_acquire(self, name: str) -> None:
        st = self._stack()
        if name in st:  # reentrant (RLock) or condition re-acquire: no edge
            st.append(name)
            return
        if st:
            with self._mu:
                for held in set(st):
                    if held != name:
                        self._edges.setdefault(held, set()).add(name)
                        self._edges.setdefault(name, set())
        else:
            with self._mu:
                self._edges.setdefault(name, set())
        st.append(name)

    def note_release(self, name: str) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == name:
                del st[i]
                return

    def edges(self) -> dict[str, set[str]]:
        with self._mu:
            return {k: set(v) for k, v in self._edges.items()}

    def find_cycle(self):
        return find_cycle(self.edges())

    def assert_acyclic(self) -> None:
        cycle = self.find_cycle()
        if cycle:
            raise AssertionError(
                "lock acquisition cycle observed (potential ABBA deadlock): "
                + " -> ".join(cycle)
            )


class InstrumentedLock:
    """Lock wrapper recording acquisition order into a :class:`LockGraph`.

    Duck-types ``threading.Lock`` (acquire/release/context manager), so it
    can replace a plain lock attribute after construction, or serve as the
    underlying lock of a ``threading.Condition``."""

    def __init__(self, graph: LockGraph, inner=None, name: str | None = None,
                 reentrant: bool = False):
        self._graph = graph
        self._inner = inner if inner is not None else (
            threading._orig_rlock() if reentrant and hasattr(threading, "_orig_rlock")
            else _ORIG_RLOCK() if reentrant else _ORIG_LOCK()
        )
        self.name = name or f"lock@{id(self):x}"

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._graph.note_acquire(self.name)
        return got

    def release(self):
        self._graph.note_release(self.name)
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def _at_fork_reinit(self):  # pragma: no cover - fork support parity
        self._inner._at_fork_reinit()

    def __repr__(self):
        return f"<InstrumentedLock {self.name}>"


_ORIG_LOCK = threading.Lock
_ORIG_RLOCK = threading.RLock
_ORIG_COND = threading.Condition


def _creation_site() -> str:
    """'file.py:123' of the first frame outside this module / threading."""
    f = sys._getframe(2)
    skip = (__file__.rsplit("/", 1)[-1], "threading.py")
    while f is not None:
        fname = f.f_code.co_filename.rsplit("/", 1)[-1]
        if fname not in skip:
            return f"{fname}:{f.f_lineno}"
        f = f.f_back
    return "<unknown>"


@contextmanager
def patched_locks(graph: LockGraph):
    """Patch ``threading.Lock/RLock/Condition`` so every lock constructed
    in the scope records its acquisition order into ``graph``, named by
    creation site. Locks created inside keep working after the scope ends
    (they hold their own references); only *construction* is patched."""

    def make_lock():
        return InstrumentedLock(graph, inner=_ORIG_LOCK(), name=_creation_site())

    def make_rlock():
        return InstrumentedLock(
            graph, inner=_ORIG_RLOCK(), name=_creation_site(), reentrant=True
        )

    def make_cond(lock=None):
        if lock is None:
            lock = InstrumentedLock(graph, inner=_ORIG_LOCK(), name=_creation_site())
        return _ORIG_COND(lock)

    threading.Lock = make_lock
    threading.RLock = make_rlock
    threading.Condition = make_cond
    try:
        yield graph
    finally:
        threading.Lock = _ORIG_LOCK
        threading.RLock = _ORIG_RLOCK
        threading.Condition = _ORIG_COND
