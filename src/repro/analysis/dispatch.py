"""Dispatch-hygiene tracer: steady-state recompiles and host syncs.

Two hazards this repo has already shipped and hand-fixed once each:

* **Steady-state recompiles** — a decode loop whose batch/chunk shapes are
  not padded to a closed bucket set retraces and recompiles mid-stream
  (the PR 5 non-pow2 bucket bug). Compiles are observed via
  ``jax.monitoring``'s ``/jax/core/compile/backend_compile_duration``
  event, which fires exactly once per backend compile and never on cache
  hits, so ``delta(snapshot).compiles == 0`` is a precise "no new
  programs" assertion.

* **Per-token host syncs** — an eager ``int(...)``/``np.asarray(...)`` on
  a device array inside the token loop serializes every step on a
  device→host transfer (the PR 5 eager-argmax bug). JAX's transfer guard
  is a no-op on the CPU backend, so while armed the tracer patches
  ``numpy.asarray`` and ``jax.device_get`` and counts calls whose
  argument is a concrete ``jax.Array``. The smoke gate allows one batched
  fetch per decode step plus O(1) per request (seating/finishing) and
  fails on anything per-token-per-lane.

The tracer is a process-wide singleton (``TRACER``), disarmed by default
(zero overhead: arming is what installs the patches). ``load_bench
--serve --smoke`` arms it after warmup and asserts on the deltas;
``kernels/ops.py`` reports eager kernel entries informationally (eager
dispatch is legitimate on the unfused interpreter path).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.analysis.guards import guarded_by


@dataclass(frozen=True)
class DispatchSnapshot:
    compiles: int
    host_syncs: int
    decode_steps: int
    kernel_calls: int


class DispatchTracer:
    """Armable recompile + host-sync counter. See module docstring.

    Thread-safe and re-entrant: ``arm``/``disarm`` are ref-counted under
    ``_mu``, so overlapping measurement windows (the smoke gate arming
    while the overhead gate is already armed) never double-install the
    transfer patches — and never capture an installed wrapper as the
    "original" to restore, which would leak the patch forever."""

    GUARDED_FIELDS = {
        "_arm_count": "_mu",
        "_listener_installed": "_mu",
        "_unpatch": "_mu",
        "compiles": "_mu",
        "host_syncs": "_mu",
        "decode_steps": "_mu",
        "kernel_calls": "_mu",
    }

    _EVENT = "/jax/core/compile/backend_compile_duration"

    def __init__(self):
        self._mu = threading.Lock()
        # lock-free fast-path flag the hot hooks read; written ONLY under
        # _mu. A stale read at an arm/disarm boundary misses/adds at most
        # one in-flight event — never a leak or a crash — so the hooks
        # stay O(1) with no lock acquisition while disarmed.
        self._armed = False
        self._arm_count = 0
        self._listener_installed = False
        self._unpatch = None  # installed patches' restore thunk, or None
        self.compiles = 0
        self.host_syncs = 0
        self.decode_steps = 0
        self.kernel_calls: dict[str, int] = {}

    @property
    def armed(self) -> bool:
        return self._armed

    # -- wiring ------------------------------------------------------------

    def _on_event(self, event: str, duration: float, **kw) -> None:
        # jax.monitoring has no unregister API, so the listener outlives
        # every disarm: it must gate on the armed state or steady-state
        # compile asserts would see compiles from unrelated code between
        # measurement windows
        if event == self._EVENT and self._armed:
            with self._mu:
                if self._arm_count > 0:
                    self.compiles += 1

    @guarded_by("_mu")
    def _install_listener(self) -> None:
        if self._listener_installed:
            return
        import jax.monitoring
        # there is no unregister API; the listener stays and filters by event
        jax.monitoring.register_event_duration_secs_listener(self._on_event)
        self._listener_installed = True

    def _is_device_array(self, x) -> bool:
        import jax
        return isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)

    @guarded_by("_mu")
    def _patch_transfers(self) -> None:
        """Install the asarray/device_get counting wrappers. Only called on
        the 0 -> 1 arm transition with the previous patches restored, so the
        captured originals are always the real functions."""
        if self._unpatch is not None:
            return
        import jax
        import numpy

        orig_asarray = numpy.asarray
        orig_device_get = jax.device_get
        tracer = self

        def asarray(a, *args, **kw):
            if tracer._armed and tracer._is_device_array(a):
                with tracer._mu:
                    if tracer._arm_count > 0:
                        tracer.host_syncs += 1
            return orig_asarray(a, *args, **kw)

        def device_get(x):
            if tracer._armed:
                with tracer._mu:
                    if tracer._arm_count > 0:
                        tracer.host_syncs += 1
            return orig_device_get(x)

        numpy.asarray = asarray
        jax.device_get = device_get
        self._unpatch = lambda: (
            setattr(numpy, "asarray", orig_asarray),
            setattr(jax, "device_get", orig_device_get),
        )

    # -- public API --------------------------------------------------------

    def arm(self) -> None:
        """Begin (or join) a measurement window. Every ``arm`` needs a
        matching ``disarm``; patches install on the first and are removed
        by the last, so mid-flight re-arms neither double-count nor leak."""
        with self._mu:
            self._arm_count += 1
            if self._arm_count == 1:
                self._install_listener()
                self._patch_transfers()
                self._armed = True

    def disarm(self) -> None:
        with self._mu:
            if self._arm_count == 0:
                return  # idempotent: stray disarms don't underflow
            self._arm_count -= 1
            if self._arm_count == 0:
                self._armed = False
                if self._unpatch is not None:
                    self._unpatch()
                    self._unpatch = None

    def note_decode_step(self) -> None:
        if self._armed:
            with self._mu:
                self.decode_steps += 1

    def note_kernel_call(self, name: str, probe=None) -> None:
        """Informational: an op entry executed eagerly (concrete operand).

        Legitimate on the unfused interpreter path; recorded so smoke
        reports show the eager/traced split, never asserted on."""
        if not self._armed:
            return
        if probe is not None:
            try:
                if not self._is_device_array(probe):
                    return
            except Exception:
                return
        with self._mu:
            self.kernel_calls[name] = self.kernel_calls.get(name, 0) + 1

    def snapshot(self) -> DispatchSnapshot:
        with self._mu:
            return DispatchSnapshot(
                compiles=self.compiles,
                host_syncs=self.host_syncs,
                decode_steps=self.decode_steps,
                kernel_calls=sum(self.kernel_calls.values()),
            )

    def delta(self, since: DispatchSnapshot) -> DispatchSnapshot:
        now = self.snapshot()
        return DispatchSnapshot(
            compiles=now.compiles - since.compiles,
            host_syncs=now.host_syncs - since.host_syncs,
            decode_steps=now.decode_steps - since.decode_steps,
            kernel_calls=now.kernel_calls - since.kernel_calls,
        )


#: Process-wide tracer instance the instrumentation hooks report into.
TRACER = DispatchTracer()
