"""provlint: repo-native static analysis + instrumented-runtime checks.

Four passes (see ``python -m repro.analysis.lint``):

* lock-discipline  — ``GUARDED_FIELDS`` / ``GUARDED_WRITES`` /
  ``@guarded_by`` annotations checked by an AST domination pass
* lock-order       — static nested-``with`` acquisition graph +
  runtime :class:`InstrumentedLock` recorder for the fuzz suites
* clock-hygiene    — raw ``time.*`` / ``Condition.wait`` banned outside
  ``scheduler/clock.py``; big sleeps in tier-1 tests banned
* dispatch-hygiene — armable :data:`TRACER` counting steady-state
  recompiles and device→host syncs for the smoke benchmarks
"""
from repro.analysis.dispatch import TRACER, DispatchSnapshot, DispatchTracer
from repro.analysis.findings import WAIVER, Finding
from repro.analysis.guards import guarded_by
from repro.analysis.lockorder import InstrumentedLock, LockGraph, patched_locks

__all__ = [
    "TRACER",
    "DispatchSnapshot",
    "DispatchTracer",
    "Finding",
    "InstrumentedLock",
    "LockGraph",
    "WAIVER",
    "guarded_by",
    "patched_locks",
]
