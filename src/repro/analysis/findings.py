"""Shared finding record for every provlint pass.

A finding pins (pass name, file, line, message) — the tuple the fixture
tests assert on exactly, and the unit the JSON report serializes. Keeping
it dataclass-dumb means every pass stays a pure function from source text
to findings, trivially testable without touching the filesystem.
"""
from __future__ import annotations

import dataclasses


#: Substring that waives any provlint diagnostic on the line it appears on.
#: Use sparingly and leave the reason next to it, e.g.
#: ``time.sleep(0.5)  # provlint: ok — async drain is the scenario``.
WAIVER = "provlint: ok"


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str  # "lock-discipline" | "lock-order" | "clock-hygiene" | "test-sleep"
    path: str       # repo-relative where possible
    line: int       # 1-indexed
    message: str

    def to_dict(self) -> dict:
        return {
            "pass": self.pass_name,
            "path": self.path,
            "line": self.line,
            "message": self.message,
        }

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_name}] {self.message}"


def waived(source_lines: list[str], lineno: int) -> bool:
    """True when the 1-indexed source line carries a waiver comment."""
    if 1 <= lineno <= len(source_lines):
        return WAIVER in source_lines[lineno - 1]
    return False
