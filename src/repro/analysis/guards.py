"""Lock-discipline annotations consumed by provlint's static checker.

Two conventions, both zero-cost at runtime:

``GUARDED_FIELDS`` — a plain (un-annotated, so dataclass-safe) class
attribute mapping attribute name -> the ``self.<lock>`` attribute that must
be held for ANY access (read or write) from the class's own methods::

    class KVArena:
        GUARDED_FIELDS = {"_held": "_lock", "_free": "_lock"}

``GUARDED_WRITES`` — same shape, but only *writes* (including subscript
stores through a local alias, the classic functional-RMW swap) require the
lock; unlocked reads are allowed. This is for fields where a torn read is
benign (a GIL-atomic reference read) but a read-modify-write races::

    class KVArena:
        GUARDED_WRITES = {"data": "_data_lock"}

``@guarded_by("<lock>")`` — marks a method whose CALLER must already hold
the lock (the ``_locked``-suffix contract made machine-readable). Inside
the method the lock counts as held; calls to it from a context that does
not hold the lock are flagged::

    @guarded_by("_lock")
    def _pop_free_page_locked(self): ...

The decorator only attaches metadata — no wrapper, no per-call overhead on
hot paths. ``__init__`` / ``__post_init__`` are exempt from checking
(construction happens before the object is shared).

Condition variables constructed over an existing lock
(``self._cond = threading.Condition(self._lock)``) are understood by the
checker: holding either name counts as holding the one underlying lock.
"""
from __future__ import annotations

GUARDED_BY_ATTR = "__guarded_by__"


def guarded_by(lock_name: str):
    """Declare that callers of this method must hold ``self.<lock_name>``."""

    def mark(fn):
        setattr(fn, GUARDED_BY_ATTR, lock_name)
        return fn

    return mark
