"""provlint CLI: run all static passes over the repo and report.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint [--root DIR] [--json OUT]

Passes and scopes:

* ``lock-discipline`` + ``lock-order`` — every module under ``src/repro``
* ``clock-hygiene`` — every module under ``src/repro`` except
  ``scheduler/clock.py``
* ``test-sleep`` — every ``test_*.py`` under ``tests/``

Fixture snippets (any path containing a ``fixtures`` component) are
skipped — they are *intentionally* bad and are exercised by
``tests/test_provlint.py`` instead. Exit status is the number of findings
clamped to 1, so CI can gate on it directly.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import clocklint, lockcheck, lockorder
from repro.analysis.findings import Finding


def _skip(path: Path) -> bool:
    return "fixtures" in path.parts or "__pycache__" in path.parts


def collect_findings(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    src = root / "src" / "repro"
    tests = root / "tests"
    for path in sorted(src.rglob("*.py")):
        if _skip(path):
            continue
        rel = str(path.relative_to(root))
        source = path.read_text(encoding="utf-8")
        findings += lockcheck.check_source(source, rel)
        findings += lockorder.check_source(source, rel)
        findings += clocklint.check_source(source, rel)
    if tests.is_dir():
        for path in sorted(tests.glob("test_*.py")):
            if _skip(path):
                continue
            rel = str(path.relative_to(root))
            findings += clocklint.check_test_source(
                path.read_text(encoding="utf-8"), rel)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.analysis.lint", description=__doc__)
    ap.add_argument("--root", type=Path,
                    default=Path(__file__).resolve().parents[3],
                    help="repo root (default: inferred from this file)")
    ap.add_argument("--json", type=Path, default=None, metavar="OUT",
                    help="write machine-readable report to OUT")
    args = ap.parse_args(argv)

    findings = collect_findings(args.root)
    for f in findings:
        print(f, file=sys.stderr)
    report = {
        "root": str(args.root),
        "findings": [f.to_dict() for f in findings],
        "counts": _counts(findings),
        "ok": not findings,
    }
    if args.json:
        args.json.write_text(json.dumps(report, indent=2) + "\n", encoding="utf-8")
    print(f"provlint: {len(findings)} finding(s) "
          f"({', '.join(f'{k}={v}' for k, v in report['counts'].items()) or 'clean'})")
    return 1 if findings else 0


def _counts(findings: list[Finding]) -> dict[str, int]:
    out: dict[str, int] = {}
    for f in findings:
        out[f.pass_name] = out.get(f.pass_name, 0) + 1
    return out


if __name__ == "__main__":
    raise SystemExit(main())
