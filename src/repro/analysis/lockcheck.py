"""Lock-discipline AST pass.

Walks every class that declares ``GUARDED_FIELDS`` / ``GUARDED_WRITES`` or
``@guarded_by`` methods (see :mod:`repro.analysis.guards`) and flags any
access of a guarded field — or call of a guarded method — that is not
dominated by a ``with self.<lock>:`` block holding the declared lock.

What the pass understands:

* ``with self._lock:`` (including multi-item ``with a, b:``) adds the lock
  to the held set for the block's body;
* ``self._cond = threading.Condition(self._lock)`` in ``__init__`` /
  ``__post_init__`` aliases the two names to ONE lock — holding either
  counts as holding both (the scheduler's ``_lock``/``_cond`` pair);
* ``@guarded_by("_lock")`` methods run with the lock held by caller
  contract, and calling one without holding the lock is a violation;
* write-guarded fields (``GUARDED_WRITES``) track simple local aliases —
  ``dst = self.data[stage]`` followed by ``dst[kv] = ...`` outside the
  lock is the exact PR 6 ``write_prefill`` race shape and is flagged as a
  write to the field;
* nested ``def`` / ``lambda`` bodies are NOT analyzed (a closure's call
  site, not its definition site, determines what is held — flagging them
  here would be noise).

``__init__`` / ``__post_init__`` / ``__del__`` are exempt: construction
and finalization happen before/after the object is shared.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.findings import Finding, waived

PASS = "lock-discipline"

_EXEMPT_METHODS = {"__init__", "__post_init__", "__del__"}


def _is_self_attr(node) -> str | None:
    """'F' when node is ``self.F``, else None."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _literal_str_dict(node) -> dict[str, str] | None:
    if not isinstance(node, ast.Dict):
        return None
    out: dict[str, str] = {}
    for k, v in zip(node.keys, node.values):
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
            return None
        if not (isinstance(v, ast.Constant) and isinstance(v.value, str)):
            return None
        out[k.value] = v.value
    return out


def _guarded_by_decorator(dec) -> str | None:
    """Lock name when the decorator is ``guarded_by("...")`` (possibly
    attribute-qualified), else None."""
    if not (isinstance(dec, ast.Call) and dec.args):
        return None
    fn = dec.func
    name = fn.id if isinstance(fn, ast.Name) else (fn.attr if isinstance(fn, ast.Attribute) else None)
    if name != "guarded_by":
        return None
    arg = dec.args[0]
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value
    return None


@dataclasses.dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    guarded: dict[str, str]        # field -> lock (reads + writes)
    write_guarded: dict[str, str]  # field -> lock (writes only)
    lock_aliases: dict[str, str]   # cond attr -> underlying lock attr
    guarded_methods: dict[str, str]  # method -> required lock

    def canon(self, lock: str) -> str:
        seen = set()
        while lock in self.lock_aliases and lock not in seen:
            seen.add(lock)
            lock = self.lock_aliases[lock]
        return lock

    @property
    def annotated(self) -> bool:
        return bool(self.guarded or self.write_guarded or self.guarded_methods)


def collect_classes(tree: ast.Module) -> list[ClassInfo]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        guarded: dict[str, str] = {}
        write_guarded: dict[str, str] = {}
        aliases: dict[str, str] = {}
        methods: dict[str, str] = {}
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt = stmt.targets[0]
                if isinstance(tgt, ast.Name):
                    if tgt.id == "GUARDED_FIELDS":
                        guarded.update(_literal_str_dict(stmt.value) or {})
                    elif tgt.id == "GUARDED_WRITES":
                        write_guarded.update(_literal_str_dict(stmt.value) or {})
            if isinstance(stmt, ast.FunctionDef):
                for dec in stmt.decorator_list:
                    lock = _guarded_by_decorator(dec)
                    if lock is not None:
                        methods[stmt.name] = lock
                if stmt.name in _EXEMPT_METHODS:
                    # condition-over-lock aliases declared at construction
                    for sub in ast.walk(stmt):
                        if not (isinstance(sub, ast.Assign) and len(sub.targets) == 1):
                            continue
                        tgt_attr = _is_self_attr(sub.targets[0])
                        if tgt_attr is None or not isinstance(sub.value, ast.Call):
                            continue
                        call = sub.value
                        fn = call.func
                        is_cond = (
                            isinstance(fn, ast.Attribute) and fn.attr == "Condition"
                        ) or (isinstance(fn, ast.Name) and fn.id == "Condition")
                        if is_cond and call.args:
                            src_attr = _is_self_attr(call.args[0])
                            if src_attr is not None:
                                aliases[tgt_attr] = src_attr
        out.append(ClassInfo(node.name, node, guarded, write_guarded, aliases, methods))
    return out


class _MethodChecker:
    def __init__(self, cls: ClassInfo, method: ast.FunctionDef, path: str,
                 lines: list[str], findings: list[Finding]):
        self.cls = cls
        self.method = method
        self.path = path
        self.lines = lines
        self.findings = findings
        # local name -> write-guarded field it aliases (dst = self.data[...])
        self.aliases: dict[str, str] = {}

    # ------------------------------------------------------------- report

    def _report(self, node, kind: str, field: str, lock: str):
        if waived(self.lines, node.lineno):
            return
        self.findings.append(Finding(
            PASS, self.path, node.lineno,
            f"{self.cls.name}.{self.method.name}: {kind} '{field}' "
            f"(guarded by '{lock}') outside 'with self.{lock}'",
        ))

    def _held_ok(self, lock: str, held: frozenset) -> bool:
        return self.cls.canon(lock) in held

    # ------------------------------------------------------------- drive

    def run(self):
        held = frozenset()
        required = self.cls.guarded_methods.get(self.method.name)
        if required is not None:
            held = frozenset({self.cls.canon(required)})
        self._walk(self.method.body, held)

    def _walk(self, stmts, held: frozenset):
        for stmt in stmts:
            self._stmt(stmt, held)

    def _stmt(self, stmt, held: frozenset):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # closures/nested defs: held set at call time is unknown
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            new_held = set(held)
            for item in stmt.items:
                attr = _is_self_attr(item.context_expr)
                if attr is not None:
                    new_held.add(self.cls.canon(attr))
                else:
                    self._expr(item.context_expr, held)
            self._walk(stmt.body, frozenset(new_held))
            return
        if isinstance(stmt, ast.Assign):
            self._expr(stmt.value, held)
            for tgt in stmt.targets:
                self._target(tgt, held)
            self._track_alias(stmt, held)
            return
        if isinstance(stmt, ast.AugAssign):
            self._expr(stmt.value, held)
            self._target(stmt.target, held, aug=True)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._expr(stmt.value, held)
            self._target(stmt.target, held)
            return
        if isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                self._target(tgt, held)
            return
        if isinstance(stmt, ast.For):
            self._expr(stmt.iter, held)
            self._track_for_alias(stmt)
            self._walk(stmt.body, held)
            self._walk(stmt.orelse, held)
            return
        if isinstance(stmt, ast.While):
            self._expr(stmt.test, held)
            self._walk(stmt.body, held)
            self._walk(stmt.orelse, held)
            return
        if isinstance(stmt, ast.If):
            self._expr(stmt.test, held)
            self._walk(stmt.body, held)
            self._walk(stmt.orelse, held)
            return
        if isinstance(stmt, ast.Try):
            self._walk(stmt.body, held)
            for h in stmt.handlers:
                self._walk(h.body, held)
            self._walk(stmt.orelse, held)
            self._walk(stmt.finalbody, held)
            return
        if isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self._expr(stmt.value, held)
            return
        if isinstance(stmt, (ast.Raise, ast.Assert)):
            for sub in ast.iter_child_nodes(stmt):
                self._expr(sub, held)
            return
        # pass/break/continue/global/import/...: nothing guarded inside
        for sub in ast.iter_child_nodes(stmt):
            if isinstance(sub, ast.expr):
                self._expr(sub, held)

    # --------------------------------------------------------- alias track

    def _alias_root_field(self, expr) -> str | None:
        """Write-guarded field when expr derives from one by subscripts /
        attribute lookups / .values()-style calls, else None."""
        node = expr
        while True:
            if isinstance(node, ast.Subscript):
                node = node.value
            elif isinstance(node, ast.Call):
                node = node.func
            elif isinstance(node, ast.Attribute):
                attr = _is_self_attr(node)
                if attr is not None:
                    return attr if attr in self.cls.write_guarded else None
                node = node.value
            elif isinstance(node, ast.Name):
                return self.aliases.get(node.id)
            else:
                return None

    def _track_alias(self, stmt: ast.Assign, held: frozenset):
        if len(stmt.targets) != 1 or not isinstance(stmt.targets[0], ast.Name):
            return
        name = stmt.targets[0].id
        field = self._alias_root_field(stmt.value)
        if field is not None:
            self.aliases[name] = field
        else:
            self.aliases.pop(name, None)

    def _track_for_alias(self, stmt: ast.For):
        field = self._alias_root_field(stmt.iter)
        targets = [stmt.target] if isinstance(stmt.target, ast.Name) else (
            [e for e in getattr(stmt.target, "elts", []) if isinstance(e, ast.Name)]
        )
        for t in targets:
            if field is not None:
                self.aliases[t.id] = field
            else:
                self.aliases.pop(t.id, None)

    # ------------------------------------------------------------- targets

    def _target(self, tgt, held: frozenset, aug: bool = False):
        attr = _is_self_attr(tgt)
        if attr is not None:
            lock = self.cls.guarded.get(attr) or self.cls.write_guarded.get(attr)
            if lock is not None and not self._held_ok(lock, held):
                self._report(tgt, "write to", attr, lock)
            return
        if isinstance(tgt, ast.Subscript):
            # self.F[...] = v  or  alias[...] = v (alias of a write-guarded field)
            field = self._alias_root_field(tgt)
            if field is not None:
                lock = self.cls.write_guarded.get(field) or self.cls.guarded.get(field)
                if lock is not None and not self._held_ok(lock, held):
                    self._report(tgt, "write through", field, lock)
            # the subscript expression itself contains loads (index, value)
            self._expr(tgt.value, held)
            self._expr(tgt.slice, held)
            return
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._target(e, held, aug=aug)
            return
        if isinstance(tgt, ast.Attribute):
            self._expr(tgt.value, held)

    # --------------------------------------------------------------- exprs

    def _expr(self, node, held: frozenset):
        if node is None or not isinstance(node, ast.AST):
            return
        if isinstance(node, (ast.Lambda, ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # closure body: call-time held set unknown
        attr = _is_self_attr(node)
        if attr is not None and isinstance(node.ctx, ast.Load):
            lock = self.cls.guarded.get(attr)
            if lock is not None and not self._held_ok(lock, held):
                self._report(node, "read of", attr, lock)
        if isinstance(node, ast.Call):
            fattr = _is_self_attr(node.func)
            if fattr is not None and fattr in self.cls.guarded_methods:
                lock = self.cls.guarded_methods[fattr]
                if not self._held_ok(lock, held):
                    if not waived(self.lines, node.lineno):
                        self.findings.append(Finding(
                            PASS, self.path, node.lineno,
                            f"{self.cls.name}.{self.method.name}: call of "
                            f"'{fattr}' (requires '{lock}' held) outside "
                            f"'with self.{lock}'",
                        ))
        for child in ast.iter_child_nodes(node):
            self._expr(child, held)


def check_source(source: str, path: str) -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(PASS, path, exc.lineno or 1, f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    findings: list[Finding] = []
    for cls in collect_classes(tree):
        if not cls.annotated:
            continue
        for stmt in cls.node.body:
            if isinstance(stmt, ast.FunctionDef) and stmt.name not in _EXEMPT_METHODS:
                # skip methods without a `self` receiver (static/class methods)
                if stmt.args.args and stmt.args.args[0].arg == "self":
                    _MethodChecker(cls, stmt, path, lines, findings).run()
    return findings


def check_file(path) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return check_source(f.read(), str(path))
