"""Critical-path attribution over span trees + measured edge-cost EWMAs.

Attribution model
-----------------
A trace is the span tree rooted at span id 1. Each span's *self-time* is
its duration minus the summed durations of its direct children; a span's
category accumulates its self-time. The root's own self-time — wall time
no instrumented phase claims (dispatch glue, retry gaps) — lands in
``"unattributed"``. Summed self-times telescope to the root duration
algebraically, so ``sum(phases.values()) == wall_s`` up to float rounding;
``residual_s`` reports the difference and tests pin it at ~0. A span whose
children overlap it (children durations exceed the parent) marks the trace
``conserved=False`` instead of silently clamping.

:class:`EdgeCostModel` is the feedback half: the platform feeds measured
cross-function sync waits (``remote_call``) and merge build stalls
(``note_provisioning``) into per-edge EWMAs, and ``FusionPolicy`` weighs
those *measurements* instead of its static ``saturation_penalty`` /
``mean_wait_s`` knobs when deciding merge vs replicate.
"""
from __future__ import annotations

import threading

from repro.obs.trace import CONTROL_TRACE_ID, SpanRecord

_ROOT = 1
_EPS = 1e-9


def build_trees(records: list[SpanRecord]) -> dict[int, dict[int, SpanRecord]]:
    """Group complete (``ph == "X"``) spans by trace id, keyed by span id.
    The control-plane pseudo-trace is excluded."""
    trees: dict[int, dict[int, SpanRecord]] = {}
    for r in records:
        if r.ph != "X" or r.trace_id == CONTROL_TRACE_ID:
            continue
        trees.setdefault(r.trace_id, {})[r.span_id] = r
    return trees


def attribute_trace(spans) -> dict | None:
    """Per-category latency attribution for one trace; ``None`` when the
    root span never finished (request still in flight when sampled).
    Accepts a ``{span_id: record}`` tree (from :func:`build_trees`) or a
    plain list of one trace's records."""
    if not isinstance(spans, dict):
        spans = {r.span_id: r for r in spans if r.ph == "X"}
    root = spans.get(_ROOT)
    if root is None:
        return None
    children: dict[int, list[SpanRecord]] = {}
    for sid, r in spans.items():
        if sid == _ROOT:
            continue
        children.setdefault(r.parent_id, []).append(r)
    phases: dict[str, float] = {}
    conserved = True
    for sid, r in spans.items():
        kids = children.get(sid, ())
        self_s = r.dur_s - sum(k.dur_s for k in kids)
        if self_s < -_EPS:  # children overlap / exceed their parent
            conserved = False
        cat = "unattributed" if sid == _ROOT else r.cat
        phases[cat] = phases.get(cat, 0.0) + self_s
    # a child whose parent record was dropped by the ring breaks the
    # telescoping sum — its duration was never subtracted anywhere
    if any(pid not in spans for pid in children):
        conserved = False
    wall = root.dur_s
    residual = wall - sum(phases.values())
    return {
        "trace_id": root.trace_id,
        "name": root.name,
        "kind": root.cat,
        "wall_s": wall,
        "phases": phases,
        "residual_s": residual,
        "conserved": conserved and abs(residual) <= max(_EPS, 1e-9 + 1e-12 * abs(wall)),
        "attrs": root.args,
    }


def attribute(records: list[SpanRecord]) -> list[dict]:
    """Attribution for every finished trace in ``records``, trace-id order."""
    trees = build_trees(records)
    out = []
    for tid in sorted(trees):
        res = attribute_trace(trees[tid])
        if res is not None:
            out.append(res)
    return out


def summarize(results: list[dict]) -> dict:
    """Fleet-level rollup of :func:`attribute` output: per-category total
    seconds and the share of summed wall time each category claims."""
    totals: dict[str, float] = {}
    wall = 0.0
    for res in results:
        wall += res["wall_s"]
        for cat, s in res["phases"].items():
            totals[cat] = totals.get(cat, 0.0) + s
    shares = {c: (s / wall if wall > 0 else 0.0) for c, s in totals.items()}
    return {"requests": len(results), "wall_s": wall,
            "phase_seconds": totals, "phase_share": shares}


class EdgeCostModel:
    """Measured costs the fusion policy consumes instead of static knobs.

    * per-edge EWMA of the *blocking* cross-function sync wait observed at
      ``platform.remote_call`` (what fusing the edge would eliminate);
    * EWMA of the merge build stall and of the admission-queue depth the
      stall was inflicted on (what fusing *costs* the queued requests).
    """

    GUARDED_FIELDS = {
        "_edges": "_lock",
        "_merge_stall_s": "_lock",
        "_merge_depth": "_lock",
        "_merge_samples": "_lock",
    }

    def __init__(self, alpha: float = 0.3):
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._edges: dict[tuple[str, str], float] = {}
        self._merge_stall_s: float | None = None
        self._merge_depth: float = 0.0
        self._merge_samples: int = 0

    def _ewma(self, old: float | None, x: float) -> float:
        return x if old is None else (1.0 - self.alpha) * old + self.alpha * x

    def observe_sync_edge(self, caller: str, callee: str, wait_s: float) -> None:
        key = (caller, callee)
        with self._lock:
            self._edges[key] = self._ewma(self._edges.get(key), float(wait_s))

    def sync_edge_ewma(self, caller: str, callee: str) -> float | None:
        with self._lock:
            return self._edges.get((caller, callee))

    def observe_merge_stall(self, build_s: float, queue_depth: int = 0) -> None:
        with self._lock:
            self._merge_stall_s = self._ewma(self._merge_stall_s, float(build_s))
            self._merge_depth = self._ewma(
                self._merge_depth if self._merge_samples else None, float(queue_depth))
            self._merge_samples += 1

    def merge_stall_ewma(self) -> float | None:
        with self._lock:
            return self._merge_stall_s

    def stats(self) -> dict:
        with self._lock:
            edges = {f"{a}->{b}": w for (a, b), w in sorted(self._edges.items())}
            return {
                "edges": edges,
                "merge_stall_ewma_s": self._merge_stall_s,
                "merge_depth_ewma": self._merge_depth,
                "merge_samples": self._merge_samples,
            }
