"""Trace exporters: Chrome ``trace_event`` JSON and Prometheus text.

Chrome export is deterministic by construction: events are emitted in the
recorder's canonical order (start time, trace id, span id), timestamps are
microseconds from the injected clock's origin, ``pid`` is the tracer's
registration ordinal within the process and ``tid`` the trace id — no
wall-clock, thread-ident, or object-id field ever reaches the file, and
``json.dumps(sort_keys=True)`` with fixed separators pins the bytes.  Load
the file at ``ui.perfetto.dev`` or ``chrome://tracing``.

The Prometheus dump flattens ``platform.stats()`` plus recorder
aggregates, dispatch-tracer counters, and edge-cost EWMAs into standard
text exposition; ``serve_prometheus`` exposes it on a stdlib HTTP
endpoint for scrape-based setups.
"""
from __future__ import annotations

import json
import re

from repro.obs.trace import CONTROL_TRACE_ID, FlightRecorder, SpanRecord, live_tracers

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
_LABEL_ESC = str.maketrans({"\\": "\\\\", '"': '\\"', "\n": "\\n"})


# ------------------------------------------------------------ chrome JSON


def chrome_events(records: list[SpanRecord], *, pid: int = 1) -> list[dict]:
    events: list[dict] = [
        {"ph": "M", "pid": pid, "tid": CONTROL_TRACE_ID, "name": "thread_name",
         "args": {"name": "control-plane"}},
    ]
    for r in records:
        args = dict(r.args or {})
        args["span_id"] = r.span_id
        args["parent_id"] = r.parent_id
        ev = {
            "name": r.name,
            "cat": r.cat,
            "ph": r.ph,
            "ts": round(r.t0 * 1e6, 3),
            "pid": pid,
            "tid": r.trace_id,
            "args": args,
        }
        if r.ph == "X":
            ev["dur"] = round((r.t1 - r.t0) * 1e6, 3)
        else:
            ev["s"] = "t"  # instant event scoped to its thread (trace)
        events.append(ev)
    return events


def chrome_trace(records: list[SpanRecord], *, pid: int = 1) -> dict:
    return {"traceEvents": chrome_events(records, pid=pid),
            "displayTimeUnit": "ms"}


def dumps_chrome(doc: dict) -> str:
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def export_chrome(path: str, recorder: FlightRecorder) -> int:
    """Write one recorder's trace; returns the number of events."""
    doc = chrome_trace(recorder.snapshot())
    with open(path, "w") as fh:
        fh.write(dumps_chrome(doc))
    return len(doc["traceEvents"])


def export_all_chrome(path: str) -> int:
    """Merge every live tracer in the process into one file, one ``pid``
    per tracer in registration order (load_bench ``--trace``)."""
    events: list[dict] = []
    for i, tracer in enumerate(live_tracers(), start=1):
        events.extend(chrome_events(tracer.recorder.snapshot(), pid=i))
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as fh:
        fh.write(dumps_chrome(doc))
    return len(events)


# ------------------------------------------------------------ prometheus


def _metric_name(parts: tuple[str, ...]) -> str:
    return "repro_" + "_".join(_NAME_RE.sub("_", p).strip("_") or "x" for p in parts)


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    return f"{v:.10g}"


def _flatten(prefix: tuple[str, ...], obj, lines: list[str]) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            key = str(k)
            # map-like keys (edge names, instance ids, percentiles) become
            # labels; plain identifier keys extend the metric name
            if _NAME_RE.search(key) and not isinstance(v, dict):
                if isinstance(v, (int, float)):
                    lines.append(
                        f'{_metric_name(prefix)}{{key="{key.translate(_LABEL_ESC)}"}} {_fmt(v)}')
                continue
            _flatten(prefix + (key,), v, lines)
    elif isinstance(obj, (int, float)):
        lines.append(f"{_metric_name(prefix)} {_fmt(obj)}")
    # strings / lists / None are skipped: gauges only


def prometheus_text(platform=None, *, stats: dict | None = None) -> str:
    """Text-exposition dump: flattened ``platform.stats()`` + flight
    recorder aggregates + dispatch tracer compile/sync counters."""
    lines: list[str] = []
    if stats is None and platform is not None:
        stats = platform.stats()
    if stats:
        _flatten(("stats",), stats, lines)
    tracer = getattr(platform, "tracer", None)
    if tracer is not None:
        agg = tracer.recorder.aggregates()
        lines.append(f"repro_trace_spans_total {agg['spans']}")
        lines.append(f"repro_trace_events_total {agg['events']}")
        lines.append(f"repro_trace_dropped_total {agg['dropped']}")
        for cat, d in sorted(agg["phases"].items()):
            esc = cat.translate(_LABEL_ESC)
            lines.append(f'repro_trace_phase_count{{phase="{esc}"}} {d["count"]}')
            lines.append(
                f'repro_trace_phase_seconds{{phase="{esc}"}} {_fmt(d["seconds"])}')
    edge_costs = getattr(platform, "edge_costs", None)
    if edge_costs is not None:
        cm = edge_costs.stats()
        for edge, w in cm["edges"].items():
            lines.append(
                f'repro_edge_sync_wait_ewma_seconds{{edge="{edge.translate(_LABEL_ESC)}"}} {_fmt(w)}')
        if cm["merge_stall_ewma_s"] is not None:
            lines.append(
                f"repro_merge_stall_ewma_seconds {_fmt(cm['merge_stall_ewma_s'])}")
        lines.append(f"repro_merge_stall_samples_total {cm['merge_samples']}")
    try:
        from repro.analysis.dispatch import TRACER

        snap = TRACER.snapshot()
        lines.append(f"repro_dispatch_compiles_total {snap.compiles}")
        lines.append(f"repro_dispatch_host_syncs_total {snap.host_syncs}")
        lines.append(f"repro_dispatch_decode_steps_total {snap.decode_steps}")
        lines.append(f"repro_dispatch_kernel_calls_total {snap.kernel_calls}")
    except Exception:  # pragma: no cover - dispatch tracer is optional
        pass
    return "\n".join(lines) + "\n"


def serve_prometheus(platform, port: int = 0):
    """Minimal scrape endpoint on ``/metrics``; returns the started
    ``http.server`` instance (``server.server_address[1]`` is the bound
    port, ``server.shutdown()`` stops it)."""
    import http.server
    import threading

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - stdlib API name
            body = prometheus_text(platform).encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # silence per-request stderr noise
            pass

    server = http.server.ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True,
                     name="prometheus-exporter").start()
    return server
