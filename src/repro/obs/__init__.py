"""Always-on, clock-injected observability: causal request tracing, a
bounded flight recorder, deterministic exporters, and critical-path
attribution feeding measured costs back into the fusion policy."""
from repro.obs.critical_path import EdgeCostModel, attribute, attribute_trace, build_trees, summarize
from repro.obs.export import (
    chrome_trace,
    dumps_chrome,
    export_all_chrome,
    export_chrome,
    prometheus_text,
    serve_prometheus,
)
from repro.obs.trace import (
    CONTROL_TRACE_ID,
    PHASES,
    FlightRecorder,
    SpanContext,
    SpanRecord,
    Tracer,
    live_tracers,
    retain_tracers,
)

__all__ = [
    "CONTROL_TRACE_ID",
    "PHASES",
    "EdgeCostModel",
    "FlightRecorder",
    "SpanContext",
    "SpanRecord",
    "Tracer",
    "attribute",
    "attribute_trace",
    "build_trees",
    "chrome_trace",
    "dumps_chrome",
    "export_all_chrome",
    "export_chrome",
    "live_tracers",
    "prometheus_text",
    "retain_tracers",
    "serve_prometheus",
    "summarize",
]
