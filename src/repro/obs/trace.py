"""Causal request tracing: span contexts and a lock-cheap flight recorder.

Span model
----------
Every externally-visible request (``platform.invoke``, ``invoke_async``,
``ContinuousBatcher.submit``) mints a :class:`SpanContext` — one *trace* —
at its entry point.  The context travels with the request object (a field
on ``PendingRequest`` / ``serving._Request``; a thread-local activation for
the serial path) and accumulates *spans*: ``[t0, t1)`` intervals tagged
with a phase category (``cat``).  Leaf phases are laid out so they tile the
request's wall interval exactly — ``critical_path.attribute`` then recovers
per-category latency whose sum (plus the parent self-time gaps) equals the
end-to-end latency *by construction*, and tests assert the residual is zero.

Determinism: trace ids are minted from a single counter in submission
order, span ids from a per-trace counter, and every timestamp comes from
the injected :class:`~repro.scheduler.clock.Clock`.  Nothing in a record
depends on wall time, thread identity, or object ids, so a same-seed
``VirtualClock`` simulation exports byte-identical traces run to run.

Hot-path cost: recording a span is one append to the *calling thread's*
bounded ring buffer behind that buffer's own (uncontended) lock; overflow
drops the oldest record and bumps a drop counter.  The recorder never
blocks the request path on a reader — ``snapshot()`` copies buffers one at
a time.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager

from repro.scheduler.clock import SYSTEM_CLOCK

#: Phase taxonomy (span ``cat`` values).  Roots carry their entry-point
#: kind; attribution maps a root's self-time to "unattributed".
PHASES = frozenset(
    {
        "queue-wait",            # admission lane: enqueue -> window open
        "window-wait",           # coalescer window: open -> dispatch
        "batch-compute",         # batched XLA dispatch / decode loop
        "execute",               # handler-bracketed function execution
        "cross-function-sync",   # ctx.call boundary hop (blocking wait)
        "call-inline",           # ctx.call co-located fused-inline run
        "prefill-stall",         # serve path: alloc -> seated (self-time)
        "prefill-chunk",         # one budgeted chunk inside the stall
        "cold-provision",        # resurrect / restore on the invoke path
        "control-plane",         # merge / split / park / scale spans
    }
)

#: Reserved trace id for the platform-wide control-plane timeline.
CONTROL_TRACE_ID = 0

_ROOT_SPAN_ID = 1


@dataclasses.dataclass(frozen=True, slots=True)
class SpanRecord:
    """One immutable trace event. ``ph`` is ``"X"`` (complete span over
    ``[t0, t1)``) or ``"i"`` (instant event at ``t0``)."""

    trace_id: int
    span_id: int
    parent_id: int
    name: str
    cat: str
    t0: float
    t1: float
    ph: str = "X"
    args: dict | None = None

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0


class _ThreadBuffer:
    """One thread's bounded ring. Only its owner appends; readers copy."""

    GUARDED_FIELDS = {"items": "_lock", "dropped": "_lock", "_head": "_lock"}

    def __init__(self, capacity: int):
        self._lock = threading.Lock()
        self.capacity = capacity
        self.items: list[SpanRecord] = []
        self.dropped = 0
        #: ring cursor: index of the oldest record once the buffer wrapped
        self._head = 0

    def append(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self.items) < self.capacity:
                self.items.append(rec)
            else:
                self.items[self._head] = rec
                self._head = (self._head + 1) % self.capacity
                self.dropped += 1

    def snapshot(self) -> tuple[list[SpanRecord], int]:
        with self._lock:
            ordered = self.items[self._head:] + self.items[: self._head]
            return ordered, self.dropped

    def clear(self) -> None:
        with self._lock:
            self.items = []
            self._head = 0
            self.dropped = 0


class FlightRecorder:
    """Bounded per-thread span sink.

    ``append`` touches only the calling thread's buffer; the shared
    registry lock is taken once per thread lifetime (first append) and by
    readers. Overflow is drop-oldest with an exported drop counter.
    """

    GUARDED_FIELDS = {"_buffers": "_lock"}

    def __init__(self, capacity_per_thread: int = 8192):
        self.capacity_per_thread = int(capacity_per_thread)
        self._lock = threading.Lock()
        self._buffers: list[_ThreadBuffer] = []
        self._tls = threading.local()

    def append(self, rec: SpanRecord) -> None:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = _ThreadBuffer(self.capacity_per_thread)
            self._tls.buf = buf
            with self._lock:
                self._buffers.append(buf)
        buf.append(rec)

    def snapshot(self) -> list[SpanRecord]:
        """All retained records, globally ordered for deterministic export:
        by start time, then trace id, then span id."""
        with self._lock:
            buffers = list(self._buffers)
        records: list[SpanRecord] = []
        for buf in buffers:
            items, _ = buf.snapshot()
            records.extend(items)
        records.sort(key=lambda r: (r.t0, r.trace_id, r.span_id))
        return records

    def dropped(self) -> int:
        with self._lock:
            buffers = list(self._buffers)
        return sum(buf.snapshot()[1] for buf in buffers)

    def clear(self) -> None:
        with self._lock:
            buffers = list(self._buffers)
        for buf in buffers:
            buf.clear()

    def aggregates(self) -> dict:
        """Recorder-level counters for the Prometheus dump: span/event
        totals, drops, and per-phase count + wall seconds."""
        records = self.snapshot()
        phases: dict[str, dict] = {}
        spans = events = 0
        for r in records:
            if r.ph == "i":
                events += 1
                continue
            spans += 1
            agg = phases.setdefault(r.cat, {"count": 0, "seconds": 0.0})
            agg["count"] += 1
            agg["seconds"] += r.dur_s
        return {
            "spans": spans,
            "events": events,
            "dropped": self.dropped(),
            "phases": phases,
        }


class SpanContext:
    """Per-request (or per-batch) trace handle.

    Thread-safe: the span-id counter and the finished flag sit behind the
    context's own lock, so a request whose phases are emitted from the
    coalescer thread while cross-function children land from a worker
    thread never collides.
    """

    GUARDED_FIELDS = {"_next_id": "_lock", "_finished": "_lock"}

    __slots__ = ("tracer", "trace_id", "name", "kind", "t0", "attrs",
                 "_lock", "_next_id", "_finished")

    def __init__(self, tracer: "Tracer", trace_id: int, name: str,
                 kind: str, t0: float, attrs: dict | None = None):
        self.tracer = tracer
        self.trace_id = trace_id
        self.name = name
        self.kind = kind
        self.t0 = t0
        self.attrs = attrs
        self._lock = threading.Lock()
        self._next_id = _ROOT_SPAN_ID
        self._finished = False

    def alloc_id(self) -> int:
        with self._lock:
            self._next_id += 1
            return self._next_id

    def emit(self, name: str, cat: str, t0: float, t1: float, *,
             parent_id: int = _ROOT_SPAN_ID, span_id: int | None = None,
             args: dict | None = None) -> int:
        """Record a completed ``[t0, t1)`` child span; returns its id.
        Pass a pre-allocated ``span_id`` (from :meth:`alloc_id`) when
        children were minted under it while it was still open."""
        sid = self.alloc_id() if span_id is None else span_id
        self.tracer.recorder.append(SpanRecord(
            self.trace_id, sid, parent_id, name, cat,
            float(t0), float(max(t0, t1)), "X", args))
        return sid

    def event(self, name: str, t: float | None = None, *,
              parent_id: int = _ROOT_SPAN_ID, args: dict | None = None) -> None:
        """Instant (zero-duration) marker; ignored by attribution."""
        if t is None:
            t = self.tracer.clock.now()
        self.tracer.recorder.append(SpanRecord(
            self.trace_id, self.alloc_id(), parent_id, name, "event",
            float(t), float(t), "i", args))

    def finish(self, t1: float | None = None, *, args: dict | None = None) -> None:
        """Close the trace: emit the root span covering ``[t0, t1)``.
        Idempotent — later calls are dropped, so error paths may finish
        defensively."""
        with self._lock:
            if self._finished:
                return
            self._finished = True
        if t1 is None:
            t1 = self.tracer.clock.now()
        merged = dict(self.attrs or {})
        if args:
            merged.update(args)
        self.tracer.recorder.append(SpanRecord(
            self.trace_id, _ROOT_SPAN_ID, 0, self.name, self.kind,
            float(self.t0), float(max(self.t0, t1)), "X", merged or None))


#: Registry of live tracers so ``export_all`` (load_bench --trace) can merge
#: every platform's recorder without threading handles through call sites.
_REGISTRY_LOCK = threading.Lock()
_TRACERS: list = []  # weakrefs, in registration order
_NEXT_EXPORT_SEQ = 0
_RETAIN = False
_RETAINED: list = []  # strong refs while retention is on


def _register(tracer: "Tracer") -> int:
    import weakref

    global _NEXT_EXPORT_SEQ
    with _REGISTRY_LOCK:
        _NEXT_EXPORT_SEQ += 1
        _TRACERS.append(weakref.ref(tracer))
        if _RETAIN:
            _RETAINED.append(tracer)
        return _NEXT_EXPORT_SEQ


def retain_tracers(on: bool = True) -> None:
    """Pin a strong reference to every live tracer and every one created
    after this call. The registry is weak by default (a test suite churning
    hundreds of platforms must not accumulate their recorders); an
    export-at-exit tool (``load_bench --trace``) turns retention on so
    spans survive the scenario dropping its platform. ``on=False`` releases
    the pins."""
    global _RETAIN
    with _REGISTRY_LOCK:
        _RETAIN = on
        if on:
            _RETAINED.extend(t for ref in _TRACERS
                             if (t := ref()) is not None and t not in _RETAINED)
        else:
            _RETAINED.clear()


def live_tracers() -> list:
    """Live tracers in registration order (export pid order)."""
    with _REGISTRY_LOCK:
        refs = list(_TRACERS)
    out = []
    for ref in refs:
        t = ref()
        if t is not None:
            out.append(t)
    return out


class Tracer:
    """Mints trace/span ids, owns the recorder, and tracks the active
    span context per thread so nested instrumentation (handler enters,
    remote calls, resurrects) parents itself correctly."""

    GUARDED_FIELDS = {"_next_trace": "_lock"}

    def __init__(self, clock=None, *, capacity_per_thread: int = 8192,
                 enabled: bool = True):
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        self.recorder = FlightRecorder(capacity_per_thread)
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._next_trace = CONTROL_TRACE_ID
        self._tls = threading.local()
        #: platform-wide timeline for merge/split/park/scale events
        self.control = SpanContext(self, CONTROL_TRACE_ID,
                                   "control-plane", "control-plane", 0.0)
        self.export_seq = _register(self)

    # ------------------------------------------------------------- mint

    def begin_request(self, name: str, kind: str, *, t0: float | None = None,
                      attrs: dict | None = None) -> SpanContext | None:
        """New trace rooted at ``t0`` (defaults to now). Returns ``None``
        when tracing is disabled — callers guard every touch on that."""
        if not self.enabled:
            return None
        with self._lock:
            self._next_trace += 1
            tid = self._next_trace
        if t0 is None:
            t0 = self.clock.now()
        return SpanContext(self, tid, name, kind, float(t0), attrs)

    # ------------------------------------------- thread-local activation

    def current(self) -> tuple[SpanContext, int] | None:
        """(active context, parent span id) for this thread, or None."""
        stack = getattr(self._tls, "stack", None)
        if not stack:
            return None
        return stack[-1]

    def push(self, ctx: SpanContext, parent_id: int = _ROOT_SPAN_ID) -> None:
        """Non-scoped activation for enter/exit-bracketed call sites (the
        handler); every push MUST be paired with a :meth:`pop`."""
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        stack.append((ctx, parent_id))

    def pop(self) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack:
            stack.pop()

    @contextmanager
    def activate(self, ctx: SpanContext | None, parent_id: int = _ROOT_SPAN_ID):
        """Make ``ctx`` the ambient parent for instrumentation on this
        thread. ``None`` is accepted and is a no-op so call sites stay
        unconditional."""
        if ctx is None:
            yield
            return
        self.push(ctx, parent_id)
        try:
            yield
        finally:
            self.pop()

    def activate_snapshot(self, cur: tuple[SpanContext, int] | None):
        """Re-activate a ``current()`` snapshot on another thread (the
        orchestrated backend captures it at submit, restores in the
        worker)."""
        if cur is None:
            return self.activate(None)
        return self.activate(cur[0], cur[1])

    # -------------------------------------------------- control timeline

    def control_span(self, name: str, t0: float, t1: float, *,
                     args: dict | None = None) -> None:
        if self.enabled:
            self.control.emit(name, "control-plane", t0, t1,
                              parent_id=0, args=args)

    def control_event(self, name: str, *, t: float | None = None,
                      args: dict | None = None) -> None:
        if self.enabled:
            if t is None:
                t = self.clock.now()
            self.control.event(name, t, parent_id=0, args=args)
