"""Warm-provisioning level 1: executable index + persistent XLA compile cache.

Two layers keep a rebuilt execution unit from paying XLA again:

1. ``EXECUTABLE_INDEX`` (in-process) — a content-addressed map from an
   *executable key* to an already-compiled program.  ``jax.jit`` keys its
   own cache by function identity, and every ``FunctionInstance`` rebuild
   creates fresh closures, so the merge→split→re-merge churn loop recompiles
   programs it was serving seconds earlier.  The index keys by *behavior*
   instead: a digest of every member spec's bytecode, closure values and
   defaults, the parameter/argument tree structure, the shape bucket, and
   the environment (jax version, backend, kernel dispatch mode).  A rebuilt
   unit whose key matches reuses the live executable — zero recompiles.
2. JAX's persistent compilation cache (cross-process) —
   ``enable_persistent_cache`` points jax at an on-disk cache directory so
   even a fresh process (deploy, CI run, resurrect after restart) restores
   serialized executables instead of re-running XLA.

Safety invariants:

- Params are *passed as arguments* at call time (``compiled(params, *args)``),
  so two instances may share an executable while holding different weights;
  only the tree structure/dtypes enter the key.
- Effectful programs (``ctx.call_async`` lowers to an ``io_callback`` whose
  host callback closes over the owning platform) are NEVER inserted, so an
  index hit always yields a pure, platform-agnostic program.  Callers may
  therefore look up *before* tracing.
- Closure cells are digested by VALUE: two stages built from the same
  factory (same code object, different captured routing keys) get distinct
  keys.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import threading
import types
import weakref
from typing import Any, Mapping

import jax
import numpy as np

_MAX_ARRAY_BYTES = 1 << 20  # full-hash cap; larger arrays are sample-hashed
_MAX_DEPTH = 8


def enable_persistent_cache(directory: str) -> str | None:
    """Point jax's persistent compilation cache at ``directory`` (created if
    missing), with thresholds zeroed so even the tiny CPU test programs are
    cached.  Returns the directory on success, None if the running jax
    doesn't support the knobs (best-effort: the executable index still
    works without it)."""
    try:
        os.makedirs(directory, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", directory)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:
        return None
    return directory


def maybe_enable_from_env() -> str | None:
    """Enable the persistent cache when ``REPRO_COMPILE_CACHE`` names a
    directory (the CI workflow persists it across runs via actions/cache)."""
    directory = os.environ.get("REPRO_COMPILE_CACHE", "")
    if not directory:
        return None
    return enable_persistent_cache(directory)


def environment_key() -> tuple:
    """Everything outside the spec that changes what a program lowers to.

    ``dispatch_mode`` matters because ``kernels/ops.py`` picks Pallas vs the
    jnp oracle per call site: flipping ``REPRO_USE_PALLAS`` mid-process must
    miss the index rather than reuse a stale lowering."""
    from repro.kernels import ops

    return (
        jax.__version__,
        jax.default_backend(),
        ops.dispatch_mode(),
        bool(jax.config.jax_enable_x64),
    )


def _digest_code(h, code: types.CodeType) -> None:
    h.update(code.co_code)
    h.update(repr(code.co_names).encode())
    h.update(repr(code.co_varnames).encode())
    h.update(repr(code.co_freevars).encode())
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            _digest_code(h, const)  # nested lambdas / comprehensions
        else:
            h.update(repr(const).encode())


def _digest_update(h, obj: Any, seen: set[int], depth: int = 0) -> None:
    if depth > _MAX_DEPTH:
        h.update(b"<deep>")
        return
    if obj is None or isinstance(obj, (bool, int, float, complex, str, bytes)):
        h.update(repr(obj).encode())
        return
    oid = id(obj)
    if oid in seen:
        h.update(b"<cycle>")
        return
    seen.add(oid)
    code = getattr(obj, "__code__", None)
    if code is not None:
        _digest_code(h, code)
        for cell in getattr(obj, "__closure__", None) or ():
            try:
                val = cell.cell_contents
            except ValueError:
                val = "<empty-cell>"
            _digest_update(h, val, seen, depth + 1)
        _digest_update(h, getattr(obj, "__defaults__", None), seen, depth + 1)
        kwdefaults = getattr(obj, "__kwdefaults__", None)
        for k in sorted(kwdefaults or ()):
            h.update(k.encode())
            _digest_update(h, kwdefaults[k], seen, depth + 1)
        return
    shape = getattr(obj, "shape", None)
    dtype = getattr(obj, "dtype", None)
    if shape is not None and dtype is not None:
        h.update(f"arr:{dtype}:{shape}".encode())
        try:
            arr = np.asarray(obj)
        except Exception:
            h.update(b"<opaque-array>")
            return
        if arr.nbytes <= _MAX_ARRAY_BYTES:
            h.update(np.ascontiguousarray(arr).tobytes())
        else:
            flat = arr.reshape(-1)
            idx = np.linspace(0, flat.shape[0] - 1, num=1024).astype(np.int64)
            h.update(np.ascontiguousarray(flat[idx]).tobytes())
        return
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(type(obj).__name__.encode())
        for f in dataclasses.fields(obj):
            h.update(f.name.encode())
            _digest_update(h, getattr(obj, f.name), seen, depth + 1)
        return
    if isinstance(obj, dict):
        h.update(b"dict")
        try:
            keys = sorted(obj)
        except TypeError:
            keys = list(obj)
        for k in keys:
            h.update(repr(k).encode())
            _digest_update(h, obj[k], seen, depth + 1)
        return
    if isinstance(obj, (list, tuple)):
        h.update(type(obj).__name__.encode())
        for item in obj:
            _digest_update(h, item, seen, depth + 1)
        return
    if isinstance(obj, (set, frozenset)):
        h.update(type(obj).__name__.encode())
        for item in sorted(obj, key=repr):
            _digest_update(h, item, seen, depth + 1)
        return
    if isinstance(obj, types.ModuleType):
        h.update(f"mod:{obj.__name__}".encode())
        return
    # Fallback: repr.  Default reprs embed the object address, so two
    # *distinct* unknown objects never collide (conservatively unequal);
    # value-repr'd objects (np dtypes, enums, paths) compare by content.
    h.update(repr(obj).encode())


# spec digests are memoized by object identity — FunctionSpec is frozen, and
# the weakref finalizer evicts the id when the spec is collected so a reused
# address can't alias a dead spec's digest
_SPEC_DIGESTS: dict[int, str] = {}
_SPEC_LOCK = threading.Lock()


def _evict_spec(key: int) -> None:
    with _SPEC_LOCK:
        _SPEC_DIGESTS.pop(key, None)


def spec_digest(spec) -> str:
    """Content digest of a FunctionSpec's *behavior*: name, trust domain,
    and the full fn closure tree.  Params are excluded — they are call-time
    arguments, and their structure enters the executable key separately."""
    key = id(spec)
    with _SPEC_LOCK:
        got = _SPEC_DIGESTS.get(key)
    if got is not None:
        return got
    h = hashlib.blake2b(digest_size=16)
    h.update(spec.name.encode())
    h.update(spec.trust_domain.encode())
    _digest_update(h, spec.fn, set())
    digest = h.hexdigest()
    with _SPEC_LOCK:
        _SPEC_DIGESTS[key] = digest
    weakref.finalize(spec, _evict_spec, key)
    return digest


def members_digest(specs: Mapping[str, Any]) -> str:
    """Digest of a whole execution unit.  ``TraceContext.call`` inlines
    co-located members into one program, so the key must cover EVERY member's
    spec, not just the entry's."""
    h = hashlib.blake2b(digest_size=16)
    for name in sorted(specs):
        h.update(name.encode())
        h.update(spec_digest(specs[name]).encode())
    return h.hexdigest()


class ExecutableIndex:
    """Process-wide LRU of compiled programs keyed by executable key.

    Entries are ``CompiledEntry`` values from ``core/function.py`` (held
    opaquely — only ``compile_s`` is read, for the saved-seconds counter).
    Only effect-free programs are ever inserted (see module docstring), so a
    hit is always safe to share across instances and platforms."""

    GUARDED_FIELDS = {
        "_entries": "_lock",
        "_hits": "_lock",
        "_misses": "_lock",
        "_inserts": "_lock",
        "_evictions": "_lock",
        "_saved_s": "_lock",
    }

    def __init__(self, max_entries: int = 512):
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._hits = 0
        self._misses = 0
        self._inserts = 0
        self._evictions = 0
        self._saved_s = 0.0

    def lookup(self, key) -> Any | None:
        if key is None:
            return None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self._misses += 1
                return None
            self._entries.move_to_end(key)
            self._hits += 1
            self._saved_s += float(getattr(entry, "compile_s", 0.0))
            return entry

    def insert(self, key, entry) -> None:
        if key is None:
            return
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self._entries[key] = entry
                return
            self._entries[key] = entry
            self._inserts += 1
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self._evictions += 1

    def clear(self) -> None:
        """Drop entries AND counters — used by the coldstart benchmark so a
        retried attempt measures a genuinely cold first cycle."""
        with self._lock:
            self._entries.clear()
            self._hits = 0
            self._misses = 0
            self._inserts = 0
            self._evictions = 0
            self._saved_s = 0.0

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "hits": self._hits,
                "misses": self._misses,
                "inserts": self._inserts,
                "evictions": self._evictions,
                "saved_s": round(self._saved_s, 4),
            }


EXECUTABLE_INDEX = ExecutableIndex()
