"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run driver must set
XLA_FLAGS=--xla_force_host_platform_device_count before first jax init.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
data parallelism — the only cross-pod (DCN) collective is the once-per-step
gradient all-reduce.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} — "
            "run under dryrun.py (it sets --xla_force_host_platform_device_count=512)"
        )
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5; Auto is the default before
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_smoke_mesh(devices=None):
    """Tiny mesh over whatever devices exist (tests)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = 2 if n % 2 == 0 and n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"), devices=devices)
