"""Loop-aware static cost analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, but scan-over-layers puts ~everything inside a while loop — an
88-layer model would be undercounted ~88x. This analyzer parses the HLO
module text into computations, detects while ops and their trip counts
(from the loop-bound constant in the condition computation), and sums

  * FLOPs        — from ``dot`` ops (2 * prod(result_dims) * contraction),
                   including dots inside fusion subcomputations (attributed
                   to their callsites), scaled by enclosing trip counts;
  * HBM bytes    — per top-level instruction: result + operand bytes (the
                   fusion boundary is where XLA materializes buffers;
                   bitcast/tuple/parameter plumbing excluded), scaled by
                   trip counts;
  * collectives  — per op kind, bytes moved per device with ring-model
                   group-size factors ((g-1)/g), scaled by trip counts.

Operands in optimized HLO are untyped name references, so each computation
carries a symbol table (instruction results + header parameters) to resolve
operand shapes.

This is a *static, per-device* traffic model of the compiled program — the
quantity HloCostAnalysis reports, with loops unrolled arithmetically.
Validated against 6*N*D analytic FLOPs in tests/test_dryrun_small.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
COMP_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w.\-]+)\s+\((.*)\)\s*->\s*(.+)\{\s*$")
INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
OP_RE = re.compile(r"[)\]}\s]([a-z][a-z0-9\-]*(?:-start|-done)?)\(")
REF_RE = re.compile(r"%([\w.\-]+)")
CALL_TARGET_RE = re.compile(r"(?:calls|to_apply)=\{?%?([\w.\-]+)")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
CONST_RE = re.compile(r"constant\((\d+)\)")
HEADER_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
# Ops whose operands/results MUST touch HBM even under TPU-grade fusion.
# The CPU backend leaves elementwise chains unfused, so counting every
# instruction massively overstates what a TPU compile would move; this set
# is the fusion-optimal traffic model (documented in EXPERIMENTS.md §Roofline).
_TRAFFIC_OPS = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "sort", "rng-bit-generator",
    *COLLECTIVES,
}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_dims: list[int]
    operand_names: list[str]
    rhs: str
    group_size: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    symbols: dict[str, int]  # name -> result bytes
    dims: dict[str, list[int]] = dataclasses.field(default_factory=dict)  # name -> result dims


def _split_op(rhs: str) -> tuple[str, str, str]:
    """rhs -> (result_type_text, op, paren_contents). The op is the first
    `word(` occurrence outside the result-type prefix."""
    m = OP_RE.search(" " + rhs)  # pad so a leading op still matches
    if m is None:
        return rhs, "", ""
    op = m.group(1)
    idx = m.end()  # position after '('
    depth = 1
    j = idx
    while j < len(rhs) + 1 and depth:
        ch = (" " + rhs)[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        j += 1
    head = (" " + rhs)[: m.start() + 1]
    paren = (" " + rhs)[idx : j - 1]
    return head, op, paren


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        header = COMP_HEADER_RE.match(line)
        if header:
            current = Computation(header.group(1), [], {})
            comps[current.name] = current
            for pname, ptype in HEADER_PARAM_RE.findall(header.group(2)):
                current.symbols[pname] = _shapes_bytes(ptype)
                first = SHAPE_RE.findall(ptype)
                if first:
                    current.dims[pname] = [int(d) for d in first[0][1].split(",") if d]
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = INSTR_RE.match(line.split(" metadata=")[0])
        if m is None:
            continue
        name, rhs = m.group(1), m.group(2)
        head, op, paren = _split_op(rhs)
        result_bytes = _shapes_bytes(head)
        first = SHAPE_RE.findall(head)
        result_dims = [int(d) for d in first[0][1].split(",") if d] if first else []
        current.symbols[name] = result_bytes
        operand_names = REF_RE.findall(paren)
        g = 1
        gi = GROUPS_IOTA_RE.search(rhs)
        if gi:
            g = int(gi.group(2))
        else:
            gb = GROUPS_BRACE_RE.search(rhs)
            if gb:
                g = len([x for x in gb.group(1).split(",") if x.strip() != ""])
        current.dims[name] = result_dims
        current.instrs.append(Instr(name, op, result_bytes, result_dims, operand_names, rhs, g))
    return comps


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)
    top_collectives: list = dataclasses.field(default_factory=list)
    top_traffic: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collective_detail": self.collective_detail,
            "while_trips": self.while_trips,
        }


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        # dims table per computation: name -> dims (instructions + header params)
        self.dims: dict[str, dict[str, list[int]]] = {
            cname: comp.dims for cname, comp in self.comps.items()
        }
        self.by_name: dict[str, dict[str, Instr]] = {
            cname: {ins.name: ins for ins in comp.instrs} for cname, comp in self.comps.items()
        }
        self._fusion_cache: dict[str, float] = {}

    _PURE_LAYOUT_OPS = {"convert", "bitcast", "copy", "transpose", "parameter", "reshape", "broadcast"}

    def _is_layout_fusion(self, called: str) -> bool:
        comp = self.comps.get(called)
        if comp is None:
            return False
        return all(ins.op in self._PURE_LAYOUT_OPS or not ins.op for ins in comp.instrs)

    def _operand_traffic(self, comp: Computation, name: str) -> int:
        """HBM bytes read for one operand. If the operand is a dtype convert /
        layout-only fusion (e.g. a bf16 or fp8 KV cache upconverted to the
        dot's accumulation type), the HBM read happens at the SOURCE dtype —
        on TPU the convert fuses into the consumer (MXU upconverts in-flight)
        — so count the producer's own operand bytes."""
        ins = self.by_name.get(comp.name, {}).get(name)
        if ins is not None and ins.operand_names:
            src = sum(comp.symbols.get(n, 0) for n in ins.operand_names)
            if ins.op == "convert" and 0 < src < ins.result_bytes:
                return src
            if ins.op == "fusion":
                called = CALL_TARGET_RE.findall(ins.rhs)
                if called and self._is_layout_fusion(called[0]) and 0 < src < ins.result_bytes:
                    return src
        return comp.symbols.get(name, 0)

    # ------------------------------------------------------------- helpers

    def entry_name(self) -> str:
        called: set[str] = set()
        for c in self.comps.values():
            for ins in c.instrs:
                called.update(CALL_TARGET_RE.findall(ins.rhs))
                for key in ("body", "condition", "branch_computations"):
                    for mt in re.findall(rf"{key}=\{{?%?([\w.\-]+)", ins.rhs):
                        called.add(mt)
        roots = [n for n in self.comps if n not in called]
        # prefer one that looks like main
        for n in roots:
            if "main" in n:
                return n
        if roots:
            return roots[0]
        return next(iter(self.comps), "")

    def _dot_flops(self, ins: Instr, comp: Computation) -> float:
        if ins.op != "dot":
            return 0.0
        res_elems = 1
        head = ins.rhs.split("dot(")[0]
        mres = SHAPE_RE.findall(head)
        if mres:
            for d in mres[0][1].split(","):
                if d:
                    res_elems *= int(d)
        contraction = 1
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
        lhs_dims = self.dims[comp.name].get(ins.operand_names[0], []) if ins.operand_names else []
        if mc and mc.group(1):
            for ax in mc.group(1).split(","):
                ax = int(ax)
                if ax < len(lhs_dims):
                    contraction *= lhs_dims[ax]
        return 2.0 * res_elems * contraction

    def _fusion_cost(self, name: str, visiting: set[str]) -> tuple[float, float]:
        """(dot flops, dot bytes) inside a fusion subcomputation tree."""
        if name in self._fusion_cache:
            return self._fusion_cache[name]
        comp = self.comps.get(name)
        if comp is None or name in visiting:
            return (0.0, 0.0)
        visiting.add(name)
        flops = 0.0
        dot_bytes = 0.0
        for ins in comp.instrs:
            f = self._dot_flops(ins, comp)
            flops += f
            if f:
                dot_bytes += ins.result_bytes + sum(self._operand_traffic(comp, n) for n in ins.operand_names)
            for t in CALL_TARGET_RE.findall(ins.rhs):
                sub = self._fusion_cost(t, visiting)
                flops += sub[0]
                dot_bytes += sub[1]
        visiting.discard(name)
        self._fusion_cache[name] = (flops, dot_bytes)
        return (flops, dot_bytes)

    def _trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        best = 1
        for ins in cond.instrs:
            for c in CONST_RE.findall(ins.rhs):
                best = max(best, int(c))
        return best

    def _collective_moved(self, base: str, ins: Instr, comp: Computation) -> float:
        g = ins.group_size
        if g <= 1:
            return 0.0
        frac = (g - 1) / g
        operand_bytes = sum(comp.symbols.get(n, 0) for n in ins.operand_names)
        if base == "all-gather":
            return ins.result_bytes * frac
        if base == "all-reduce":
            return 2.0 * operand_bytes * frac
        if base == "reduce-scatter":
            return operand_bytes * frac
        if base == "all-to-all":
            return operand_bytes * frac
        return operand_bytes  # collective-permute

    # ------------------------------------------------------------- analyze

    def analyze(self, entry: str | None = None) -> CostSummary:
        if not self.comps:
            return CostSummary(collective_detail={op: {"count": 0, "bytes": 0.0} for op in COLLECTIVES})
        entry = entry or self.entry_name()
        summary = CostSummary(collective_detail={op: {"count": 0, "bytes": 0.0} for op in COLLECTIVES})
        visiting: set[str] = set()

        def walk(name: str, mult: float) -> None:
            comp = self.comps.get(name)
            if comp is None or name in visiting:
                return
            visiting.add(name)
            for ins in comp.instrs:
                op = ins.op
                if op.endswith("-done"):
                    continue
                base = op[: -len("-start")] if op.endswith("-start") else op
                if base == "while":
                    mb = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                    mc = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                    trips = self._trip_count(mc.group(1)) if mc else 1
                    summary.while_trips[f"{name}/{ins.name}"] = trips
                    if mb:
                        walk(mb.group(1), mult * trips)
                    continue
                if base in ("conditional", "call"):
                    for key in ("branch_computations", "to_apply", "calls"):
                        for t in re.findall(rf"{key}=\{{?%?([\w.\-]+)", ins.rhs):
                            walk(t, mult)
                    continue
                if base in _TRAFFIC_OPS:
                    if base in ("scatter", "dynamic-update-slice"):
                        # in-place update (donated/aliased buffers on TPU):
                        # traffic = updates read + updated-region write, NOT
                        # a full read+write of the target buffer
                        moved_bytes = 2 * sum(comp.symbols.get(n, 0) for n in ins.operand_names[1:])
                    else:
                        operand_bytes = sum(self._operand_traffic(comp, n) for n in ins.operand_names)
                        moved_bytes = ins.result_bytes + operand_bytes
                    summary.bytes += moved_bytes * mult
                    if moved_bytes * mult > 0:
                        summary.top_traffic.append(
                            {"op": base, "total_bytes": moved_bytes * mult, "per_op_bytes": moved_bytes,
                             "trips": mult, "comp": name, "line": ins.rhs[:140]}
                        )
                summary.flops += self._dot_flops(ins, comp) * mult
                if base == "fusion":
                    for t in CALL_TARGET_RE.findall(ins.rhs):
                        f, b = self._fusion_cost(t, visiting)
                        summary.flops += f * mult
                        summary.bytes += b * mult
                        if b * mult > 0:
                            summary.top_traffic.append(
                                {"op": "fusion:dots", "total_bytes": b * mult, "per_op_bytes": b,
                                 "trips": mult, "comp": name, "line": ins.rhs[:140]}
                            )
                if base in COLLECTIVES:
                    moved = self._collective_moved(base, ins, comp)
                    summary.collective_bytes += moved * mult
                    summary.collective_detail[base]["count"] += max(1, int(mult))
                    summary.collective_detail[base]["bytes"] += moved * mult
                    summary.top_collectives.append(
                        {
                            "op": base,
                            "total_bytes": moved * mult,
                            "per_op_bytes": moved,
                            "trips": mult,
                            "comp": name,
                            "line": ins.rhs[:160],
                        }
                    )
            visiting.discard(name)

        walk(entry, 1.0)
        summary.top_collectives.sort(key=lambda r: -r["total_bytes"])
        summary.top_collectives = summary.top_collectives[:20]
        summary.top_traffic.sort(key=lambda r: -r["total_bytes"])
        summary.top_traffic = summary.top_traffic[:20]
        return summary


def analyze(text: str, entry: str | None = None) -> CostSummary:
    return HloAnalyzer(text).analyze(entry)
