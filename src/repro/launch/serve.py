"""Serving launcher: deploy a model as a Provuse function chain and serve a
batched request stream, reporting per-token latency before/after the
platform's automatic fusion.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --backend tinyjax --requests 64 --tokens 16
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--backend", default="tinyjax", choices=["tinyjax", "orchestrated"])
    ap.add_argument("--no-fusion", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--min-observations", type=int, default=2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced_config
    from repro.core import FusionPolicy, OrchestratedBackend, TinyJaxBackend
    from repro.launch.compile_cache import maybe_enable_from_env
    from repro.models.model import build_model
    from repro.serving.engine import ServingEngine

    # REPRO_COMPILE_CACHE=<dir>: persistent XLA cache — relaunches restore
    # executables instead of rebuilding them (the cold-start story).
    maybe_enable_from_env()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    Backend = TinyJaxBackend if args.backend == "tinyjax" else OrchestratedBackend
    policy = FusionPolicy(min_observations=args.min_observations, merge_cost_s=0.0, enabled=not args.no_fusion)
    platform = Backend(policy)
    engine = ServingEngine(model, platform, max_len=args.max_len)

    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        inputs = {
            "src_embeds": jnp.asarray(rng.standard_normal((args.batch, args.prompt_len, cfg.d_model)) * 0.02, jnp.bfloat16),
            "tokens": jnp.zeros((args.batch, 1), jnp.int32),
        }
    elif cfg.family == "vlm":
        inputs = {"embeds": jnp.asarray(rng.standard_normal((args.batch, args.prompt_len, cfg.d_model)) * 0.02, jnp.bfloat16)}
    else:
        inputs = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}

    t0 = time.perf_counter()
    toks, lat = engine.generate(inputs, steps=args.tokens)
    wall = time.perf_counter() - t0
    stats = platform.stats()
    merges = [m for m in stats["merges"] if m["healthy"]]
    pre = float(np.median(lat[:3])) if len(lat) >= 3 else float("nan")
    post = float(np.median(lat[-3:])) if len(lat) >= 3 else float("nan")
    print(json.dumps({
        "arch": cfg.name,
        "backend": platform.backend_name,
        "fusion": not args.no_fusion,
        "generated": list(map(int, np.asarray(toks[0])[:8])),
        "merges": [list(m["members"]) for m in merges],
        "per_token_ms_pre": round(pre * 1e3, 2),
        "per_token_ms_post": round(post * 1e3, 2),
        "instances_left": len(stats["instances"]),
        "ram_bytes": stats["ram_bytes"],
        "billing_gb_s": round(stats["billing"]["total_gb_s"], 6),
        "wall_s": round(wall, 2),
    }, indent=2))
    platform.shutdown()


if __name__ == "__main__":
    main()
