import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms from the compiled artifact.

For each cell this driver:
  1. builds the production mesh — (16,16) single-pod or (2,16,16) multi-pod;
  2. builds sharded ShapeDtypeStructs for params / optimizer state / inputs /
     KV caches (zero allocation — a 34B-param train state stays symbolic);
  3. jits the right program (train_step / prefill / decode), ``.lower()``s
     and ``.compile()``s it;
  4. records ``memory_analysis()`` (proves the per-device footprint fits),
     ``cost_analysis()`` (FLOPs / bytes for the roofline), and the
     collective schedule parsed from the optimized HLO;
  5. appends one JSON line to the results file.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import re
import subprocess
import sys
import time

HW = {  # TPU v5e per chip (assignment constants)
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,  # per link; we take the single-link figure (DESIGN.md §8)
}

def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the symbolic defs."""
    from repro.models.model import build_model
    from repro.models.params import param_count

    model = build_model(cfg)
    total = param_count(model.param_defs)
    active = total
    if cfg.num_experts:
        # replace per-layer expert params with top-k worth of experts
        from repro.models.params import param_count as pc

        expert_per_layer = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts
        active_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts_per_tok
        active = total - cfg.num_layers * (expert_per_layer - active_expert)
    return total, active


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    _, active = count_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else (shape.seq_len if shape.kind == "prefill" else 1))
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path: str | None) -> dict:
    import jax

    from repro.configs import applicable_shapes, get_arch, get_shape, shape_skip_reason
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import build_model
    from repro.models.params import param_structs
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.sharding.specs import decode_rules, infer_rules, train_rules
    from repro.training.train_step import make_train_state_defs, make_train_step

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    skip = shape_skip_reason(cfg, shape_name)
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "kind": shape.kind,
    }
    if skip:
        record.update(status="skipped", reason=skip)
        if out_path:
            with open(out_path, "a") as f:
                f.write(json.dumps(record) + "\n")
        return record

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    if shape.kind == "decode":
        rules = decode_rules(mesh, kv_heads=cfg.num_kv_heads or None, batch=shape.global_batch)
    elif shape.kind == "prefill":
        rules = infer_rules(mesh, kv_heads=cfg.num_kv_heads or None)
    else:
        rules = train_rules(mesh)
    model = build_model(cfg, rules)

    with mesh:
        if shape.kind == "train":
            defs = make_train_state_defs(model)
            state_structs = param_structs(defs, mesh, rules)
            batch_structs = param_structs(model.input_defs(shape), mesh, rules)
            step = make_train_step(model, AdamWConfig(), cosine_schedule(3e-4, 100, 10000))
            lowered = jax.jit(step, donate_argnums=0).lower(state_structs, batch_structs)
        elif shape.kind == "prefill":
            p_structs = param_structs(model.param_defs, mesh, rules)
            in_structs = param_structs(model.input_defs(shape), mesh, rules)
            lowered = jax.jit(model.prefill_fn).lower(p_structs, in_structs)
        else:  # decode
            p_structs = param_structs(model.param_defs, mesh, rules)
            in_structs = param_structs(model.input_defs(shape), mesh, rules)
            cache_structs = param_structs(model.cache_defs(shape), mesh, rules)
            lowered = jax.jit(model.decode_fn, donate_argnums=2).lower(p_structs, in_structs, cache_structs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    from repro.launch.hlo_analysis import analyze

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 wraps the dict in a list
        ca = ca[0] if ca else {}
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    summary = analyze(compiled.as_text())  # loop-aware (trip-count-scaled)
    flops = summary.flops
    bytes_accessed = summary.bytes
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
    }
    # CPU backend's peak stat can be unreliable; the conservative footprint
    # is arguments (resident params/opt/caches) + temp arena + outputs.
    footprint = mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"]
    # Per-device param residency (from the sharded struct shapes).
    import numpy as _np

    def _dev_bytes(struct):
        shard = struct.sharding.shard_shape(struct.shape)
        return int(_np.prod(shard)) * struct.dtype.itemsize

    if shape.kind == "train":
        p_structs_for_count = param_structs(model.param_defs, mesh, rules)
    else:
        p_structs_for_count = p_structs
    params_dev = sum(_dev_bytes(s) for s in jax.tree.leaves(p_structs_for_count))
    # TPU estimate for inference programs: XLA:CPU materializes every scan-xs
    # layer slice (~2x params of dead temp); XLA:TPU windows into the stacked
    # buffer instead. Documented in EXPERIMENTS.md §Dry-run.
    if shape.kind in ("prefill", "decode") and cfg.num_layers > 1:
        tpu_est = footprint - int(2 * params_dev * (1 - 1.0 / cfg.num_layers))
        tpu_est = max(tpu_est, mem["argument_bytes"] + mem["output_bytes"])  # floor: live buffers
    else:
        tpu_est = footprint

    mf = model_flops(cfg, shape)
    total_params, active_params = count_params(cfg)
    compute_s = flops / HW["peak_flops_bf16"]
    memory_s = bytes_accessed / HW["hbm_bw"]
    collective_s = summary.collective_bytes / HW["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    record.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        raw_cost_analysis={"flops": raw_flops, "bytes": raw_bytes,
                           "note": "while-bodies counted once by XLA; see corrected fields"},
        collectives={
            "total_bytes": summary.collective_bytes,
            **summary.collective_detail,
        },
        while_trips=summary.while_trips,
        memory=mem,
        hbm_per_device_gb=round(footprint / 2**30, 3),
        fits_16gb=footprint < 16 * 2**30,
        params_bytes_per_device=params_dev,
        hbm_tpu_estimate_gb=round(tpu_est / 2**30, 3),
        fits_16gb_tpu_est=tpu_est < 16 * 2**30,
        params_total=total_params,
        params_active=active_params,
        model_flops_global=mf,
        model_flops_per_device=mf / n_chips,
        useful_flops_ratio=(mf / n_chips) / flops if flops else 0.0,
        roofline={
            **{k: round(v, 6) for k, v in terms.items()},
            "dominant": dominant,
            "bound_s": round(max(terms.values()), 6),
        },
    )
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(record) + "\n")
    return record


def all_cells(multi_pod: bool):
    from repro.configs import ARCHS, applicable_shapes

    for arch, cfg in ARCHS.items():
        for shape_name in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            yield arch, shape_name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    if args.all:
        done = set()
        if args.skip_done and os.path.exists(args.out):
            with open(args.out) as f:
                for line in f:
                    try:
                        r = json.loads(line)
                        done.add((r["arch"], r["shape"], r["mesh"]))
                    except json.JSONDecodeError:
                        continue
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for arch, shape_name in all_cells(args.multi_pod):
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                if (arch, shape_name, mesh_name) in done:
                    print(f"[skip-done] {arch} {shape_name} {mesh_name}", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape_name, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                print(f"[cell] {arch} {shape_name} {mesh_name}", flush=True)
                t0 = time.perf_counter()
                try:
                    proc = subprocess.run(cmd, timeout=args.timeout, capture_output=True, text=True)
                    if proc.returncode != 0:
                        err = (proc.stderr or "").strip().splitlines()
                        msg = err[-1] if err else f"exit {proc.returncode}"
                        with open(args.out, "a") as f:
                            f.write(json.dumps({"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "error", "reason": msg[-500:]}) + "\n")
                        print(f"  ERROR: {msg[-200:]}", flush=True)
                except subprocess.TimeoutExpired:
                    with open(args.out, "a") as f:
                        f.write(json.dumps({"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "timeout"}) + "\n")
                    print("  TIMEOUT", flush=True)
                print(f"  done in {time.perf_counter()-t0:.0f}s", flush=True)
        return

    record = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    print(json.dumps(record, indent=2))


if __name__ == "__main__":
    main()
