"""Training launcher.

CPU-scale driver for real runs in this container; the same entry point
drives a pod by passing --mesh (the mesh/sharding machinery is identical —
see dryrun.py for the 256/512-chip lowering proof).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mode", default="affine", choices=["affine", "random"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import dataclasses

    import jax

    from repro.checkpointing import CheckpointManager
    from repro.configs import get_arch, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticTokenPipeline
    from repro.launch.compile_cache import maybe_enable_from_env
    from repro.models.model import build_model

    # REPRO_COMPILE_CACHE=<dir>: persistent XLA cache across train relaunches
    maybe_enable_from_env()
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.training import TrainLoop
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.microbatches > 1:
        cfg = dataclasses.replace(cfg, microbatches=args.microbatches)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = build_model(cfg)
    step_fn = make_train_step(
        model, AdamWConfig(lr=args.lr), cosine_schedule(args.lr, max(1, args.steps // 10), args.steps)
    )
    state = init_train_state(model, jax.random.PRNGKey(0))
    manager = CheckpointManager(args.ckpt_dir, retain=3, async_save=True)
    loop = TrainLoop(
        step_fn,
        lambda start: SyntheticTokenPipeline(cfg, shape, seed=0, mode=args.mode, start_batch=start),
        manager,
        ckpt_every=args.ckpt_every,
    )
    t0 = time.perf_counter()
    state, history = loop.run(state, args.steps)
    wall = time.perf_counter() - t0
    for h in history[:: args.log_every]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} {h['seconds']*1e3:.0f}ms")
    tokens = args.steps * args.batch * args.seq
    print(json.dumps({
        "arch": cfg.name, "steps": args.steps, "wall_s": round(wall, 1),
        "tokens_per_s": round(tokens / wall, 1),
        "final_loss": round(history[-1]["loss"], 4),
        "first_loss": round(history[0]["loss"], 4),
        "stragglers": len(loop.straggler_events),
    }))


if __name__ == "__main__":
    main()
