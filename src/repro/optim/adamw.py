"""AdamW with fp32 first/second moments sharded exactly like the params
(ZeRO posture: the FSDP axes on every param carry over to m/v, so optimizer
state for a 34B model is ~1.6 GB/chip on the 256-chip pod).

Functional API; ``adamw_state_defs`` mirrors the param ParamDefs at fp32 so
the dry-run can lower a full train_step with zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, map_defs


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_state_defs(param_defs):
    """m/v ParamDef trees: same shapes + logical axes, fp32, zero-init."""
    as_fp32 = lambda d: dataclasses.replace(d, dtype=jnp.float32, init="zeros")
    return {
        "step": ParamDef((), (), init="zeros", dtype=jnp.int32),
        "m": map_defs(as_fp32, param_defs),
        "v": map_defs(as_fp32, param_defs),
    }


def adamw_init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig, lr_schedule: Callable[[jax.Array], jax.Array] | None = None):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(step) if lr_schedule is not None else jnp.asarray(cfg.lr, jnp.float32)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(opt_state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(opt_state["v"])[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
