from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_state_defs,
    adamw_update,
    global_norm,
)
from repro.optim.schedule import cosine_schedule  # noqa: F401
