"""Fusion policy: which observed synchronous edges become fusion requests.

Constraints carried over from the paper (§3, §6):
* only *synchronous* edges fuse (async/non-blocking calls never do);
* both functions must share a trust domain (fusion reduces isolation);
* fusion cost (rebuild + redeploy, here: retrace + recompile) is amortized
  over subsequent invocations — the policy requires the projected saving
  over the amortization horizon to exceed the merge cost.

Fusion groups are maintained by union-find: A+B merged, then (B->C) observed
=> the next merge hosts {A, B, C}. The platform converges to one execution
unit per synchronous chain, which is the paper's Fig. 5 staircase.
"""
from __future__ import annotations

import dataclasses
import threading


class UnionFind:
    def __init__(self):
        self._parent: dict[str, str] = {}

    def find(self, x: str) -> str:
        self._parent.setdefault(x, x)
        root = x
        while self._parent[root] != root:
            root = self._parent[root]
        while self._parent[x] != root:  # path compression
            self._parent[x], x = root, self._parent[x]
        return root

    def union(self, a: str, b: str) -> str:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self._parent[rb] = ra
        return ra

    def group(self, x: str) -> frozenset[str]:
        root = self.find(x)
        return frozenset(m for m in self._parent if self.find(m) == root)


@dataclasses.dataclass
class FusionDecision:
    fuse: bool
    reason: str
    group: frozenset[str] = frozenset()


@dataclasses.dataclass
class FusionPolicy:
    """min_observations: sync-edge observations before fusing (lets the
    platform be sure the edge is hot, not incidental).
    merge_cost_s: assumed cost of one merge (retrace+recompile+healthcheck);
    measured values are fed back by the Merger after each merge.
    amortization_horizon: invocations over which the merge must pay off.
    """

    min_observations: int = 3
    amortization_horizon: int = 500
    merge_cost_s: float = 2.0
    enabled: bool = True

    def __post_init__(self):
        self.groups = UnionFind()
        self._lock = threading.Lock()
        self._fused_edges: set[tuple[str, str]] = set()

    def feedback_merge_cost(self, seconds: float) -> None:
        # exponential moving average of observed merge costs
        self.merge_cost_s = 0.5 * self.merge_cost_s + 0.5 * seconds

    def decide(self, caller: str, callee: str, stats, trust_a: str, trust_b: str) -> FusionDecision:
        with self._lock:
            if not self.enabled:
                return FusionDecision(False, "fusion disabled")
            if (caller, callee) in self._fused_edges:
                return FusionDecision(False, "edge already fused")
            if trust_a != trust_b:
                return FusionDecision(False, f"trust domains differ ({trust_a} vs {trust_b})")
            if self.groups.find(caller) == self.groups.find(callee):
                return FusionDecision(False, "already in same fusion group")
            if stats.sync_count < self.min_observations:
                return FusionDecision(False, f"only {stats.sync_count} observations")
            projected_saving = stats.mean_wait_s * self.amortization_horizon
            if projected_saving < self.merge_cost_s:
                return FusionDecision(
                    False,
                    f"not amortizable: saving {projected_saving:.3f}s < cost {self.merge_cost_s:.3f}s",
                )
            group = self.groups.group(caller) | self.groups.group(callee) | {caller, callee}
            return FusionDecision(True, "sync edge hot + amortizable", frozenset(group))

    def commit(self, caller: str, callee: str) -> frozenset[str]:
        with self._lock:
            self._fused_edges.add((caller, callee))
            self.groups.union(caller, callee)
            return self.groups.group(caller)
