"""Fusion policy: which observed synchronous edges become fusion requests.

Constraints carried over from the paper (§3, §6):
* only *synchronous* edges fuse (async/non-blocking calls never do);
* both functions must share a trust domain (fusion reduces isolation);
* fusion cost (rebuild + redeploy, here: retrace + recompile) is amortized
  over subsequent invocations — the policy requires the projected saving
  over the amortization horizon to exceed the merge cost.

Fusion groups are maintained by union-find: A+B merged, then (B->C) observed
=> the next merge hosts {A, B, C}. The platform converges to one execution
unit per synchronous chain, which is the paper's Fig. 5 staircase.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Iterable

from repro.scheduler.adaptive import SchedulerSignals
from repro.scheduler.clock import SYSTEM_CLOCK


class UnionFind:
    def __init__(self):
        self._parent: dict[str, str] = {}

    def find(self, x: str) -> str:
        self._parent.setdefault(x, x)
        root = x
        while self._parent[root] != root:
            root = self._parent[root]
        while self._parent[x] != root:  # path compression
            self._parent[x], x = root, self._parent[x]
        return root

    def union(self, a: str, b: str) -> str:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self._parent[rb] = ra
        return ra

    def group(self, x: str) -> frozenset[str]:
        root = self.find(x)
        return frozenset(m for m in self._parent if self.find(m) == root)

    def split_cells(self, cells: Iterable[frozenset[str]]) -> None:
        """Dissolve one group into the given partition cells: members of a
        cell stay unioned with each other and disconnected from every other
        cell. Only valid when the cells' union is a complete group (no
        outside member roots through it) — which is how fission uses it."""
        for cell in cells:
            root = min(cell)
            for member in cell:
                self._parent[member] = root


@dataclasses.dataclass
class FusionDecision:
    fuse: bool
    reason: str
    group: frozenset[str] = frozenset()
    # The alternative arm of the fuse decision (Konflux frames fusion as a
    # cost-model choice): don't merge — add a replica of the saturated callee
    # instead. Set only when replica spin-up is estimated cheaper than the
    # merge; the Merger forwards it to the autoscaler as a scale-out hint.
    replicate: bool = False


@dataclasses.dataclass
class SplitDecision:
    split: bool
    reason: str
    # Partition of the fused group's members: each cell becomes one rebuilt
    # execution unit (singletons for saturation/tail regret; hot singletons +
    # one cold residual cell for traffic divergence).
    partition: tuple[frozenset[str], ...] = ()


@dataclasses.dataclass
class FusionPolicy:
    """min_observations: sync-edge observations before fusing (lets the
    platform be sure the edge is hot, not incidental).
    merge_cost_s: assumed cost of one merge (retrace+recompile+healthcheck);
    measured values are fed back by the Merger after each merge.
    amortization_horizon: invocations over which the merge must pay off.

    Scheduler-feedback knobs (used when `decide` receives live
    :class:`SchedulerSignals` from the request scheduler):
    saturation_occupancy/saturation_depth: a chain whose batches already
    run at least this full with at least this many requests queued is
    *saturated* — micro-batching is absorbing the load, and the merge's
    recompile stall lands exactly when clients are waiting, so the
    projected saving must beat ``saturation_penalty x`` the merge cost.
    promote_wait_s: a *cold* (unsaturated) chain whose per-edge sync-wait
    tail (p95) reaches this long gets promoted — half the observation floor
    and ``promote_discount x`` the merge cost — because per-request blocking
    dominates and fusion removes it directly. The chain's end-to-end p95
    gates this: blocking must be a meaningful share of observed latency.
    """

    # provlint: un-annotated, so dataclasses ignores it (not a field).
    # merge_cost_s is RMW'd by feedback_merge_cost while decide reads it —
    # both must hold _lock (the PR 2 race).
    GUARDED_FIELDS = {
        "merge_cost_s": "_lock",
        "groups": "_lock",
        "_fused_edges": "_lock",
        "_edge_backoff": "_lock",
        "_sat_streak": "_lock",
        "_slo_streak": "_lock",
    }

    min_observations: int = 3
    amortization_horizon: int = 500
    merge_cost_s: float = 2.0
    enabled: bool = True
    saturation_occupancy: float = 0.85
    saturation_depth: int = 1
    saturation_penalty: float = 4.0
    promote_wait_s: float = 0.05
    promote_discount: float = 0.5
    # ---- fuse-vs-replicate knobs ----
    # A SATURATED callee poses a choice: merging drags the caller into the
    # hot instance (and pays a recompile stall mid-overload), while a replica
    # is warm (restore-not-rebuild) and adds capacity directly. When the
    # measured replica spin-up time is <= replicate_bias x the merge cost,
    # `decide` returns replicate=True instead of weighing the penalized
    # merge. max_replica_hint stops hinting once the callee already holds
    # that many replicas — more capacity isn't the fix at that point, and
    # the penalized-merge arm gets its turn again.
    replicate_enabled: bool = True
    replicate_bias: float = 1.0
    max_replica_hint: int = 4
    # ---- fission (reversible fusion) knobs ----
    # split_occupancy/split_depth/split_sustain: a fused group whose batches
    # run at least split_occupancy full with split_depth+ requests queued for
    # split_sustain consecutive regret evaluations is *saturated*: its one
    # serialized unit has become the bottleneck, so fission rebuilds
    # per-partition units to win back parallel dispatch.
    # regret_p95_factor: post-merge tail regret — the group splits when its
    # recent p95 exceeds this multiple of the pre-merge baseline snapshotted
    # at commit time.
    # cold_rate_ratio: traffic-divergence regret — members whose recent
    # request rate fell below this fraction of the hottest member's are
    # "cold"; hot members split out as singletons, cold ones stay co-located.
    # min_group_age_s / remerge_backoff_s: hysteresis. A fresh merge cannot
    # split before min_group_age_s (no reacting to its own swap transient),
    # and a split group's edges cannot re-merge within remerge_backoff_s —
    # together they bound merge<->split flapping to one transition per
    # backoff period even under pathological oscillating load.
    fission_enabled: bool = True
    split_occupancy: float = 0.9
    split_depth: int = 2
    split_sustain: int = 3
    regret_p95_factor: float = 1.5
    cold_rate_ratio: float = 0.05
    min_group_age_s: float = 1.0
    remerge_backoff_s: float = 10.0
    # Injectable time source (hysteresis backoffs, streak bookkeeping):
    # tests drive merge<->split flap windows on a virtual clock, no sleeps.
    clock: Any = None

    # provlint: un-annotated — not a dataclass field. The platform assigns
    # its obs.EdgeCostModel here at construction (write-once, before
    # traffic); when present, `decide` weighs MEASURED sync-edge waits and
    # merge stalls instead of the static mean_wait_s / saturation_penalty
    # knobs. The model has its own lock; reading the attribute is safe.
    cost_model = None

    def __post_init__(self):
        if self.clock is None:
            self.clock = SYSTEM_CLOCK
        self.groups = UnionFind()
        self._lock = threading.Lock()
        self._fused_edges: set[tuple[str, str]] = set()
        self._edge_backoff: dict[tuple[str, str], float] = {}
        self._sat_streak: dict[frozenset[str], int] = {}
        self._slo_streak: dict[frozenset[str], int] = {}

    def feedback_merge_cost(self, seconds: float) -> None:
        # exponential moving average of observed merge costs; `decide` reads
        # merge_cost_s under the lock, so the read-modify-write takes it too
        with self._lock:
            self.merge_cost_s = 0.5 * self.merge_cost_s + 0.5 * seconds

    def decide(
        self,
        caller: str,
        callee: str,
        stats,
        trust_a: str,
        trust_b: str,
        signals: SchedulerSignals | Callable[[], SchedulerSignals] | None = None,
        *,
        replica_spinup_s: float | None = None,
        callee_replicas: int = 1,
    ) -> FusionDecision:
        """``signals``: a :class:`SchedulerSignals`, or a zero-arg callable
        returning one — resolved only past the cheap early-outs so hot
        unfusable edges (observed on every sync call) don't pay for a
        scheduler snapshot per invocation.

        ``replica_spinup_s``: the platform's measured warm replica spin-up
        estimate (None when no replica has ever spun up — the replicate arm
        then never fires, so callers without an autoscaler are unaffected).
        ``callee_replicas``: how many replicas already serve the callee."""
        with self._lock:
            if not self.enabled:
                return FusionDecision(False, "fusion disabled")
            if (caller, callee) in self._fused_edges:
                return FusionDecision(False, "edge already fused")
            if self._edge_backoff.get((caller, callee), 0.0) > self.clock.now():
                # the group this edge belonged to was just split — immediately
                # re-merging on the same (still-warm) observation counters
                # would flap merge<->split on every oscillation of the load
                return FusionDecision(False, "recently split (fission hysteresis)")
            if trust_a != trust_b:
                return FusionDecision(False, f"trust domains differ ({trust_a} vs {trust_b})")
            if self.groups.find(caller) == self.groups.find(callee):
                return FusionDecision(False, "already in same fusion group")
            if stats.sync_count < max(1, self.min_observations // 2):
                # below even the promoted floor: no signal can change this
                return FusionDecision(False, f"only {stats.sync_count} observations")
            min_obs = self.min_observations
            required_cost = self.merge_cost_s
            note = ""
            # Measured costs (obs.EdgeCostModel, fed by the tracing layer)
            # displace the static knobs when samples exist: the edge's OWN
            # observed sync-wait EWMA prices the saving, and the measured
            # merge stall prices the saturation cost below.
            cm = self.cost_model
            measured_edge_s = cm.sync_edge_ewma(caller, callee) if cm is not None else None
            measured_stall_s = cm.merge_stall_ewma() if cm is not None else None
            if callable(signals):
                signals = signals()
            if signals is not None:
                saturated = (
                    signals.mean_occupancy >= self.saturation_occupancy
                    and signals.queue_depth >= self.saturation_depth
                )
                # Promotion keys on the edge's own SYNC-WAIT tail — the time
                # fusion actually removes. End-to-end p95 (queueing + compute)
                # only gates it: a chain whose latency is dominated by slow
                # compute, not blocking, gains nothing from an early merge.
                edge_wait_s = getattr(stats, "p95_wait_s", stats.mean_wait_s)
                blocking_matters = (
                    signals.p95_ms == 0.0 or edge_wait_s >= 0.2 * signals.p95_ms / 1e3
                )
                # An SLO class violating its target on this chain promotes
                # the merge IF removing the edge's sync-wait tail would
                # plausibly un-violate it — fusion is then not a throughput
                # optimization but the mechanism that restores the target.
                viol = signals.worst_violation()
                slo_fixable = (
                    viol is not None
                    and viol[1] - edge_wait_s * 1e3 <= viol[2]
                    and edge_wait_s > 0.0
                )
                if saturated:
                    if (
                        self.replicate_enabled
                        and replica_spinup_s is not None
                        and callee_replicas < self.max_replica_hint
                        and replica_spinup_s <= self.merge_cost_s * self.replicate_bias
                    ):
                        return FusionDecision(
                            False,
                            f"saturated callee: warm replica "
                            f"(~{replica_spinup_s:.3f}s) beats merge "
                            f"(~{self.merge_cost_s:.3f}s) — replicate instead",
                            replicate=True,
                        )
                    if measured_stall_s is not None:
                        # Measured replacement for the static multiplier:
                        # merging NOW serializes the measured build stall in
                        # front of every queued request, so that — not a
                        # fixed 4x — is what the saving must beat.
                        required_cost = (
                            self.merge_cost_s
                            + measured_stall_s * max(1, signals.queue_depth)
                        )
                        note = (
                            f" [saturated: measured stall ~{measured_stall_s:.3f}s"
                            f" x depth {signals.queue_depth}]"
                        )
                    else:
                        required_cost *= self.saturation_penalty
                        note = " [deprioritized: chain saturated]"
                elif slo_fixable:
                    required_cost *= self.promote_discount
                    min_obs = max(1, min_obs // 2)
                    note = (
                        f" [promoted: class {viol[0]!r} at p95 {viol[1]:.1f}ms vs "
                        f"target {viol[2]:.1f}ms; merge removes ~{edge_wait_s * 1e3:.1f}ms wait]"
                    )
                elif edge_wait_s >= self.promote_wait_s and blocking_matters:
                    required_cost *= self.promote_discount
                    min_obs = max(1, min_obs // 2)
                    note = " [promoted: cold chain, long sync waits]"
            if stats.sync_count < min_obs:
                return FusionDecision(False, f"only {stats.sync_count} observations{note}")
            edge_mean_s = stats.mean_wait_s if measured_edge_s is None else measured_edge_s
            projected_saving = edge_mean_s * self.amortization_horizon
            if projected_saving < required_cost:
                return FusionDecision(
                    False,
                    f"not amortizable: saving {projected_saving:.3f}s "
                    f"< cost {required_cost:.3f}s{note}",
                )
            group = self.groups.group(caller) | self.groups.group(callee) | {caller, callee}
            return FusionDecision(True, f"sync edge hot + amortizable{note}", frozenset(group))

    def commit(self, caller: str, callee: str) -> frozenset[str]:
        with self._lock:
            self._fused_edges.add((caller, callee))
            self.groups.union(caller, callee)
            group = self.groups.group(caller)
            self._sat_streak.pop(group, None)
            self._slo_streak.pop(group, None)
            return group

    # ------------------------------------------------------------- fission

    def decide_split(
        self,
        members: frozenset[str],
        *,
        signals: SchedulerSignals | None = None,
        member_rates: dict[str, float] | None = None,
        baseline_rates: dict[str, float] | None = None,
        baseline_p95_ms: float = 0.0,
        current_p95_ms: float = 0.0,
        age_s: float = 0.0,
        replica_count: int = 1,
    ) -> SplitDecision:
        """Regret check for one committed fusion group, evaluated off the
        data path by the control plane's reconciler.

        ``signals`` is the group's live scheduler snapshot, ``member_rates``
        the per-member recent request rates (handler.recent_rate),
        ``baseline_p95_ms`` the pre-merge tail snapshotted at commit,
        ``current_p95_ms`` the recent post-merge tail, ``age_s`` time since
        the merge committed. Four regret signals, checked in order:
        sustained saturation, a sustained SLO-class violation on the group,
        post-merge tail regression, member traffic divergence (edge gone
        cold).

        ``replica_count``: how many replicas the platform already runs of
        this fused unit. Replication is itself a fission-pressure signal —
        the autoscaler had to clone the WHOLE group to keep up, so the
        co-located unit is the bottleneck replica_count times over, and
        splitting wins back per-member parallel dispatch on every replica.
        A replicated group therefore needs only half the sustained-streak
        evidence before the saturation/SLO checks fire."""
        members = frozenset(members)
        with self._lock:
            if not self.fission_enabled or len(members) < 2:
                return SplitDecision(False, "fission disabled or singleton group")
            if age_s < self.min_group_age_s:
                return SplitDecision(
                    False, f"group too young ({age_s:.2f}s < {self.min_group_age_s}s hysteresis)"
                )
            singletons = tuple(frozenset((m,)) for m in sorted(members))
            # replication pressure (see docstring): a cloned group halves the
            # sustained-evidence requirement for the streak-based checks
            sustain = (
                self.split_sustain
                if replica_count <= 1
                else max(1, self.split_sustain // 2)
            )
            pressure = "" if replica_count <= 1 else (
                f"; replica pressure: {replica_count} replicas halved the "
                f"sustain floor"
            )
            # --- sustained saturation: the fused unit serializes a load the
            # scheduler could be running in parallel across per-member units
            saturated = (
                signals is not None
                and signals.mean_occupancy >= self.split_occupancy
                and signals.queue_depth >= self.split_depth
            )
            if saturated:
                streak = self._sat_streak.get(members, 0) + 1
                self._sat_streak[members] = streak
                if streak >= sustain:
                    self._sat_streak.pop(members, None)
                    return SplitDecision(
                        True,
                        f"sustained saturation ({streak} consecutive evaluations at "
                        f"occupancy {signals.mean_occupancy:.2f}, depth "
                        f"{signals.queue_depth}{pressure})",
                        singletons,
                    )
            else:
                self._sat_streak.pop(members, None)
            # --- SLO-class regret: a strict class sustained above its target
            # on the fused group means the one serialized unit is violating a
            # deadline per-member units could meet in parallel. Sustained
            # (same streak discipline as saturation) so one tail blip — or
            # the merge's own swap transient — cannot trigger fission; the
            # min_group_age_s/remerge_backoff_s hysteresis bounds flapping
            # when the target is simply unachievable either way.
            viol = signals.worst_violation() if signals is not None else None
            if viol is not None:
                streak = self._slo_streak.get(members, 0) + 1
                self._slo_streak[members] = streak
                if streak >= sustain:
                    self._slo_streak.pop(members, None)
                    return SplitDecision(
                        True,
                        f"SLO class {viol[0]!r} violated on fused group ({streak} "
                        f"consecutive evaluations at p95 {viol[1]:.1f}ms vs target "
                        f"{viol[2]:.1f}ms{pressure})",
                        singletons,
                    )
            else:
                self._slo_streak.pop(members, None)
            # --- post-merge tail regret vs the baseline snapshotted at commit
            if (
                baseline_p95_ms > 0.0
                and current_p95_ms >= self.regret_p95_factor * baseline_p95_ms
            ):
                return SplitDecision(
                    True,
                    f"post-merge p95 regressed ({current_p95_ms:.1f}ms >= "
                    f"{self.regret_p95_factor}x baseline {baseline_p95_ms:.1f}ms)",
                    singletons,
                )
            # --- traffic divergence: the fused members no longer share a
            # workload — hot members split out, cold ones stay co-located.
            # Only members that had DIRECT demand at commit time can go cold:
            # an interior chain member is served by inlined calls, so its
            # direct rate reads 0 whether the chain is hot or dead.
            if member_rates:
                hottest = max(member_rates.values())
                cold = frozenset(
                    m for m in members
                    if member_rates.get(m, 0.0) <= self.cold_rate_ratio * hottest
                    and (baseline_rates or {}).get(m, 0.0) > 0.0
                )
                hot = members - cold
                if hottest > 0.0 and cold and hot:
                    partition = tuple(frozenset((m,)) for m in sorted(hot)) + (cold,)
                    return SplitDecision(
                        True,
                        f"member traffic diverged (cold: {sorted(cold)} at <= "
                        f"{self.cold_rate_ratio:.0%} of hottest member's rate)",
                        partition,
                    )
            return SplitDecision(False, "no regret signal")

    def dissolve(self, cells: Iterable[frozenset[str]], backoff_s: float | None = None) -> None:
        """Un-commit a fused group along the given partition: fused edges
        crossing cells are forgotten, the union-find group dissolves into
        the cells, and every crossing pair enters the re-merge backoff
        window (hysteresis — see ``remerge_backoff_s``)."""
        cells = [frozenset(c) for c in cells]
        members = frozenset().union(*cells) if cells else frozenset()
        cell_of = {m: i for i, cell in enumerate(cells) for m in cell}
        until = self.clock.now() + (self.remerge_backoff_s if backoff_s is None else backoff_s)
        with self._lock:
            for a in members:
                for b in members:
                    if a != b and cell_of[a] != cell_of[b]:
                        self._edge_backoff[(a, b)] = until
            self._fused_edges = {
                (a, b)
                for (a, b) in self._fused_edges
                if not (a in cell_of and b in cell_of and cell_of[a] != cell_of[b])
            }
            self.groups.split_cells(cells)
            self._sat_streak.pop(members, None)
            self._slo_streak.pop(members, None)
