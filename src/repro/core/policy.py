"""Fusion policy: which observed synchronous edges become fusion requests.

Constraints carried over from the paper (§3, §6):
* only *synchronous* edges fuse (async/non-blocking calls never do);
* both functions must share a trust domain (fusion reduces isolation);
* fusion cost (rebuild + redeploy, here: retrace + recompile) is amortized
  over subsequent invocations — the policy requires the projected saving
  over the amortization horizon to exceed the merge cost.

Fusion groups are maintained by union-find: A+B merged, then (B->C) observed
=> the next merge hosts {A, B, C}. The platform converges to one execution
unit per synchronous chain, which is the paper's Fig. 5 staircase.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable

from repro.scheduler.adaptive import SchedulerSignals


class UnionFind:
    def __init__(self):
        self._parent: dict[str, str] = {}

    def find(self, x: str) -> str:
        self._parent.setdefault(x, x)
        root = x
        while self._parent[root] != root:
            root = self._parent[root]
        while self._parent[x] != root:  # path compression
            self._parent[x], x = root, self._parent[x]
        return root

    def union(self, a: str, b: str) -> str:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self._parent[rb] = ra
        return ra

    def group(self, x: str) -> frozenset[str]:
        root = self.find(x)
        return frozenset(m for m in self._parent if self.find(m) == root)


@dataclasses.dataclass
class FusionDecision:
    fuse: bool
    reason: str
    group: frozenset[str] = frozenset()


@dataclasses.dataclass
class FusionPolicy:
    """min_observations: sync-edge observations before fusing (lets the
    platform be sure the edge is hot, not incidental).
    merge_cost_s: assumed cost of one merge (retrace+recompile+healthcheck);
    measured values are fed back by the Merger after each merge.
    amortization_horizon: invocations over which the merge must pay off.

    Scheduler-feedback knobs (used when `decide` receives live
    :class:`SchedulerSignals` from the request scheduler):
    saturation_occupancy/saturation_depth: a chain whose batches already
    run at least this full with at least this many requests queued is
    *saturated* — micro-batching is absorbing the load, and the merge's
    recompile stall lands exactly when clients are waiting, so the
    projected saving must beat ``saturation_penalty x`` the merge cost.
    promote_wait_s: a *cold* (unsaturated) chain whose per-edge sync-wait
    tail (p95) reaches this long gets promoted — half the observation floor
    and ``promote_discount x`` the merge cost — because per-request blocking
    dominates and fusion removes it directly. The chain's end-to-end p95
    gates this: blocking must be a meaningful share of observed latency.
    """

    min_observations: int = 3
    amortization_horizon: int = 500
    merge_cost_s: float = 2.0
    enabled: bool = True
    saturation_occupancy: float = 0.85
    saturation_depth: int = 1
    saturation_penalty: float = 4.0
    promote_wait_s: float = 0.05
    promote_discount: float = 0.5

    def __post_init__(self):
        self.groups = UnionFind()
        self._lock = threading.Lock()
        self._fused_edges: set[tuple[str, str]] = set()

    def feedback_merge_cost(self, seconds: float) -> None:
        # exponential moving average of observed merge costs; `decide` reads
        # merge_cost_s under the lock, so the read-modify-write takes it too
        with self._lock:
            self.merge_cost_s = 0.5 * self.merge_cost_s + 0.5 * seconds

    def decide(
        self,
        caller: str,
        callee: str,
        stats,
        trust_a: str,
        trust_b: str,
        signals: SchedulerSignals | Callable[[], SchedulerSignals] | None = None,
    ) -> FusionDecision:
        """``signals``: a :class:`SchedulerSignals`, or a zero-arg callable
        returning one — resolved only past the cheap early-outs so hot
        unfusable edges (observed on every sync call) don't pay for a
        scheduler snapshot per invocation."""
        with self._lock:
            if not self.enabled:
                return FusionDecision(False, "fusion disabled")
            if (caller, callee) in self._fused_edges:
                return FusionDecision(False, "edge already fused")
            if trust_a != trust_b:
                return FusionDecision(False, f"trust domains differ ({trust_a} vs {trust_b})")
            if self.groups.find(caller) == self.groups.find(callee):
                return FusionDecision(False, "already in same fusion group")
            if stats.sync_count < max(1, self.min_observations // 2):
                # below even the promoted floor: no signal can change this
                return FusionDecision(False, f"only {stats.sync_count} observations")
            min_obs = self.min_observations
            required_cost = self.merge_cost_s
            note = ""
            if callable(signals):
                signals = signals()
            if signals is not None:
                saturated = (
                    signals.mean_occupancy >= self.saturation_occupancy
                    and signals.queue_depth >= self.saturation_depth
                )
                # Promotion keys on the edge's own SYNC-WAIT tail — the time
                # fusion actually removes. End-to-end p95 (queueing + compute)
                # only gates it: a chain whose latency is dominated by slow
                # compute, not blocking, gains nothing from an early merge.
                edge_wait_s = getattr(stats, "p95_wait_s", stats.mean_wait_s)
                blocking_matters = (
                    signals.p95_ms == 0.0 or edge_wait_s >= 0.2 * signals.p95_ms / 1e3
                )
                if saturated:
                    required_cost *= self.saturation_penalty
                    note = " [deprioritized: chain saturated]"
                elif edge_wait_s >= self.promote_wait_s and blocking_matters:
                    required_cost *= self.promote_discount
                    min_obs = max(1, min_obs // 2)
                    note = " [promoted: cold chain, long sync waits]"
            if stats.sync_count < min_obs:
                return FusionDecision(False, f"only {stats.sync_count} observations{note}")
            projected_saving = stats.mean_wait_s * self.amortization_horizon
            if projected_saving < required_cost:
                return FusionDecision(
                    False,
                    f"not amortizable: saving {projected_saving:.3f}s "
                    f"< cost {required_cost:.3f}s{note}",
                )
            group = self.groups.group(caller) | self.groups.group(callee) | {caller, callee}
            return FusionDecision(True, f"sync edge hot + amortizable{note}", frozenset(group))

    def commit(self, caller: str, callee: str) -> frozenset[str]:
        with self._lock:
            self._fused_edges.add((caller, callee))
            self.groups.union(caller, callee)
            return self.groups.group(caller)
