"""FaaS functions and their serving instances.

A :class:`FunctionSpec` is the *bring-your-own-function-code* unit: a pure
JAX-traceable callable ``fn(ctx, params, *args)`` whose only impurity is
calling other functions through the platform context (``ctx.call`` /
``ctx.call_async``).

A :class:`FunctionInstance` is the running analogue of a FaaS container: it
hosts one or more functions' code + weights. Entries whose trace is
*self-contained* (leaf functions; fused groups whose calls all resolve to
co-located members) are served as ONE compiled XLA program. Entries with a
synchronous boundary call run as *interpreter glue* (EagerContext): the
function's code executes in the host runtime and each outbound call is a
real blocking dispatch through the platform — the blocking-socket analogue
the Function Handler observes. Fusion turns glued chains into compiled
units; the payoff is real compiler-level cross-function optimization, not
simulation.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import InvocationError


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    name: str
    fn: Callable  # fn(ctx, params, *args) -> pytree
    params: Any = None
    trust_domain: str = "default"
    description: str = ""


# Per-instance runtime footprint (container language runtime + loaded libs).
# A FaaS instance is a container; tinyFaaS/K8s Python containers idle at
# ~30-60 MiB RSS, and the paper's RAM savings come precisely from retiring
# these duplicated runtimes. Our in-process instances share one interpreter,
# so the platform's RAM metric models this per-container constant explicitly
# (documented in EXPERIMENTS.md §Paper-fidelity); buffer accounting
# (weights + compiled workspace) is measured, not modeled.
INSTANCE_RUNTIME_OVERHEAD_BYTES = 32 * 2**20


def tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total


def _structs_of(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), tree)


def _struct_key(tree) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef), tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


class InstanceState(enum.Enum):
    """Control-plane lifecycle: PROVISIONING (being built/compiled) ->
    READY (health-checked, not yet routed) -> SERVING (routed) ->
    DRAINING (displaced, in-flight requests finishing) -> RETIRED (drained,
    memory freed). Transitions are driven by the ControlPlane's epoch
    publishes; see repro.core.lifecycle."""

    PROVISIONING = "provisioning"
    READY = "ready"
    SERVING = "serving"
    DRAINING = "draining"
    RETIRED = "retired"


@dataclasses.dataclass
class CompiledEntry:
    compiled: Any
    temp_bytes: int
    code_bytes: int
    output_bytes: int
    compile_s: float


class BatchingUnsupported(Exception):
    """Entry cannot run as one batched program (e.g. host-callback effects)."""


def _finalize_compiled(compiled, t0: float) -> CompiledEntry:
    """Package a compiled executable with its memory-analysis footprint."""
    temp = code = out = 0
    try:
        ma = compiled.memory_analysis()
        temp = int(getattr(ma, "temp_size_in_bytes", 0) or 0)
        code = int(getattr(ma, "generated_code_size_in_bytes", 0) or 0)
        out = int(getattr(ma, "output_size_in_bytes", 0) or 0)
    except Exception:  # pragma: no cover - backend without memory analysis
        pass
    return CompiledEntry(compiled, temp, code, out, time.perf_counter() - t0)


def _footprint_bytes(params, compiled: dict) -> int:
    """One instance's live footprint: container runtime constant + weights +
    compiled-program workspace/code/output buffers. Shared by the live
    `resident_bytes` metric and `retire`'s freed-bytes accounting so the RAM
    the control plane reports freed is exactly the RAM it was counting."""
    total = INSTANCE_RUNTIME_OVERHEAD_BYTES + tree_bytes(params)
    for ce in compiled.values():
        total += ce.temp_bytes + ce.code_bytes + ce.output_bytes
    return total


class FunctionInstance:
    """One running execution unit hosting >= 1 functions ("members")."""

    _counter = 0
    _counter_lock = threading.Lock()

    GUARDED_FIELDS = {
        "cache_hits": "_lock",
        "cache_misses": "_lock",
        "compile_wall_s": "_lock",
    }

    def __init__(self, specs: dict[str, FunctionSpec], platform):
        with FunctionInstance._counter_lock:
            FunctionInstance._counter += 1
            seq = FunctionInstance._counter
        self.members: dict[str, FunctionSpec] = dict(specs)
        self.instance_id = f"inst{seq}[{'+'.join(sorted(specs))}]"
        self.platform = platform
        self.params: dict[str, Any] = {n: s.params for n, s in specs.items()}
        self.state = InstanceState.PROVISIONING
        self._compiled: dict[tuple, CompiledEntry] = {}
        self._eager_entries: set[tuple] = set()
        self._batch_unsupported: set[tuple] = set()
        self._lock = threading.Lock()
        self._active = 0
        self._idle_event = threading.Event()
        self._idle_event.set()
        self.created_at = time.perf_counter()
        # provisioning profile: executable-index hits vs real XLA compiles
        self.cache_hits = 0
        self.cache_misses = 0
        self.compile_wall_s = 0.0
        # Content digest of every member's behavior (TraceContext.call inlines
        # co-located members, so the compiled program depends on ALL of them)
        # plus the param-tree structure. None disables executable sharing for
        # this instance — indexing is an optimization, never a requirement.
        try:
            from repro.launch.compile_cache import members_digest

            self._members_digest = members_digest(self.members)
            self._params_skey = _struct_key(self.params)
        except Exception:  # pragma: no cover - undigestable spec
            self._members_digest = None
            self._params_skey = None

    # ----------------------------------------------------------- lifecycle

    def mark_ready(self):
        self.state = InstanceState.READY

    def mark_serving(self):
        """Routed by an epoch publish (called under the routing lock)."""
        if self.state != InstanceState.RETIRED:
            self.state = InstanceState.SERVING

    def begin_drain(self):
        """Displaced by an epoch publish (called under the routing lock, in
        the same critical section that removed this instance's last route)."""
        with self._lock:
            if self.state != InstanceState.RETIRED:
                self.state = InstanceState.DRAINING

    def begin_request(self):
        with self._lock:
            if self.state == InstanceState.RETIRED:
                raise InvocationError(f"{self.instance_id} is {self.state.value}")
            self._active += 1
            self._idle_event.clear()

    def end_request(self):
        with self._lock:
            self._active -= 1
            if self._active == 0:
                self._idle_event.set()

    def outstanding(self) -> int:
        """In-flight request count (begin/end_request bracketing) — the
        least-outstanding spread's load signal. Pod work queued behind a
        busy orchestrated worker but not yet begun is not counted."""
        with self._lock:
            return self._active

    def retire(self, timeout: float = 30.0) -> int:
        """Drain in-flight requests, terminate, free weights. Returns bytes
        freed (the RAM the fusion reclaims).

        The RETIRED flip and the in-flight check share the instance lock, so
        a request that slipped past resolution cannot begin AFTER the params
        are freed: either it begins while DRAINING (and retire keeps
        waiting), or it finds RETIRED and raises InvocationError into the
        platform's re-resolve retry path."""
        self.begin_drain()
        if self.state == InstanceState.RETIRED:
            return 0  # idempotent: already drained and freed
        deadline = time.perf_counter() + timeout
        while True:
            with self._lock:
                if self._active == 0 or time.perf_counter() >= deadline:
                    self.state = InstanceState.RETIRED
                    params, compiled = self.params, self._compiled
                    self.params = {}
                    self._compiled = {}
                    break
            self._idle_event.wait(max(0.0, deadline - time.perf_counter()))
        return _footprint_bytes(params, compiled)

    # ----------------------------------------------------------- compile

    def _entry_callable(self, entry: str):
        from repro.core.context import TraceContext

        spec = self.members[entry]

        def run(params_by_member, *args):
            ctx = TraceContext(self.platform, self, params_by_member, entry)
            return spec.fn(ctx, params_by_member[entry], *args)

        return run

    def _executable_key(self, kind: str, entry: str, skey: tuple, bucket: int | None = None):
        """Process-wide executable-index key, or None when indexing is off."""
        if self._members_digest is None:
            return None
        from repro.launch.compile_cache import environment_key

        return (kind, entry, self._members_digest, self._params_skey, skey,
                bucket, environment_key())

    def _note_compile(self, *, hit: bool, seconds: float, saved_s: float = 0.0) -> None:
        note = getattr(self.platform, "note_compile", None)
        if note is not None:
            note(hit=hit, seconds=seconds, saved_s=saved_s)

    def get_compiled(self, entry: str, args: tuple) -> CompiledEntry | None:
        """Compiled program for this entry, or None when the entry crosses an
        instance boundary synchronously (-> interpreter-glue execution)."""
        key = (entry, _struct_key(args))
        with self._lock:
            if key in self._eager_entries:
                return None
            got = self._compiled.get(key)
        if got is not None:
            return got
        from repro.core.context import BoundaryCall
        from repro.launch.compile_cache import EXECUTABLE_INDEX

        t0 = time.perf_counter()
        # Index lookup happens BEFORE tracing: the key doesn't depend on the
        # trace, and only effect-free programs are ever inserted, so a hit is
        # always a pure program safe to share across instances/platforms.
        xkey = self._executable_key("single", entry, key[1])
        cached = EXECUTABLE_INDEX.lookup(xkey)
        if cached is not None:
            entry_obj = dataclasses.replace(cached, compile_s=time.perf_counter() - t0)
            with self._lock:
                self._compiled[key] = entry_obj
                self.cache_hits += 1
            self._note_compile(hit=True, seconds=entry_obj.compile_s,
                               saved_s=cached.compile_s)
            return entry_obj
        run = self._entry_callable(entry)
        params_structs = _structs_of(self.params)
        arg_structs = _structs_of(args)
        try:
            traced = jax.jit(run).trace(params_structs, *arg_structs)
            compiled = traced.lower().compile()
        except BoundaryCall:
            with self._lock:
                self._eager_entries.add(key)
            return None
        entry_obj = _finalize_compiled(compiled, t0)
        with self._lock:
            self._compiled[key] = entry_obj
            self.cache_misses += 1
            self.compile_wall_s += entry_obj.compile_s
        # Effectful programs (ctx.call_async -> io_callback closing over THIS
        # platform) must stay private to this instance; sharing one would
        # route another platform's async calls through a dead dispatcher.
        if not traced.jaxpr.effects:
            EXECUTABLE_INDEX.insert(xkey, entry_obj)
        self._note_compile(hit=False, seconds=entry_obj.compile_s)
        return entry_obj

    # ----------------------------------------------------------- execute

    def execute(self, entry: str, args: tuple):
        """Run one request to completion (synchronous, device-synced)."""
        ce = self.get_compiled(entry, args)
        if ce is None:  # interpreter glue: host-dispatched outbound calls
            from repro.core.context import EagerContext

            spec = self.members[entry]
            ctx = EagerContext(self.platform, self, self.params, entry)
            out = spec.fn(ctx, self.params[entry], *args)
        else:
            out = ce.compiled(self.params, *args)
        jax.block_until_ready(out)
        return out

    # ----------------------------------------------------------- batched execute

    def _get_batched(self, entry: str, args: tuple, bucket: int) -> CompiledEntry | None:
        """Compiled program serving ``bucket`` requests of this entry at once,
        or None when the entry cannot be a single program (boundary calls,
        unbatchable effects).

        The program takes the k request pytrees SEPARATELY, stacks them along
        a new leading axis inside the trace, vmaps the entry over it, and
        slices the outputs back apart — so gather/scatter of the batch is
        XLA-fused with the compute and the host pays ONE dispatch per batch
        (per-leaf host-side stack/split was measured at ~10x the cost of the
        batched execution itself)."""
        key = ("__batch__", entry, _struct_key(args), bucket)
        with self._lock:
            if key in self._batch_unsupported:
                return None
            got = self._compiled.get(key)
        if got is not None:
            return got
        from repro.launch.compile_cache import EXECUTABLE_INDEX
        from repro.scheduler.batching import split_results, stack_requests

        t0 = time.perf_counter()
        xkey = self._executable_key("batch", entry, key[2], bucket)
        cached = EXECUTABLE_INDEX.lookup(xkey)
        if cached is not None:
            entry_obj = dataclasses.replace(cached, compile_s=time.perf_counter() - t0)
            with self._lock:
                self._compiled[key] = entry_obj
                self.cache_hits += 1
            self._note_compile(hit=True, seconds=entry_obj.compile_s,
                               saved_s=cached.compile_s)
            return entry_obj
        run = self._entry_callable(entry)

        def batched_run(params, *requests):
            stacked = stack_requests(list(requests))
            outs = jax.vmap(run, in_axes=(None,) + (0,) * len(stacked))(params, *stacked)
            return tuple(split_results(outs, len(requests)))

        params_structs = _structs_of(self.params)
        arg_structs = _structs_of(args)
        try:
            # One trace serves both the effects check and the lowering —
            # tracing a model-sized entry twice would double the compile
            # stall the bucket-reuse logic exists to avoid.
            traced = jax.jit(batched_run).trace(params_structs, *([arg_structs] * bucket))
            # Effectful entries (ctx.call_async -> io_callback) must NOT
            # batch: the callback fires once per vmap lane, so bucket padding
            # would replay the last request's side effects per padded lane.
            if traced.jaxpr.effects:
                raise BatchingUnsupported(entry)
            compiled = traced.lower().compile()
        except Exception:  # noqa: BLE001 — includes BoundaryCall. Batching is an
            # optimization: anything vmap/XLA rejects (boundary dispatch, host
            # callbacks, effects) falls back to per-request execution, never
            # to a request failure.
            with self._lock:
                self._batch_unsupported.add(key)
            return None
        entry_obj = _finalize_compiled(compiled, t0)
        with self._lock:
            self._compiled[key] = entry_obj
            self.cache_misses += 1
            self.compile_wall_s += entry_obj.compile_s
        # Reaching here implies traced.jaxpr.effects was empty (effectful
        # entries raised BatchingUnsupported above) — safe to share.
        EXECUTABLE_INDEX.insert(xkey, entry_obj)
        self._note_compile(hit=False, seconds=entry_obj.compile_s)
        return entry_obj

    def execute_batch(self, entry: str, args_list: list[tuple], max_bucket: int | None = None) -> list:
        """Run k compatible requests as ONE execution where possible.

        Requests stack along a new leading axis, padded up to a power-of-two
        bucket (capped at ``max_bucket``, normally the scheduler's max_batch,
        so a full batch never pads past its configured size) — at most
        O(log max_batch) batched programs ever compile. The batch axis is
        carried by vmap, so each request sees its original shapes. Entries
        that cannot compile as one program run per-request."""
        k = len(args_list)
        if k == 1:
            return [self.execute(entry, args_list[0])]
        from repro.scheduler.batching import next_batch_bucket

        skey = _struct_key(args_list[0])
        with self._lock:
            # Prefer an already-compiled bucket that fits (padding is nearly
            # free; a fresh XLA compile mid-traffic is a multi-second stall).
            fitting = [
                key[3] for key in self._compiled
                if len(key) == 4 and key[0] == "__batch__" and key[1] == entry
                and key[2] == skey and key[3] >= k
            ]
        bucket = min(fitting) if fitting else next_batch_bucket(k, max_bucket)
        if bucket < k:
            # Non-power-of-two max_bucket clamps below k (e.g. 6 requests,
            # cap 6 -> bucket 4): run power-of-two chunks instead of minting
            # a never-reused bucket-6 program.
            out: list = []
            for i in range(0, k, bucket):
                out.extend(self.execute_batch(entry, args_list[i : i + bucket], max_bucket))
            return out
        ce = self._get_batched(entry, args_list[0], bucket)
        if ce is None:
            return [self.execute(entry, a) for a in args_list]
        padded = args_list + [args_list[-1]] * (bucket - k)
        outs = ce.compiled(self.params, *padded)
        jax.block_until_ready(outs)
        return list(outs[:k])

    # ----------------------------------------------------------- metrics

    def provision_profile(self) -> dict:
        """How this instance's programs came to exist: executable-index hits
        vs real XLA compiles (and their wall seconds). A fully warm build has
        ``cache_misses == 0`` — the signal the provisioning stats use to
        classify a merge/split/resurrect as warm."""
        with self._lock:
            return {
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "compile_wall_s": round(self.compile_wall_s, 4),
            }

    def resident_bytes(self) -> int:
        """Live footprint of this execution unit: the container runtime
        constant + weights + compiled-program workspace (temp arena),
        generated code, and output staging buffers."""
        if self.state == InstanceState.RETIRED:
            return 0
        with self._lock:
            return _footprint_bytes(self.params, self._compiled)

    def __repr__(self):
        return f"<{self.instance_id} {self.state.value} members={sorted(self.members)}>"
