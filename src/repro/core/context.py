"""Invocation contexts — where fusion actually happens.

Execution model (the FaaS analogy, made robust):

* **Eager glue** (:class:`EagerContext`) — the vanilla runtime. User function
  code runs op-by-op in the host interpreter (a container's language
  runtime); every ``ctx.call`` is a *real blocking host dispatch* through the
  platform to the callee instance. The wait is observed by the Function
  Handler — the paper's blocking-socket detection.
* **Compiled unit** (:class:`TraceContext`) — when an entry point is
  *self-contained* (a leaf function, or a fused group whose internal calls
  all resolve to co-located members), the platform traces it into ONE XLA
  program: co-located calls inline; async calls become fire-and-forget
  ``io_callback``s. Tracing that hits a *synchronous boundary* call raises
  :class:`BoundaryCall` and the platform falls back to eager glue for that
  entry — a compiled program never blocks mid-execution on another instance.

Function fusion therefore does exactly what the paper's Merger does: it
turns a chain of interpreter-glued units into one compiled unit, eliminating
per-hop dispatch, interpreter overhead, and intermediate materialization.

``AbstractContext`` mirrors user code under ``jax.eval_shape`` so the
platform can pre-compute output signatures without running anything.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback


class BoundaryCall(Exception):
    """Raised when tracing an entry reaches a synchronous call to a function
    that is NOT co-located — the entry cannot be a single compiled unit."""

    def __init__(self, caller: str, callee: str):
        super().__init__(f"{caller} -> {callee} crosses the instance boundary")
        self.caller = caller
        self.callee = callee


class TraceContext:
    """Context used while tracing a (candidate) compiled unit."""

    def __init__(self, platform, instance, params_by_member, member: str):
        self._platform = platform
        self._instance = instance
        self._params = params_by_member
        self.member = member

    def _child(self, member: str) -> "TraceContext":
        return TraceContext(self._platform, self._instance, self._params, member)

    def call(self, name: str, *args):
        if name in self._instance.members:  # co-located: inline (FUSION)
            # recorded ONCE, at trace time: a fused-inline edge compiles to
            # zero runtime dispatches, which is exactly the point — the trace
            # timeline shows the edge folding into the compiled unit
            self._platform.tracer.control_event(
                f"fused-inline:{self.member}->{name}",
                args={"caller": self.member, "callee": name,
                      "instance": self._instance.instance_id})
            spec = self._instance.members[name]
            return spec.fn(self._child(name), self._params[name], *args)
        raise BoundaryCall(self.member, name)

    def call_async(self, name: str, *args):
        """Fire-and-forget: enqueue at the callee WITHOUT waiting. Safe inside
        a compiled program (the callback never blocks on another program)."""
        caller_fn = self.member
        platform = self._platform
        caller_instance = self._instance

        def _fire(*flat_args):
            platform.async_call(caller_instance, caller_fn, name, flat_args)
            return np.int32(0)

        return io_callback(_fire, jax.ShapeDtypeStruct((), jnp.int32), *args, ordered=False)


class EagerContext:
    """Context for interpreter-glued (vanilla) execution."""

    def __init__(self, platform, instance, params_by_member, member: str):
        self._platform = platform
        self._instance = instance
        self._params = params_by_member
        self.member = member

    def _child(self, member: str) -> "EagerContext":
        return EagerContext(self._platform, self._instance, self._params, member)

    def call(self, name: str, *args):
        if name in self._instance.members:  # co-located member: run its code here
            # fused-inline: a distinct span kind from the boundary hop, so a
            # trace shows WHICH calls fusion already absorbed
            platform = self._platform
            cur = platform.tracer.current()
            spec = self._instance.members[name]
            if cur is None:
                return spec.fn(self._child(name), self._params[name], *args)
            ctx, parent = cur
            sid = ctx.alloc_id()
            t0 = platform.clock.now()
            with platform.tracer.activate(ctx, sid):
                out = spec.fn(self._child(name), self._params[name], *args)
            ctx.emit(f"{self.member}->{name}", "call-inline", t0,
                     platform.clock.now(), parent_id=parent, span_id=sid,
                     args={"caller": self.member, "callee": name})
            return out
        # real blocking dispatch through the platform (observed sync edge)
        return self._platform.remote_call(self._instance, self.member, name, args)

    def call_async(self, name: str, *args):
        self._platform.async_call(self._instance, self.member, name, args)
        return jnp.zeros((), jnp.int32)


class AbstractContext:
    """Shape-inference twin (used under ``jax.eval_shape``).

    A nested ``call`` resolves the callee's output signature through the
    platform's (memoized, cycle-checked) shape registry — pure Python
    recursion outside the trace — and materializes abstract zeros of that
    signature inside the trace. Async calls contribute only their token."""

    def __init__(self, platform, member: str):
        self._platform = platform
        self.member = member

    def call(self, name: str, *args):
        arg_structs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), args
        )
        out = self._platform.output_structs(name, arg_structs)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), out)

    def call_async(self, name: str, *args):
        return jnp.zeros((), jnp.int32)
