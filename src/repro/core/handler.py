"""The Function Handler: dispatch coordination + synchronous-call detection.

Every invocation — external (client) or internal (function-to-function) —
flows through the handler. For internal calls it observes, at run time,
whether the issuing execution *blocked* waiting for the callee (the paper's
blocking-socket observation; here the caller's compiled program is parked
inside a ``pure_callback`` until the callee responds). Observed synchronous
edges accumulate per (caller, callee) and are reported to the fusion policy;
when the policy fires, a fusion request with the two function identifiers is
submitted to the Merger — exactly the §3 control flow.

The handler also:
* captures the latest request per function as the *canary* used by the
  Merger's health check;
* maintains the per-thread invocation stack so blocked time is attributed
  to the right billing record (the double-billing measurement).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Any, Callable

from repro.core.billing import BillingMeter, InvocationRecord
from repro.scheduler.clock import SYSTEM_CLOCK

_RECENT_WAITS = 64  # bounded per-edge wait history for the tail estimate
_RECENT_TS = 256  # bounded per-edge / per-function timestamp history: the
# fission regret path must see whether an edge or a member is hot NOW —
# all-time counters stay "hot" forever after traffic moves away
RECENT_WINDOW_S = 5.0  # default lookback for the windowed rates


def _windowed_rate(ts, window_s: float, now: float) -> float:
    """Events/s over the trailing window from a bounded timestamp deque.
    When the deque overflowed INSIDE the window (high-rate source: 256
    entries can span well under 5s), the denominator is the span the deque
    actually covers — dividing the capped count by the full window would
    clamp every hot source to maxlen/window_s (~51 req/s) and compress the
    rate ratios the divergence check compares."""
    if not ts:
        return 0.0
    cutoff = now - window_s
    count = sum(1 for t in ts if t >= cutoff)
    if count == 0:
        return 0.0
    span = window_s
    maxlen = getattr(ts, "maxlen", None)
    if maxlen is not None and len(ts) == maxlen and ts[0] >= cutoff:
        # ONLY an overflowed deque truncates the window. Shortening the span
        # just because the oldest retained sample is recent would turn a
        # function's first two requests into a thousands-req/s reading.
        span = max(now - ts[0], 1e-6)
    return count / span


@dataclasses.dataclass
class EdgeStats:
    sync_count: int = 0
    async_count: int = 0
    total_wait_s: float = 0.0

    def __post_init__(self):
        # Deliberately NOT a dataclass field: asdict()/replace() snapshots
        # stay plain scalars (JSON-serializable stats, cheap copies).
        self.recent_waits: list[float] = []
        self.recent_ts: collections.deque[float] = collections.deque(maxlen=_RECENT_TS)

    def recent_sync_rate(self, window_s: float = RECENT_WINDOW_S, now: float | None = None) -> float:
        """Sync observations per second over the trailing ``window_s`` — the
        *windowed* view of edge heat: a chain whose traffic moved away reads
        ~0 here while sync_count stays frozen at its all-time total."""
        now = time.perf_counter() if now is None else now
        return _windowed_rate(self.recent_ts, window_s, now)

    @property
    def mean_wait_s(self) -> float:
        return self.total_wait_s / self.sync_count if self.sync_count else 0.0

    @property
    def p95_wait_s(self) -> float:
        """Nearest-rank p95 over the recent sync waits — the fusion policy's
        promote rule keys on tail blocking, which a mean over a mostly-fast
        edge hides. Falls back to the mean when no history is retained."""
        if not self.recent_waits:
            return self.mean_wait_s
        ordered = sorted(self.recent_waits)
        rank = min(len(ordered), max(1, math.ceil(0.95 * len(ordered))))
        return ordered[rank - 1]


@dataclasses.dataclass
class _ActiveInvocation:
    function: str
    instance_id: str
    t_start: float
    resident_bytes: int
    blocked_s: float = 0.0
    batch_size: int = 1
    # (SpanContext, outer parent id, this execute span's id) when a trace
    # was active at enter — exit/abort close the span and pop the activation
    span: tuple | None = None


class FunctionHandler:
    def __init__(self, meter: BillingMeter, on_fusion_candidate: Callable[[str, str], None] | None = None,
                 clock=None, tracer=None):
        self.meter = meter
        # Injectable time source: edge heat, demand rates, and blocked-time
        # attribution all become drivable by a virtual clock in tests.
        self.clock = clock or SYSTEM_CLOCK
        # obs.Tracer: enter/exit bracket every execution, so the handler is
        # where per-execution "execute" spans (with the serving instance id —
        # the replica pick) enter the active request's trace.
        self._tracer = tracer
        self.on_fusion_candidate = on_fusion_candidate
        self.edges: dict[tuple[str, str], EdgeStats] = {}
        self.canaries: dict[str, tuple] = {}
        # Per-function recent EXTERNAL demand timestamps (stamped by the
        # platform's client entry points, NOT by internal chain dispatches or
        # canary replays): the fission policy's traffic-divergence check
        # reads the direct demand a member sees RIGHT NOW. Counting internal
        # dispatches here would poison the pre-merge baseline — a chain
        # callee served by inlined calls post-merge would look like a member
        # whose clients left, and every healthy chain would split.
        self._recent_calls: dict[str, collections.deque] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()

    # ------------------------------------------------------- invocation stack

    def _stack(self) -> list[_ActiveInvocation]:
        if not hasattr(self._tls, "stack"):
            self._tls.stack = []
        return self._tls.stack

    def enter(self, function: str, instance, batch_size: int = 1) -> None:
        """``batch_size > 1`` marks a micro-batched execution: k co-batched
        requests holding the instance once. `exit` then emits one record PER
        request (each carrying batch_size, so billed GB-s splits k ways and
        per-function call counts still count client requests)."""
        inv = _ActiveInvocation(
            function, instance.instance_id, self.clock.now(), instance.resident_bytes(),
            batch_size=max(1, batch_size),
        )
        if self._tracer is not None:
            cur = self._tracer.current()
            if cur is not None:
                ctx, parent = cur
                sid = ctx.alloc_id()
                # activate so nested cross-function hops / resurrects parent
                # under this execute span (exit/abort pops)
                self._tracer.push(ctx, sid)
                inv.span = (ctx, parent, sid)
        self._stack().append(inv)

    def exit(self, function: str) -> None:
        stack = self._stack()
        inv = stack.pop()
        t_end = self.clock.now()
        self._close_span(inv, t_end)
        for _ in range(inv.batch_size):
            self.meter.record(
                InvocationRecord(
                    function=inv.function,
                    instance=inv.instance_id,
                    t_start=inv.t_start,
                    t_end=t_end,
                    resident_bytes=inv.resident_bytes,
                    blocked_s=inv.blocked_s / inv.batch_size,
                    batch_size=inv.batch_size,
                )
            )

    def abort(self, function: str) -> None:
        """Pop the invocation WITHOUT billing — used when an attempt fails
        and will be retried (billing the failed attempt would double-count
        the request once the retry lands). The aborted attempt still closes
        its trace span (flagged) — the retry emits its own."""
        inv = self._stack().pop()
        self._close_span(inv, self.clock.now(), aborted=True)

    def _close_span(self, inv: _ActiveInvocation, t_end: float,
                    aborted: bool = False) -> None:
        if inv.span is None:
            return
        ctx, parent, sid = inv.span
        self._tracer.pop()
        args = {"instance": inv.instance_id, "batch": inv.batch_size}
        if aborted:
            args["aborted"] = True
        ctx.emit(f"execute:{inv.function}", "execute", inv.t_start, t_end,
                 parent_id=parent, span_id=sid, args=args)

    def attribute_blocked(self, seconds: float) -> None:
        stack = self._stack()
        if stack:
            stack[-1].blocked_s += seconds

    # ------------------------------------------------------- observation

    def record_canary(self, function: str, args: tuple) -> None:
        with self._lock:
            self.canaries[function] = args

    def canary(self, function: str):
        with self._lock:
            return self.canaries.get(function)

    def observe_edge(self, caller: str, callee: str, *, sync: bool, wait_s: float = 0.0) -> None:
        notify = False
        with self._lock:
            st = self.edges.setdefault((caller, callee), EdgeStats())
            if sync:
                st.sync_count += 1
                st.total_wait_s += wait_s
                st.recent_waits.append(wait_s)
                st.recent_ts.append(self.clock.now())
                if len(st.recent_waits) > _RECENT_WAITS:
                    del st.recent_waits[0]
                notify = True
            else:
                st.async_count += 1
        if notify and self.on_fusion_candidate is not None:
            self.on_fusion_candidate(caller, callee)

    def note_demand(self, function: str) -> None:
        """One unit of direct external demand (a client invoke/invoke_async)
        landed on ``function`` — the platform's entry points call this;
        internal function-to-function dispatches and control-plane canary
        replays deliberately do not."""
        with self._lock:
            recent = self._recent_calls.get(function)
            if recent is None:
                recent = self._recent_calls[function] = collections.deque(maxlen=_RECENT_TS)
            recent.append(self.clock.now())

    def recent_rate(self, function: str, window_s: float = RECENT_WINDOW_S) -> float:
        """Direct external demand (requests/s) on this function over the
        trailing window — the per-member signal the fission divergence check
        compares against its commit-time baseline."""
        now = self.clock.now()
        with self._lock:
            recent = self._recent_calls.get(function)
            return _windowed_rate(recent, window_s, now) if recent else 0.0

    def recent_inbound_rate(self, function: str, exclude=frozenset(),
                            window_s: float = RECENT_WINDOW_S) -> float:
        """Windowed rate of synchronous dispatches INTO ``function`` from
        callers outside ``exclude`` — demand a fused member receives from
        other execution units, invisible to `recent_rate` (eager-glue calls
        are not client traffic). The fission divergence check sums this with
        the direct rate so a member fed by an external caller never reads
        cold. Calls from inside ``exclude`` (the member's own fusion group)
        are inlined post-merge and must not count either way."""
        now = self.clock.now()
        with self._lock:
            return sum(
                st.recent_sync_rate(window_s, now=now)
                for (caller, callee), st in self.edges.items()
                if callee == function and caller not in exclude
            )

    def last_activity(self, function: str) -> float | None:
        """Most recent timestamp this function saw ANY traffic: direct
        external demand or an inbound synchronous dispatch. None if it has
        never been called — the idle-park tick treats never-invoked functions
        by their deploy time instead."""
        with self._lock:
            last: float | None = None
            recent = self._recent_calls.get(function)
            if recent:
                last = recent[-1]
            for (caller, callee), st in self.edges.items():
                if callee == function and st.recent_ts:
                    t = st.recent_ts[-1]
                    last = t if last is None else max(last, t)
            return last

    def sync_edges(self) -> dict[tuple[str, str], EdgeStats]:
        with self._lock:
            return {k: dataclasses.replace(v) for k, v in self.edges.items() if v.sync_count}

    def stats(self) -> dict:
        now = self.clock.now()
        with self._lock:
            return {
                f"{a}->{b}": {
                    **dataclasses.asdict(v),
                    "recent_sync_rate": round(v.recent_sync_rate(now=now), 3),
                }
                for (a, b), v in sorted(self.edges.items())
            }
