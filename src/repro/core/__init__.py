"""Provuse core: platform-side function fusion (the paper's contribution)."""
from repro.core.autoscaler import Autoscaler  # noqa: F401
from repro.core.billing import BillingMeter  # noqa: F401
from repro.core.errors import (  # noqa: F401
    DeploymentError,
    HealthCheckError,
    InvocationError,
    ProvuseError,
    UnknownFunctionError,
)
from repro.core.function import FunctionInstance, FunctionSpec, InstanceState  # noqa: F401
from repro.core.handler import FunctionHandler  # noqa: F401
from repro.core.lifecycle import ControlPlane, EpochEvent  # noqa: F401
from repro.core.merger import GroupRecord, MergeEvent, Merger, SplitEvent  # noqa: F401
from repro.core.platform import OrchestratedBackend, ProvusePlatform, TinyJaxBackend  # noqa: F401
from repro.core.policy import FusionDecision, FusionPolicy, SplitDecision  # noqa: F401
from repro.core.registry import (  # noqa: F401
    LeastOutstandingSpread,
    RoundRobinSpread,
    RoutingTable,
    SpreadPolicy,
)
from repro.scheduler import RequestScheduler  # noqa: F401
from repro.scheduler.clock import SYSTEM_CLOCK, SystemClock, VirtualClock  # noqa: F401
from repro.scheduler.slo import BEST_EFFORT, IMMEDIATE, SLOClass  # noqa: F401
