"""Rho-driven replica autoscaler: scale out on sustained predicted overload,
scale in through the reconciler's trough windows.

The scaler consumes ONLY signals the platform already computes — the
scheduler's M/G/1 offered-load prediction (``predicted_rho``: summed lane
arrival rates x EWMA service / max_batch) and ``signals_for`` queue depth —
so scaling needs no new measurement path. It runs as a reconciler tick hook
(control-plane thread, never the data path):

- **out**: a name whose predicted rho stays >= ``rho_high`` (or whose queue
  depth stays >= ``depth_high``) for ``sustain`` consecutive evaluations
  gains a replica via ``platform._spawn_replica`` — with the executable
  index / compile cache warm (PR 8), spin-up is restore-not-rebuild.
- **in**: a name whose rho stays <= ``rho_low`` for ``sustain`` evaluations
  sheds its newest replica through ``ControlPlane.scale_in`` — enqueued on
  the reconciler so the drain lands in a traffic trough, and the DRAINING
  path guarantees in-flight requests finish first.

The fusion policy's replicate arm (``FusionDecision.replicate``) feeds
:meth:`request_scale_out`: a saturated callee gets a warm replica instead of
a merge that would drag the caller into the hot instance. Hints respect the
same ``max_replicas``/cooldown guards as organic scaling.

Note the rho signal requires the scheduler's adaptive windows (service-time
EWMAs); on a non-adaptive platform only ``depth_high`` hints and policy
requests can trigger scale-out.
"""
from __future__ import annotations

import collections
import threading

_EVENT_LOG_MAX = 256


class Autoscaler:
    GUARDED_FIELDS = {
        "_hi_streak": "_lock",
        "_lo_streak": "_lock",
        "_cooldown_until": "_lock",
        "_requests": "_lock",
        "_pending_in": "_lock",
        "_last_eval": "_lock",
        "events": "_lock",
    }

    def __init__(self, platform, *, rho_high: float = 0.9, rho_low: float = 0.3,
                 depth_high: int | None = None, sustain: int = 3,
                 max_replicas: int = 4, min_replicas: int = 1,
                 cooldown_s: float = 1.0, eval_interval_s: float = 0.05):
        if max_replicas < min_replicas:
            raise ValueError("max_replicas < min_replicas")
        self.platform = platform
        self.clock = platform.clock
        self.rho_high = rho_high
        self.rho_low = rho_low
        self.depth_high = depth_high
        self.sustain = max(1, sustain)
        self.max_replicas = max_replicas
        self.min_replicas = max(1, min_replicas)
        self.cooldown_s = cooldown_s
        self.eval_interval_s = eval_interval_s
        self._lock = threading.Lock()
        self._hi_streak: dict[str, int] = {}
        self._lo_streak: dict[str, int] = {}
        self._cooldown_until: dict[str, float] = {}
        self._requests: list[tuple[str, str]] = []  # policy replicate hints
        self._pending_in: set[str] = set()  # victim ids queued for scale-in
        self._last_eval = 0.0
        self.events: collections.deque[dict] = collections.deque(maxlen=_EVENT_LOG_MAX)

    # ------------------------------------------------------------- triggers

    def request_scale_out(self, name: str, reason: str = "") -> None:
        """Explicit scale-out hint (the fusion policy's replicate arm). The
        spin-up itself happens on the next reconciler tick — never on the
        data-path thread that observed the saturation."""
        with self._lock:
            if all(n != name for n, _ in self._requests):
                self._requests.append((name, reason or "replicate hint"))

    def tick(self) -> None:
        """Reconciler tick hook: drain explicit hints, then evaluate every
        routed name's rho/queue-depth streaks."""
        now = self.clock.now()
        with self._lock:
            due = now - self._last_eval >= self.eval_interval_s
            requests, self._requests = self._requests, []
            if due:
                self._last_eval = now
        for name, reason in requests:
            self._try_scale_out(name, reason=reason)
        if not due:
            return
        platform = self.platform
        for name in platform.registry.names():
            rho = platform.scheduler.predicted_rho(name)
            depth = 0
            if self.depth_high is not None:
                depth = platform.scheduler.signals_for((name,)).queue_depth
            hot = rho >= self.rho_high or (
                self.depth_high is not None and depth >= self.depth_high
            )
            cold = not hot and rho <= self.rho_low
            with self._lock:
                if hot:
                    hi = self._hi_streak[name] = self._hi_streak.get(name, 0) + 1
                    self._lo_streak.pop(name, None)
                    lo = 0
                elif cold:
                    lo = self._lo_streak[name] = self._lo_streak.get(name, 0) + 1
                    self._hi_streak.pop(name, None)
                    hi = 0
                else:
                    self._hi_streak.pop(name, None)
                    self._lo_streak.pop(name, None)
                    hi = lo = 0
            if hi >= self.sustain:
                self._try_scale_out(
                    name,
                    reason=f"sustained rho {rho:.2f} >= {self.rho_high}"
                    if rho >= self.rho_high
                    else f"sustained queue depth {depth} >= {self.depth_high}",
                )
            elif lo >= self.sustain:
                self._schedule_scale_in(
                    name, reason=f"sustained rho {rho:.2f} <= {self.rho_low}"
                )

    # ------------------------------------------------------------ scale out

    def _try_scale_out(self, name: str, *, reason: str) -> None:
        platform = self.platform
        now = self.clock.now()
        with self._lock:
            if now < self._cooldown_until.get(name, 0.0):
                return
        n = platform.registry.replica_count(name)
        if n == 0 or n >= self.max_replicas:
            return
        replica = platform._spawn_replica(name)
        if replica is None:
            return
        with self._lock:
            self._hi_streak.pop(name, None)
            until = self.clock.now() + self.cooldown_s
            for member in replica.members:
                self._cooldown_until[member] = until
            self.events.append({
                "kind": "scale-out", "name": name, "replicas": n + 1,
                "instance": replica.instance_id, "reason": reason,
                "t": round(now, 4),
            })
        platform.tracer.control_event(
            f"scale-out:{name}",
            args={"name": name, "replicas": n + 1,
                  "instance": replica.instance_id, "reason": reason})

    # ------------------------------------------------------------- scale in

    def _schedule_scale_in(self, name: str, *, reason: str) -> None:
        platform = self.platform
        replicas = platform.registry.replicas(name)
        if len(replicas) <= self.min_replicas:
            with self._lock:
                self._lo_streak.pop(name, None)
            return
        victim = replicas[-1]  # newest replica first: the primary persists
        now = self.clock.now()
        with self._lock:
            if now < self._cooldown_until.get(name, 0.0):
                return
            if victim.instance_id in self._pending_in:
                return
            self._pending_in.add(victim.instance_id)
            self._lo_streak.pop(name, None)
        platform.lifecycle.enqueue(
            lambda: self._do_scale_in(victim, reason),
            kind="scale-in",
            names=tuple(sorted(victim.members)),
            reason=reason,
        )

    def _do_scale_in(self, victim, reason: str) -> None:
        try:
            event = self.platform.lifecycle.scale_in(victim, reason=reason)
            if event is not None:
                with self._lock:
                    until = self.clock.now() + self.cooldown_s
                    for member in victim.members:
                        self._cooldown_until[member] = until
                    self.events.append({
                        "kind": "scale-in", "name": ",".join(event.names),
                        "instance": victim.instance_id, "reason": reason,
                        "t": round(event.t_completed, 4),
                    })
                self.platform.tracer.control_event(
                    f"scale-in:{','.join(event.names)}",
                    t=event.t_completed,
                    args={"instance": victim.instance_id, "reason": reason})
        finally:
            with self._lock:
                self._pending_in.discard(victim.instance_id)

    # -------------------------------------------------------------- metrics

    def stats(self) -> dict:
        with self._lock:
            return {
                "rho_high": self.rho_high,
                "rho_low": self.rho_low,
                "sustain": self.sustain,
                "max_replicas": self.max_replicas,
                "hi_streaks": dict(self._hi_streak),
                "lo_streaks": dict(self._lo_streak),
                "pending_scale_in": sorted(self._pending_in),
                "events": list(self.events)[-32:],
            }
