"""The Merger: builds, health-checks, and swaps in fused execution units.

Mirrors §3/§4 of the paper:
  fusion request (caller, callee identifiers) from the Function Handler
    -> policy decision (sync-only, trust domain, amortization)
    -> build a NEW execution unit hosting every function of the fusion
       group, preserving each function's identifier (no collisions — the
       members dict is keyed by name, the analogue of the preserved
       directory structure)
    -> "image build" = retrace members with co-located calls inlined +
       XLA compile (can run in the background while originals keep serving)
    -> health check: canary request through the new unit must match the
       live (unfused) path's output
    -> atomic traffic swap in the routing table
    -> drain + terminate the originals, freeing their memory.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import numpy as np

from repro.core.errors import HealthCheckError
from repro.core.function import FunctionInstance


@dataclasses.dataclass
class MergeEvent:
    t_completed: float
    members: tuple[str, ...]
    freed_bytes: int
    build_s: float
    healthy: bool
    reason: str = ""
    # Members whose canary was replayed through the live path during the
    # health check — each replay is one extra (control-plane) invocation on
    # the billing meter, so tests can account for merge traffic exactly.
    checked_members: tuple[str, ...] = ()


def _allclose_tree(a, b, rtol: float, atol: float) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xf = np.asarray(x, dtype=np.float64) if np.asarray(x).dtype.kind == "f" else np.asarray(x)
        yf = np.asarray(y, dtype=np.float64) if np.asarray(y).dtype.kind == "f" else np.asarray(y)
        if xf.shape != yf.shape:
            return False
        if not np.allclose(xf, yf, rtol=rtol, atol=atol):
            return False
    return True


class Merger:
    def __init__(self, platform, policy, *, health_rtol: float = 2e-2, health_atol: float = 1e-2, async_build: bool = False):
        self.platform = platform
        self.policy = policy
        self.health_rtol = health_rtol
        self.health_atol = health_atol
        self.async_build = async_build
        self.merge_log: list[MergeEvent] = []
        self._inflight: set[tuple[str, str]] = set()
        # Edges/groups whose merged unit FAILED its health check. The merged
        # program is a pure function of the specs, so retrying without a code
        # change fails identically — and because the health check's own
        # reference invocation re-observes the hot edge, retry-on-observation
        # would spin the control plane forever. Failed rollouts stay failed.
        # The group set catches OTHER edges that resolve to the same doomed
        # member set (e.g. (A,C) after (B,C) failed to extend committed
        # {A,B}) before they pay the build cost again.
        self._quarantined: set[tuple[str, str]] = set()
        self._failed_groups: set[frozenset[str]] = set()
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []

    # ------------------------------------------------------------ entry

    def submit(self, caller: str, callee: str) -> None:
        """Fusion request from the Function Handler."""
        stats = self.platform.handler.edges.get((caller, callee))
        if stats is None:
            return
        with self._lock:
            # before the (costlier) policy decision: quarantined or already
            # in-flight edges are re-submitted on every sync observation of
            # a hot chain — they must not pay for scheduler snapshots
            if (caller, callee) in self._inflight or (caller, callee) in self._quarantined:
                return
        spec_a = self.platform.spec_of(caller)
        spec_b = self.platform.spec_of(callee)
        # Live scheduler feedback (queue depth, occupancy, tail latency)
        # modulates the decision: saturated chains wait, cold slow ones jump.
        # Passed lazily — decide only snapshots it past its cheap early-outs.
        signals_fn = getattr(self.platform, "scheduler_signals", None)
        signals = (lambda: signals_fn((caller, callee))) if signals_fn is not None else None
        decision = self.policy.decide(
            caller, callee, stats, spec_a.trust_domain, spec_b.trust_domain, signals=signals
        )
        if not decision.fuse:
            return
        with self._lock:
            if (caller, callee) in self._inflight or (caller, callee) in self._quarantined:
                return
            if frozenset(decision.group) in self._failed_groups:
                return  # another edge already proved this exact unit unhealthy
            self._inflight.add((caller, callee))
        if self.async_build:
            th = threading.Thread(target=self._do_merge, args=(caller, callee, decision.group), daemon=True)
            self._threads.append(th)
            th.start()
        else:
            self._do_merge(caller, callee, decision.group)

    def wait_idle(self, timeout: float = 120.0) -> None:
        for th in self._threads:
            th.join(timeout)
        self._threads = [t for t in self._threads if t.is_alive()]

    # ------------------------------------------------------------ merge

    def _do_merge(self, caller: str, callee: str, group: frozenset[str]) -> None:
        t0 = time.perf_counter()
        platform = self.platform
        try:
            specs = {name: platform.spec_of(name) for name in group}
            merged = FunctionInstance(specs, platform)
            platform.attach_instance(merged)

            # --- health check on captured canary traffic (warms the compile) ---
            healthy = True
            checked: list[str] = []
            for name in sorted(group):
                canary = platform.handler.canary(name)
                if canary is None:
                    continue
                ref = platform._invoke_with_retry(name, canary)  # old (still-routed) path
                got = merged.execute(name, canary)
                checked.append(name)
                if not _allclose_tree(ref, got, self.health_rtol, self.health_atol):
                    healthy = False
                    break
            if not checked:
                healthy = False  # no canary -> cannot verify; do not swap

            if not healthy:
                # Abort: never swap an unverified unit. Originals keep serving.
                platform.detach_instance(merged)
                reason = "health check failed" if checked else "no canary traffic captured"
                if checked:  # no-canary aborts may retry once traffic arrives
                    with self._lock:
                        self._quarantined.add((caller, callee))
                        self._failed_groups.add(frozenset(group))
                self.merge_log.append(
                    MergeEvent(time.perf_counter(), tuple(sorted(group)), 0, time.perf_counter() - t0,
                               False, reason, tuple(checked))
                )
                return

            merged.mark_ready()
            displaced = platform.registry.swap(group, merged)
            self.policy.commit(caller, callee)

            # --- retire originals no longer routed anywhere ---
            still_live = {id(i) for i in platform.registry.live_instances()}
            freed = 0
            for inst in {id(v): v for v in displaced.values()}.values():
                if id(inst) not in still_live and inst is not merged:
                    freed += platform.retire_instance(inst)

            build_s = time.perf_counter() - t0
            self.policy.feedback_merge_cost(build_s)
            self.merge_log.append(
                MergeEvent(time.perf_counter(), tuple(sorted(group)), freed, build_s, True,
                           checked_members=tuple(checked))
            )
        finally:
            with self._lock:
                self._inflight.discard((caller, callee))
