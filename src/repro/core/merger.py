"""The Merger: builds, health-checks, and swaps in fused execution units.

Mirrors §3/§4 of the paper:
  fusion request (caller, callee identifiers) from the Function Handler
    -> policy decision (sync-only, trust domain, amortization)
    -> build a NEW execution unit hosting every function of the fusion
       group, preserving each function's identifier (no collisions — the
       members dict is keyed by name, the analogue of the preserved
       directory structure)
    -> "image build" = retrace members with co-located calls inlined +
       XLA compile (can run in the background while originals keep serving)
    -> health check: canary request through the new unit must match the
       live (unfused) path's output
    -> atomic traffic swap in the routing table
    -> drain + terminate the originals, freeing their memory.
"""
from __future__ import annotations

import dataclasses
import threading

import jax
import numpy as np

from repro.core.errors import HealthCheckError
from repro.core.function import FunctionInstance
from repro.scheduler.clock import SYSTEM_CLOCK


@dataclasses.dataclass
class MergeEvent:
    t_completed: float
    members: tuple[str, ...]
    freed_bytes: int
    build_s: float
    healthy: bool
    reason: str = ""
    # Members whose canary was replayed through the live path during the
    # health check — each replay is one extra (control-plane) invocation on
    # the billing meter, so tests can account for merge traffic exactly.
    checked_members: tuple[str, ...] = ()
    epoch: int = 0  # routing epoch this merge published (0: never swapped)
    # True when the merged unit's build was served entirely from the
    # executable index (zero recompiles) — the restore-not-rebuild signal.
    # None: unknown (unhealthy merges abort before the profile is read).
    warm: bool | None = None


@dataclasses.dataclass
class SplitEvent:
    """One fission transaction: a fused group rebuilt as per-partition units."""

    t_completed: float
    members: tuple[str, ...]
    partition: tuple[tuple[str, ...], ...]
    healthy: bool
    reason: str = ""
    checked_members: tuple[str, ...] = ()
    epoch: int = 0
    build_s: float = 0.0
    warm: bool | None = None  # every rebuilt unit hit the executable index


@dataclasses.dataclass
class GroupRecord:
    """Control-plane memory of one committed fusion group — everything the
    regret check needs to decide the merge should be undone."""

    members: frozenset[str]
    instance: FunctionInstance
    committed_t: float
    epoch: int
    # Pre-merge per-member tails/rates snapshotted at commit: the regret
    # comparison is always against what the platform looked like BEFORE it
    # fused, never against an aspiration.
    baseline_p95_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    baseline_rates: dict[str, float] = dataclasses.field(default_factory=dict)


def _allclose_tree(a, b, rtol: float, atol: float) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xf = np.asarray(x, dtype=np.float64) if np.asarray(x).dtype.kind == "f" else np.asarray(x)
        yf = np.asarray(y, dtype=np.float64) if np.asarray(y).dtype.kind == "f" else np.asarray(y)
        if xf.shape != yf.shape:
            return False
        if not np.allclose(xf, yf, rtol=rtol, atol=atol):
            return False
    return True


class Merger:
    # provlint: merge_log/split_log are append-only observability lists
    # read after quiesce; the operational state below is lock-guarded.
    GUARDED_FIELDS = {
        "_groups": "_lock",
        "_inflight": "_lock",
        "_quarantined": "_lock",
        "_failed_groups": "_lock",
        "_failed_splits": "_lock",
        "_threads": "_lock",
    }


    def _trace_outcome(self, kind: str, event) -> None:
        """Stamp the merge/split transaction outcome on the control-plane
        trace timeline — policy decisions land next to the traffic that
        caused them (successful builds also get a duration span via
        ``note_provisioning``; this instant carries the verdict)."""
        tracer = getattr(self.platform, "tracer", None)
        if tracer is not None:
            tracer.control_event(
                f"{kind}:{'+'.join(event.members)}", t=event.t_completed,
                args={"members": list(event.members),
                      "healthy": event.healthy, "reason": event.reason})

    def __init__(self, platform, policy, *, health_rtol: float = 2e-2, health_atol: float = 1e-2, async_build: bool = False):
        self.platform = platform
        self.policy = policy
        # share the platform's time source (virtual in simulation tests) so
        # group ages / event timestamps sit on the same axis as the
        # scheduler's and the policy's hysteresis windows
        self._clock = getattr(platform, "clock", None) or SYSTEM_CLOCK
        self.health_rtol = health_rtol
        self.health_atol = health_atol
        self.async_build = async_build
        self.merge_log: list[MergeEvent] = []
        self._inflight: set[tuple[str, str]] = set()
        # Edges/groups whose merged unit FAILED its health check. The merged
        # program is a pure function of the specs, so retrying without a code
        # change fails identically — and because the health check's own
        # reference invocation re-observes the hot edge, retry-on-observation
        # would spin the control plane forever. Failed rollouts stay failed.
        # The group set catches OTHER edges that resolve to the same doomed
        # member set (e.g. (A,C) after (B,C) failed to extend committed
        # {A,B}) before they pay the build cost again.
        self._quarantined: set[tuple[str, str]] = set()
        self._failed_groups: set[frozenset[str]] = set()
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self.split_log: list[SplitEvent] = []
        self._groups: dict[frozenset[str], GroupRecord] = {}
        # (member set, partition) pairs whose rebuilt units FAILED the split
        # health check. Like _failed_groups for merges: the rebuilt programs
        # are pure functions of the specs, so retrying the SAME partition
        # fails identically — without this, a persistent regret signal would
        # rebuild + recompile + re-check the doomed partition on every
        # reconciler tick. Keyed per partition: a different partition of the
        # same group builds different units and deserves its own attempt.
        self._failed_splits: set[tuple[frozenset[str], frozenset[frozenset[str]]]] = set()

    # ------------------------------------------------------------ entry

    def submit(self, caller: str, callee: str) -> None:
        """Fusion request from the Function Handler."""
        stats = self.platform.handler.edges.get((caller, callee))
        if stats is None:
            return
        with self._lock:
            # before the (costlier) policy decision: quarantined or already
            # in-flight edges are re-submitted on every sync observation of
            # a hot chain — they must not pay for scheduler snapshots
            if (caller, callee) in self._inflight or (caller, callee) in self._quarantined:
                return
        spec_a = self.platform.spec_of(caller)
        spec_b = self.platform.spec_of(callee)
        # Live scheduler feedback (queue depth, occupancy, tail latency)
        # modulates the decision: saturated chains wait, cold slow ones jump.
        # Passed lazily — decide only snapshots it past its cheap early-outs.
        signals_fn = getattr(self.platform, "scheduler_signals", None)
        signals = (lambda: signals_fn((caller, callee))) if signals_fn is not None else None
        # Fuse-vs-replicate inputs: the platform's measured warm spin-up
        # estimate and the callee's current replica count. Both None/1 on
        # platforms without the replicated data plane — the replicate arm
        # then never fires and decide() behaves exactly as before.
        spinup_fn = getattr(self.platform, "replica_spinup_estimate", None)
        replica_spinup_s = spinup_fn(callee) if spinup_fn is not None else None
        registry = getattr(self.platform, "registry", None)
        callee_replicas = (
            registry.replica_count(callee)
            if registry is not None and hasattr(registry, "replica_count")
            else 1
        )
        decision = self.policy.decide(
            caller, callee, stats, spec_a.trust_domain, spec_b.trust_domain,
            signals=signals, replica_spinup_s=replica_spinup_s,
            callee_replicas=callee_replicas,
        )
        if decision.replicate:
            # The cost model chose capacity over consolidation: hint the
            # autoscaler to clone the saturated callee instead of merging.
            request = getattr(self.platform, "request_replica", None)
            if request is not None:
                request(callee, reason=decision.reason)
            return
        if not decision.fuse:
            return
        with self._lock:
            if (caller, callee) in self._inflight or (caller, callee) in self._quarantined:
                return
            if frozenset(decision.group) in self._failed_groups:
                return  # another edge already proved this exact unit unhealthy
            self._inflight.add((caller, callee))
        lifecycle = getattr(self.platform, "lifecycle", None)
        if lifecycle is not None and getattr(self.platform, "trough_merges", False):
            # Deferred merge: the reconciler runs the build+swap at the next
            # observed traffic trough (or after its max-defer deadline), so
            # the recompile stall lands in a quiet gap instead of mid-burst.
            t_queued = self._clock.now()
            lifecycle.enqueue(
                lambda: self._do_merge(caller, callee, decision.group,
                                       deferred_s=self._clock.now() - t_queued,
                                       revalidate=True),
                kind="merge", names=tuple(sorted(decision.group)),
                reason=decision.reason,
            )
        elif self.async_build:
            th = threading.Thread(target=self._do_merge, args=(caller, callee, decision.group), daemon=True)
            with self._lock:
                # prune-on-submit keeps the list bounded under sustained
                # async_build traffic; append under the SAME lock wait_idle
                # snapshots under (append/prune used to race it)
                self._threads = [t for t in self._threads if t.is_alive()]
                self._threads.append(th)
            th.start()
        else:
            self._do_merge(caller, callee, decision.group)

    def wait_idle(self, timeout: float = 120.0) -> None:
        lifecycle = getattr(self.platform, "lifecycle", None)
        if lifecycle is not None and getattr(self.platform, "trough_merges", False):
            # run anything still queued now, then wait out transitions the
            # reconciler already popped and is mid-way through executing
            lifecycle.run_pending(force=True)
            lifecycle.wait_idle(timeout)
        with self._lock:
            threads = list(self._threads)
        for th in threads:
            th.join(timeout)
        with self._lock:
            self._threads = [t for t in self._threads if t.is_alive()]

    # ------------------------------------------------------------ merge

    def _do_merge(self, caller: str, callee: str, group: frozenset[str],
                  deferred_s: float = 0.0, revalidate: bool = False) -> None:
        t0 = self._clock.now()
        platform = self.platform
        try:
            if revalidate:
                # Deferred merges re-run the decision at execution time: up
                # to max_defer_s passed since decide(), during which a split
                # may have put these edges into remerge backoff or the group
                # may have changed shape — publishing the stale group would
                # bypass the flap hysteresis and desync policy from routing.
                stats = platform.handler.edges.get((caller, callee))
                if stats is None:
                    return
                decision = self.policy.decide(
                    caller, callee, stats,
                    platform.spec_of(caller).trust_domain,
                    platform.spec_of(callee).trust_domain,
                )
                if not decision.fuse:
                    return
                with self._lock:
                    if frozenset(decision.group) in self._failed_groups:
                        return  # the (possibly re-shaped) group is already
                        # proven unhealthy — don't pay the build again
                group = decision.group
            specs = {name: platform.spec_of(name) for name in group}
            merged = FunctionInstance(specs, platform)
            platform.attach_instance(merged)

            # --- health check on captured canary traffic (warms the compile) ---
            healthy = True
            checked: list[str] = []
            for name in sorted(group):
                canary = platform.handler.canary(name)
                if canary is None:
                    continue
                ref = platform._invoke_with_retry(name, canary)  # old (still-routed) path
                got = merged.execute(name, canary)
                checked.append(name)
                if not _allclose_tree(ref, got, self.health_rtol, self.health_atol):
                    healthy = False
                    break
            if not checked:
                healthy = False  # no canary -> cannot verify; do not swap

            if not healthy:
                # Abort: never swap an unverified unit. Originals keep serving.
                platform.detach_instance(merged)
                reason = "health check failed" if checked else "no canary traffic captured"
                if checked:  # no-canary aborts may retry once traffic arrives
                    with self._lock:
                        self._quarantined.add((caller, callee))
                        self._failed_groups.add(frozenset(group))
                event = MergeEvent(self._clock.now(), tuple(sorted(group)), 0,
                                   self._clock.now() - t0, False, reason, tuple(checked))
                self.merge_log.append(event)
                self._trace_outcome("merge", event)
                return

            # --- pre-merge baseline snapshot: what regret will compare against ---
            scheduler = getattr(platform, "scheduler", None)
            baseline_p95 = {
                m: (scheduler.recent_p95_ms(m) if scheduler is not None else 0.0)
                for m in group
            }
            baseline_rates = {m: self._member_demand(m, group) for m in group}

            merged.mark_ready()
            # Epoch transaction: atomic route publish + lifecycle transitions
            # (merged -> SERVING, unrouted originals -> DRAINING under the
            # routing lock), then drain + retire outside it.
            event = platform.lifecycle.publish(
                {name: merged for name in group}, kind="merge",
                reason=f"fused {caller}->{callee}", deferred_s=deferred_s,
            )
            self.policy.commit(caller, callee)
            freed = event.freed_bytes

            with self._lock:
                # the new group subsumes any committed subgroup's record (its
                # instance was displaced by this very publish)
                for members in [k for k in self._groups if k <= frozenset(group)]:
                    del self._groups[members]
                self._groups[frozenset(group)] = GroupRecord(
                    members=frozenset(group), instance=merged,
                    committed_t=self._clock.now(), epoch=event.epoch,
                    baseline_p95_ms=baseline_p95, baseline_rates=baseline_rates,
                )

            build_s = self._clock.now() - t0
            self.policy.feedback_merge_cost(build_s)
            # Warm iff the canary warm-up above compiled NOTHING — every
            # entry came out of the executable index. A re-merge of a
            # previously-seen group should read warm; the first ever merge
            # of this shape reads cold.
            profile = merged.provision_profile()
            warm = profile["cache_misses"] == 0 and profile["cache_hits"] > 0
            note = getattr(platform, "note_provisioning", None)
            if note is not None:
                note("merge", build_s, warm=warm,
                     functions=tuple(sorted(group)),
                     resident_bytes=merged.resident_bytes())
            merge_event = MergeEvent(
                self._clock.now(), tuple(sorted(group)), freed, build_s, True,
                checked_members=tuple(checked), epoch=event.epoch, warm=warm)
            self.merge_log.append(merge_event)
            self._trace_outcome("merge", merge_event)
        finally:
            with self._lock:
                self._inflight.discard((caller, callee))

    def forget_instance(self, instance: FunctionInstance) -> None:
        """Drop the committed-group record backing ``instance`` (scale-to-zero
        park retired it). Members resurrect as SINGLETON units, so the policy's
        group state must dissolve too — with zero backoff: the park was an
        idleness decision, not a flap, and the first hot edge after resurrect
        should be free to re-fuse immediately."""
        members = frozenset(instance.members)
        with self._lock:
            rec = self._groups.get(members)
            if rec is not None and rec.instance is instance:
                del self._groups[members]
        if len(members) >= 2:
            self.policy.dissolve([frozenset([m]) for m in members], backoff_s=0.0)

    # ------------------------------------------------------------ fission

    def committed_groups(self) -> list[GroupRecord]:
        with self._lock:
            return list(self._groups.values())

    def _member_demand(self, member: str, group) -> float:
        """Demand one fused member sees: direct client traffic plus sync
        dispatches from units OUTSIDE the group (calls from inside the group
        are inlined post-merge and excluded both pre and post so baseline
        and current measure the same thing)."""
        handler = self.platform.handler
        return handler.recent_rate(member) + handler.recent_inbound_rate(
            member, exclude=group
        )

    def evaluate_splits(self) -> list[SplitEvent]:
        """Regret pass over every committed fusion group (reconciler-tick
        work, never data-path): gather live signals, ask the policy's
        ``decide_split``, and execute any split it orders. Returns the split
        events performed."""
        platform = self.platform
        events: list[SplitEvent] = []
        for rec in self.committed_groups():
            routed = {m: platform.registry.get(m) for m in rec.members}
            if any(inst is not rec.instance for inst in routed.values()):
                # superseded by a later merge or redeploy — drop the record
                with self._lock:
                    if self._groups.get(rec.members) is rec:
                        del self._groups[rec.members]
                continue
            signals_fn = getattr(platform, "scheduler_signals", None)
            signals = signals_fn(tuple(sorted(rec.members))) if signals_fn else None
            scheduler = getattr(platform, "scheduler", None)
            rates = {m: self._member_demand(m, rec.members) for m in rec.members}
            current_p95 = max(
                (scheduler.recent_p95_ms(m) for m in rec.members), default=0.0
            ) if scheduler is not None else 0.0
            count_fn = getattr(platform.registry, "replica_count", None)
            replica_count = (
                max(count_fn(m) for m in rec.members) if count_fn is not None else 1
            )
            decision = self.policy.decide_split(
                rec.members,
                signals=signals,
                member_rates=rates,
                baseline_rates=rec.baseline_rates,
                baseline_p95_ms=max(rec.baseline_p95_ms.values(), default=0.0),
                current_p95_ms=current_p95,
                age_s=self._clock.now() - rec.committed_t,
                replica_count=replica_count,
            )
            if decision.split:
                event = self.split(rec.members, decision.partition, reason=decision.reason)
                if event is not None:
                    events.append(event)
        return events

    def split(self, members, partition, reason: str = "") -> SplitEvent | None:
        """Fission transaction: rebuild the fused group as one execution unit
        per partition cell, health-check each rebuilt unit against the fused
        unit's canaries, and epoch-swap them in (retiring the fused unit).

        Returns the SplitEvent, or None when the group is no longer routed as
        expected (a concurrent merge/redeploy won the race — the publish is
        guarded by compare-and-swap, so a stale split aborts cleanly)."""
        t0 = self._clock.now()
        platform = self.platform
        members = frozenset(members)
        cells = [frozenset(c) for c in partition]
        covered = frozenset().union(*cells) if cells else frozenset()
        if covered != members or sum(len(c) for c in cells) != len(members):
            raise ValueError(f"partition {cells!r} does not partition {sorted(members)!r}")
        if len(cells) < 2:
            return None  # a single cell is not a split
        with self._lock:
            if (members, frozenset(cells)) in self._failed_splits:
                return None  # this exact partition already proved unhealthy
            rec = self._groups.get(members)
        fused = rec.instance if rec is not None else platform.registry.get(next(iter(members)))
        if fused is None or any(platform.registry.get(m) is not fused for m in members):
            return None  # group already superseded

        if not any(platform.handler.canary(m) is not None for m in members):
            # nothing to verify against — refuse before paying for the
            # rebuilds (may retry once traffic has produced a canary)
            event = SplitEvent(
                self._clock.now(), tuple(sorted(members)),
                tuple(tuple(sorted(c)) for c in cells), False,
                "no canary traffic captured", (), build_s=self._clock.now() - t0,
            )
            self.split_log.append(event)
            self._trace_outcome("split", event)
            return event

        units: dict[frozenset, FunctionInstance] = {}
        try:
            for cell in cells:
                specs = {m: platform.spec_of(m) for m in cell}
                unit = FunctionInstance(specs, platform)
                platform.attach_instance(unit)
                units[cell] = unit

            # --- health check: each rebuilt unit must reproduce the fused
            # unit's outputs on the captured canaries (the fused unit IS the
            # live reference — it is what clients have been getting answers
            # from). Holding a request slot on the fused unit keeps a
            # concurrent epoch transition from retiring it (and freeing its
            # params) mid-check.
            fused.begin_request()
            healthy = True
            checked: list[str] = []
            try:
                for cell in cells:
                    for m in sorted(cell):
                        canary = platform.handler.canary(m)
                        if canary is None:
                            continue
                        if units[cell].get_compiled(m, canary) is None:
                            # Boundary entry: replaying it would dispatch the
                            # outbound call through live routing — i.e. queue
                            # behind the saturated fused pod this split exists
                            # to relieve, blocking the reconciler for the
                            # backlog's duration and polluting edge stats and
                            # billing with control-plane traffic. Co-members'
                            # self-contained entries cover the rebuilt units;
                            # compiling it here still pre-warms the post-split
                            # eager fallback's entry cache.
                            continue
                        ref = fused.execute(m, canary)
                        got = units[cell].execute(m, canary)
                        checked.append(m)
                        if not _allclose_tree(ref, got, self.health_rtol, self.health_atol):
                            healthy = False
                            break
                    if not healthy:
                        break
            finally:
                fused.end_request()
            if not healthy or not checked:
                for unit in units.values():
                    platform.detach_instance(unit)
                if not healthy:  # deterministic: this partition cannot pass
                    with self._lock:
                        self._failed_splits.add((members, frozenset(cells)))
                event = SplitEvent(
                    self._clock.now(), tuple(sorted(members)),
                    tuple(tuple(sorted(c)) for c in cells), False,
                    "health check failed" if not healthy else "no self-contained entry to verify",
                    tuple(checked), build_s=self._clock.now() - t0,
                )
                self.split_log.append(event)
                self._trace_outcome("split", event)
                return event

            for unit in units.values():
                unit.mark_ready()
            routes = {m: units[cell] for cell in cells for m in cell}
            epoch_event = platform.lifecycle.publish(
                routes, kind="split", reason=reason,
                expect={m: fused for m in members},
            )
            if epoch_event is None:
                # routing moved underneath us (raced a merge/redeploy): abort
                for unit in units.values():
                    platform.detach_instance(unit)
                return None
        except BaseException:
            # an unexpected failure (fused unit retired mid-check, compile
            # error) must not leak attached units — on the orchestrated
            # backend each would pin a worker thread forever
            for unit in units.values():
                platform.detach_instance(unit)
            raise
        self.policy.dissolve(cells)
        with self._lock:
            self._groups.pop(members, None)
            # multi-member cells remain committed groups in their own right:
            # their members still share one unit and can split again later
            for cell in cells:
                if len(cell) > 1:
                    self._groups[cell] = GroupRecord(
                        members=cell, instance=units[cell],
                        committed_t=self._clock.now(), epoch=epoch_event.epoch,
                        baseline_p95_ms={m: v for m, v in (rec.baseline_p95_ms if rec else {}).items() if m in cell},
                        baseline_rates={m: v for m, v in (rec.baseline_rates if rec else {}).items() if m in cell},
                    )
        build_s = self._clock.now() - t0
        profiles = [units[cell].provision_profile() for cell in cells]
        warm = (all(p["cache_misses"] == 0 for p in profiles)
                and any(p["cache_hits"] > 0 for p in profiles))
        note = getattr(platform, "note_provisioning", None)
        if note is not None:
            note("split", build_s, warm=warm,
                 functions=tuple(sorted(members)),
                 resident_bytes=sum(u.resident_bytes() for u in units.values()))
        event = SplitEvent(
            self._clock.now(), tuple(sorted(members)),
            tuple(tuple(sorted(c)) for c in cells), True, reason,
            tuple(checked), epoch=epoch_event.epoch, build_s=build_s, warm=warm,
        )
        self.split_log.append(event)
        self._trace_outcome("split", event)
        return event
