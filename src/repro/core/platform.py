"""Provuse platform backends.

Two backends mirror the paper's two implementations:

* :class:`TinyJaxBackend` — the tinyFaaS analogue: a minimal in-process
  dispatcher. Invocations execute in the calling thread; routing is a dict
  lookup; async branches run on a small shared pool.
* :class:`OrchestratedBackend` — the Kubernetes analogue: every execution
  unit gets a worker (queue + thread = Pod), invocations travel through a
  Service-like indirection (routing table -> worker queue -> Future),
  merged units go through a readiness gate before the Service selector
  flips (rolling swap), and displaced units are drained before termination.

Both share the Function Handler, Merger, policy, and billing meter — the
Provuse mechanism is backend-agnostic, as the paper demonstrates.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax

from repro.core.billing import BillingMeter
from repro.core.context import AbstractContext
from repro.core.errors import DeploymentError, InvocationError, UnknownFunctionError
from repro.core.function import FunctionInstance, FunctionSpec, InstanceState, _struct_key, _structs_of
from repro.core.handler import FunctionHandler
from repro.core.lifecycle import ControlPlane
from repro.core.merger import Merger
from repro.core.policy import FusionPolicy
from repro.core.registry import RoutingTable
from repro.scheduler import RequestScheduler
from repro.scheduler.clock import SYSTEM_CLOCK
from repro.scheduler.slo import SLOClass


class ProvusePlatform:
    """Base platform: deploy / invoke / observe / fuse / schedule.

    Two dispatch modes face the client:

    * ``invoke`` — the paper's serial path: one request, executed to
      completion in (or via) the calling thread.
    * ``invoke_async`` — returns a Future; the request scheduler coalesces
      concurrent compatible requests into micro-batches that run as ONE
      (vmapped) XLA execution on the routed — possibly fused — instance.
    """

    backend_name = "base"

    def __init__(self, policy: FusionPolicy | None = None, *, async_build: bool = False,
                 health_rtol: float = 2e-2, health_atol: float = 1e-2,
                 max_batch: int = 8, max_delay_ms: float = 2.0,
                 adaptive: bool = False, adaptive_config=None,
                 be_shed_depth: int | None = None,
                 fission: bool = False, fission_interval_s: float = 0.25,
                 trough_merges: bool = False, max_defer_s: float = 1.0,
                 clock=None):
        # One injectable time source for the whole platform: scheduler
        # windows, handler edge heat, lifecycle deferrals, and merge ages
        # all move on the same axis (virtual in simulation tests).
        self.clock = clock or SYSTEM_CLOCK
        self.registry = RoutingTable()
        self.meter = BillingMeter(clock=self.clock)
        self.policy = policy or FusionPolicy()
        self.handler = FunctionHandler(self.meter, on_fusion_candidate=self._on_candidate,
                                       clock=self.clock)
        # Control plane: every deploy/merge/split/redeploy is an epoch
        # transition published through here; the reconciler thread (started
        # lazily) executes deferred transitions during traffic troughs.
        self.lifecycle = ControlPlane(self, self.registry, max_defer_s=max_defer_s,
                                      clock=self.clock)
        # trough_merges: promoted merges queue on the reconciler and run at
        # the next observed trough instead of stalling live traffic.
        self.trough_merges = trough_merges
        self.merger = Merger(self, self.policy, async_build=async_build,
                             health_rtol=health_rtol, health_atol=health_atol)
        self.scheduler = RequestScheduler(
            self._dispatch_batch, max_batch=max_batch, max_delay_ms=max_delay_ms,
            adaptive=adaptive, adaptive_config=adaptive_config,
            be_shed_depth=be_shed_depth,
            on_request_done=lambda name, lat_s, k: self.meter.observe_latency(name, lat_s),
            clock=self.clock,
        )
        # fission: the reconciler periodically runs the regret check
        # (Merger.evaluate_splits) so a merge the live signals say was a
        # mistake gets reversed — see FusionPolicy.decide_split. Registered
        # after the scheduler exists: the hook starts the reconciler thread,
        # which reads scheduler signals.
        self._fission_interval_s = fission_interval_s
        self._last_fission_eval = 0.0
        if fission:
            self.lifecycle.add_tick_hook(self._fission_tick)
        self._specs: dict[str, FunctionSpec] = {}
        self._shape_cache: dict[tuple, Any] = {}
        self._shape_stack: list[str] = []
        self._shape_lock = threading.RLock()
        # Fusion candidates are processed OFF the data path: an edge observed
        # mid-request (inside a parked pure_callback) is queued and the merge
        # runs after the request completes. Merging inside the callback would
        # re-enter the currently-suspended executable (measured: ~30s stall
        # on the 1-core host) — and control-plane work does not belong on the
        # request path anyway.
        self._pending_candidates: list[tuple[str, str]] = []
        self._pending_lock = threading.Lock()
        self._draining = threading.Lock()

    # ------------------------------------------------------------- deploy

    def deploy(self, spec: FunctionSpec) -> FunctionInstance:
        if spec.name in self._specs:
            raise DeploymentError(f"function {spec.name!r} already deployed")
        self._specs[spec.name] = spec
        instance = FunctionInstance({spec.name: spec}, self)
        self.attach_instance(instance)
        instance.mark_ready()
        self.lifecycle.publish({spec.name: instance}, kind="deploy", reason="deploy")
        return instance

    def spec_of(self, name: str) -> FunctionSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise UnknownFunctionError(name) from None

    # ------------------------------------------------------------- shapes

    def output_structs(self, name: str, args: tuple):
        key = (name, _struct_key(args))
        with self._shape_lock:
            if key in self._shape_cache:
                return self._shape_cache[key]
            if name in self._shape_stack:
                raise InvocationError(f"call cycle through {name!r}: {self._shape_stack}")
            spec = self.spec_of(name)
            self._shape_stack.append(name)
            try:
                def run(params, *a):
                    return spec.fn(AbstractContext(self, name), params, *a)

                out = jax.eval_shape(run, _structs_of(spec.params), *_structs_of(args))
            finally:
                self._shape_stack.pop()
            self._shape_cache[key] = out
            return out

    # ------------------------------------------------------------- hooks

    def _on_candidate(self, caller: str, callee: str) -> None:
        with self._pending_lock:
            if (caller, callee) not in self._pending_candidates:
                self._pending_candidates.append((caller, callee))

    def _drain_candidates(self) -> None:
        if not self._draining.acquire(blocking=False):
            return  # a merge in progress is already invoking health checks
        try:
            while True:
                with self._pending_lock:
                    if not self._pending_candidates:
                        return
                    caller, callee = self._pending_candidates.pop(0)
                self.merger.submit(caller, callee)
        finally:
            self._draining.release()

    def attach_instance(self, instance: FunctionInstance) -> None:
        """Backend hook: provision execution resources for an instance."""

    def detach_instance(self, instance: FunctionInstance) -> None:
        """Backend hook: tear down resources for a never-promoted instance."""

    def retire_instance(self, instance: FunctionInstance) -> int:
        freed = instance.retire()
        self.detach_instance(instance)
        return freed

    # ------------------------------------------------------------- running

    def _run_request(self, instance: FunctionInstance, entry: str, args: tuple):
        instance.begin_request()
        self.handler.enter(entry, instance)
        try:
            out = instance.execute(entry, args)
        except BaseException:
            # failed attempts are not billed — the retry path would otherwise
            # double-bill the same request (swap races, redeploys)
            self.handler.abort(entry)
            raise
        else:
            self.handler.exit(entry)
            return out
        finally:
            instance.end_request()

    def _run_batch(self, instance: FunctionInstance, entry: str, args_list: list[tuple]) -> list:
        instance.begin_request()
        self.handler.enter(entry, instance, batch_size=len(args_list))
        try:
            out = instance.execute_batch(entry, args_list, max_bucket=self.scheduler.max_batch)
        except BaseException:
            self.handler.abort(entry)
            raise
        else:
            self.handler.exit(entry)
            return out
        finally:
            instance.end_request()

    def _invoke_with_retry(self, name: str, args: tuple):
        """Serial dispatch with swap-race recovery. Also the Merger's canary
        replay path — no latency observation here, so control-plane traffic
        never pollutes the external latency percentiles."""
        try:
            try:
                return self._dispatch_sync(name, args)
            except InvocationError:
                # A request can race a merge swap: it resolved the old
                # instance, the Merger retired it mid-flight. Re-resolving
                # picks up the new routing; only if THAT fails is the
                # container actually gone and a fresh one provisioned.
                try:
                    return self._dispatch_sync(name, args)
                except InvocationError:
                    self._redeploy(name)
                    return self._dispatch_sync(name, args)
        finally:
            self._drain_candidates()

    def invoke(self, name: str, *args):
        """External (client) invocation — serial path."""
        self.handler.record_canary(name, args)
        self.handler.note_demand(name)
        t0 = self.clock.now()
        out = self._invoke_with_retry(name, args)
        self.meter.observe_latency(name, self.clock.now() - t0)
        return out

    def invoke_async(self, name: str, *args, priority: int = 0,
                     slo: SLOClass | None = None) -> Future:
        """External invocation through the request scheduler. Returns a
        Future; compatible concurrent requests may execute as one batch.
        ``slo=SLOClass(name, target_p95_ms)`` admits the request into its
        class's own lane (single-class batches, window from the class's
        target slack); ``priority=PRIORITY_HIGH`` is the two-level shim —
        it maps to the zero-target class, jumps queued normal traffic, and
        closes an open batching window early (SLO admission)."""
        self.handler.record_canary(name, args)
        self.handler.note_demand(name)
        return self.scheduler.submit(name, args, priority=priority, slo=slo)

    def scheduler_signals(self, names):
        """Live scheduler feedback for the fusion policy (Merger.submit)."""
        return self.scheduler.signals_for(names)

    def _dispatch_batch(self, name: str, args_list: list[tuple]) -> list:
        """Scheduler callback: execute one coalesced batch."""
        try:
            try:
                return self._dispatch_batch_impl(name, args_list)
            except InvocationError:
                try:  # routing may have swapped mid-flight (see invoke)
                    return self._dispatch_batch_impl(name, args_list)
                except InvocationError:
                    self._redeploy(name)
                    return self._dispatch_batch_impl(name, args_list)
        finally:
            self._drain_candidates()

    def _redeploy(self, name: str) -> None:
        spec = self.spec_of(name)
        fresh = FunctionInstance({name: spec}, self)
        self.attach_instance(fresh)
        fresh.mark_ready()
        # Epoch transition: the displaced (dead-routed) instance is drained
        # AND retired — before the control plane owned this, the old worker
        # thread stayed alive and ram_bytes() kept counting the corpse.
        self.lifecycle.publish({name: fresh}, kind="redeploy", reason=f"redeploy {name}")

    def _fission_tick(self) -> None:
        """Reconciler-tick hook: rate-limited regret evaluation over the
        committed fusion groups (control-plane work, off the data path)."""
        now = self.clock.now()
        if now - self._last_fission_eval < self._fission_interval_s:
            return
        self._last_fission_eval = now
        self.merger.evaluate_splits()

    def remote_call(self, caller_instance: FunctionInstance, caller_fn: str, callee: str, args: tuple):
        """Blocking function-to-function dispatch (runs inside the caller's
        pure_callback — the caller's program is parked until this returns)."""
        self.handler.record_canary(callee, args)
        t0 = self.clock.now()
        out = self._dispatch_sync(callee, args)
        wait = self.clock.now() - t0
        self.handler.attribute_blocked(wait)
        self.handler.observe_edge(caller_fn, callee, sync=True, wait_s=wait)
        return out

    def async_call(self, caller_instance: FunctionInstance, caller_fn: str, callee: str, args: tuple) -> None:
        self.handler.observe_edge(caller_fn, callee, sync=False)
        self._dispatch_async(callee, args)

    # ------------------------------------------------------------- metrics

    def ram_bytes(self) -> int:
        return sum(inst.resident_bytes() for inst in self.registry.live_instances())

    def stats(self) -> dict:
        return {
            "backend": self.backend_name,
            "ram_bytes": self.ram_bytes(),
            "instances": [repr(i) for i in self.registry.live_instances()],
            "edges": self.handler.stats(),
            "merges": [
                {
                    "members": e.members,
                    "freed_bytes": e.freed_bytes,
                    "build_s": round(e.build_s, 4),
                    "healthy": e.healthy,
                    "epoch": e.epoch,
                    "reason": e.reason,
                }
                for e in self.merger.merge_log
            ],
            "splits": [
                {
                    "members": e.members,
                    "partition": e.partition,
                    "healthy": e.healthy,
                    "epoch": e.epoch,
                    "reason": e.reason,
                    "build_s": round(e.build_s, 4),
                }
                for e in self.merger.split_log
            ],
            "lifecycle": self.lifecycle.stats(),
            "billing": self.meter.summary(),
            "latency": self.meter.latency_summary(),
            "scheduler": self.scheduler.stats(),
        }

    # ------------------------------------------------------------- backend API

    def _dispatch_sync(self, name: str, args: tuple):
        raise NotImplementedError

    def _dispatch_async(self, name: str, args: tuple) -> None:
        raise NotImplementedError

    def _dispatch_batch_impl(self, name: str, args_list: list[tuple]) -> list:
        raise NotImplementedError

    def shutdown(self) -> None:
        self.lifecycle.shutdown()
        self.scheduler.shutdown()


class TinyJaxBackend(ProvusePlatform):
    """tinyFaaS analogue: direct in-thread dispatch, minimal overhead."""

    backend_name = "tinyjax"

    def __init__(self, *args, async_workers: int = 2, **kwargs):
        super().__init__(*args, **kwargs)
        self._async_pool = ThreadPoolExecutor(max_workers=async_workers, thread_name_prefix="tinyjax-async")

    def _dispatch_sync(self, name: str, args: tuple):
        instance = self.registry.resolve(name)
        return self._run_request(instance, name, args)

    def _dispatch_batch_impl(self, name: str, args_list: list[tuple]) -> list:
        instance = self.registry.resolve(name)
        return self._run_batch(instance, name, args_list)

    def _dispatch_async(self, name: str, args: tuple) -> None:
        self._async_pool.submit(self._safe_async, name, args)

    def _safe_async(self, name: str, args: tuple) -> None:
        try:
            self._dispatch_sync(name, args)
        except Exception:
            pass  # async branches are fire-and-forget; failures are logged by billing absence

    def shutdown(self) -> None:
        super().shutdown()
        self._async_pool.shutdown(wait=True)


class _Worker:
    """A Pod: serial request loop over a queue."""

    def __init__(self, platform: "OrchestratedBackend", instance: FunctionInstance):
        self.instance = instance
        self.platform = platform
        self.q: "queue.Queue[tuple[str, tuple, Future] | None]" = queue.Queue()
        self.thread = threading.Thread(target=self._loop, daemon=True, name=f"worker-{instance.instance_id}")
        self.thread.start()

    def _loop(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            entry, payload, fut, is_batch = item
            try:
                if is_batch:
                    fut.set_result(self.platform._run_batch(self.instance, entry, payload))
                else:
                    fut.set_result(self.platform._run_request(self.instance, entry, payload))
            except Exception as exc:  # noqa: BLE001
                fut.set_exception(exc)

    def submit(self, entry: str, args: tuple) -> Future:
        fut: Future = Future()
        self.q.put((entry, args, fut, False))
        return fut

    def submit_batch(self, entry: str, args_list: list[tuple]) -> Future:
        fut: Future = Future()
        self.q.put((entry, args_list, fut, True))
        return fut

    def stop(self):
        self.q.put(None)


class OrchestratedBackend(ProvusePlatform):
    """Kubernetes analogue: queue+thread Pods, Service indirection, rolling
    swaps with readiness gating."""

    backend_name = "orchestrated"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._workers: dict[str, _Worker] = {}
        self._workers_lock = threading.Lock()

    def attach_instance(self, instance: FunctionInstance) -> None:
        with self._workers_lock:
            self._workers[instance.instance_id] = _Worker(self, instance)

    def detach_instance(self, instance: FunctionInstance) -> None:
        with self._workers_lock:
            worker = self._workers.pop(instance.instance_id, None)
        if worker:
            worker.stop()

    def _worker_for(self, instance: FunctionInstance) -> _Worker:
        with self._workers_lock:
            worker = self._workers.get(instance.instance_id)
        if worker is None:
            raise InvocationError(f"no worker for {instance.instance_id}")
        return worker

    def _dispatch_sync(self, name: str, args: tuple):
        instance = self.registry.resolve(name)
        current = threading.current_thread()
        worker = self._worker_for(instance)
        if worker.thread is current:
            # self-call inside the same pod: run inline (avoids deadlock)
            return self._run_request(instance, name, args)
        return worker.submit(name, args).result()

    def _dispatch_batch_impl(self, name: str, args_list: list[tuple]) -> list:
        instance = self.registry.resolve(name)
        worker = self._worker_for(instance)
        if worker.thread is threading.current_thread():
            return self._run_batch(instance, name, args_list)
        return worker.submit_batch(name, args_list).result()

    def _dispatch_async(self, name: str, args: tuple) -> None:
        instance = self.registry.resolve(name)
        self._worker_for(instance).submit(name, args)

    def shutdown(self) -> None:
        super().shutdown()
        with self._workers_lock:
            for worker in self._workers.values():
                worker.stop()
            self._workers = {}
