"""Provuse platform backends.

Two backends mirror the paper's two implementations:

* :class:`TinyJaxBackend` — the tinyFaaS analogue: a minimal in-process
  dispatcher. Invocations execute in the calling thread; routing is a dict
  lookup; async branches run on a small shared pool.
* :class:`OrchestratedBackend` — the Kubernetes analogue: every execution
  unit gets a worker (queue + thread = Pod), invocations travel through a
  Service-like indirection (routing table -> worker queue -> Future),
  merged units go through a readiness gate before the Service selector
  flips (rolling swap), and displaced units are drained before termination.

Both share the Function Handler, Merger, policy, and billing meter — the
Provuse mechanism is backend-agnostic, as the paper demonstrates.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax

from repro.core.billing import BillingMeter
from repro.core.context import AbstractContext
from repro.core.errors import DeploymentError, InvocationError, UnknownFunctionError
from repro.core.function import FunctionInstance, FunctionSpec, InstanceState, _struct_key, _structs_of
from repro.core.handler import FunctionHandler
from repro.core.lifecycle import ControlPlane
from repro.core.merger import Merger
from repro.core.policy import FusionPolicy
from repro.core.registry import RoutingTable
from repro.obs.critical_path import EdgeCostModel
from repro.obs.trace import Tracer
from repro.scheduler import RequestScheduler
from repro.scheduler.clock import SYSTEM_CLOCK
from repro.scheduler.slo import SLOClass


@dataclasses.dataclass
class _ParkedFunction:
    """Scale-to-zero residue of one function: a params-free spec stub plus
    the snapshot address to resurrect from. While parked the function holds
    NO live weights or programs — and generates no billing records."""

    spec: FunctionSpec        # params=None stub (behavior only)
    digest: str               # SnapshotStore content address of the params
    like: Any                 # ShapeDtypeStruct tree for restore()
    parked_t: float


class ProvusePlatform:
    """Base platform: deploy / invoke / observe / fuse / schedule.

    Two dispatch modes face the client:

    * ``invoke`` — the paper's serial path: one request, executed to
      completion in (or via) the calling thread.
    * ``invoke_async`` — returns a Future; the request scheduler coalesces
      concurrent compatible requests into micro-batches that run as ONE
      (vmapped) XLA execution on the routed — possibly fused — instance.

    With ``enable_snapshots`` (or ``snapshot_dir=``) the platform gains
    scale-to-zero: ``scale_to_zero(name)`` snapshots an instance's weights
    into the content-addressed :class:`SnapshotStore` and unroutes it (a
    "park" epoch); the next invoke transparently resurrects it — restore
    from snapshot, health-check on the captured canary, publish — paying an
    executable-index hit instead of an XLA recompile when the program was
    seen before. ``idle_park_s > 0`` parks instances automatically from the
    reconciler tick once every member has been idle that long.
    """

    backend_name = "base"

    GUARDED_FIELDS = {
        "_parked": "_parked_lock",
        "_resurrecting": "_parked_lock",
        "_deployed_at": "_parked_lock",
        "_prov_records": "_prov_lock",
        "_compile_hits": "_prov_lock",
        "_compile_misses": "_prov_lock",
        "_compile_saved_s": "_prov_lock",
        "_compile_spent_s": "_prov_lock",
        "_spinup_ewma_s": "_prov_lock",
    }

    def __init__(self, policy: FusionPolicy | None = None, *, async_build: bool = False,
                 health_rtol: float = 2e-2, health_atol: float = 1e-2,
                 max_batch: int = 8, max_delay_ms: float = 2.0,
                 adaptive: bool = False, adaptive_config=None,
                 be_shed_depth: int | None = None,
                 fission: bool = False, fission_interval_s: float = 0.25,
                 trough_merges: bool = False, max_defer_s: float = 1.0,
                 snapshot_dir: str | None = None, idle_park_s: float = 0.0,
                 spread=None, autoscale: bool = False,
                 autoscale_config: dict | None = None,
                 clock=None, tracing: bool = True):
        # One injectable time source for the whole platform: scheduler
        # windows, handler edge heat, lifecycle deferrals, and merge ages
        # all move on the same axis (virtual in simulation tests).
        self.clock = clock or SYSTEM_CLOCK
        # Always-on causal tracing: every entry point mints a SpanContext,
        # every phase lands in the tracer's flight recorder, and the
        # EdgeCostModel turns measured sync waits / merge stalls into the
        # policy's cost inputs. ``tracing=False`` disables span minting
        # (the overhead-gate baseline) without touching any call site.
        self.tracer = Tracer(clock=self.clock, enabled=tracing)
        self.edge_costs = EdgeCostModel()
        # spread: replica selection policy for multi-replica routes —
        # "least-outstanding" (default) or "round-robin" (see registry).
        self.registry = RoutingTable(spread=spread)
        self.meter = BillingMeter(clock=self.clock)
        self.policy = policy or FusionPolicy()
        if self.policy.cost_model is None:
            self.policy.cost_model = self.edge_costs
        self.handler = FunctionHandler(self.meter, on_fusion_candidate=self._on_candidate,
                                       clock=self.clock, tracer=self.tracer)
        # Control plane: every deploy/merge/split/redeploy is an epoch
        # transition published through here; the reconciler thread (started
        # lazily) executes deferred transitions during traffic troughs.
        self.lifecycle = ControlPlane(self, self.registry, max_defer_s=max_defer_s,
                                      clock=self.clock)
        # trough_merges: promoted merges queue on the reconciler and run at
        # the next observed trough instead of stalling live traffic.
        self.trough_merges = trough_merges
        self.merger = Merger(self, self.policy, async_build=async_build,
                             health_rtol=health_rtol, health_atol=health_atol)
        self.scheduler = RequestScheduler(
            self._dispatch_batch, max_batch=max_batch, max_delay_ms=max_delay_ms,
            adaptive=adaptive, adaptive_config=adaptive_config,
            be_shed_depth=be_shed_depth,
            on_request_done=lambda name, lat_s, k: self.meter.observe_latency(name, lat_s),
            clock=self.clock,
            tracer=self.tracer,
        )
        # fission: the reconciler periodically runs the regret check
        # (Merger.evaluate_splits) so a merge the live signals say was a
        # mistake gets reversed — see FusionPolicy.decide_split. Registered
        # after the scheduler exists: the hook starts the reconciler thread,
        # which reads scheduler signals.
        self._fission_interval_s = fission_interval_s
        self._last_fission_eval = 0.0
        if fission:
            self.lifecycle.add_tick_hook(self._fission_tick)
        self._specs: dict[str, FunctionSpec] = {}
        self._shape_cache: dict[tuple, Any] = {}
        self._shape_stack: list[str] = []
        self._shape_lock = threading.RLock()
        # Fusion candidates are processed OFF the data path: an edge observed
        # mid-request (inside a parked pure_callback) is queued and the merge
        # runs after the request completes. Merging inside the callback would
        # re-enter the currently-suspended executable (measured: ~30s stall
        # on the 1-core host) — and control-plane work does not belong on the
        # request path anyway.
        self._pending_candidates: list[tuple[str, str]] = []
        self._pending_lock = threading.Lock()
        self._draining = threading.Lock()
        # --- warm provisioning / scale-to-zero state ---
        self.snapshots = None  # SnapshotStore once enable_snapshots() runs
        self._idle_park_s = 0.0
        self._parked: dict[str, _ParkedFunction] = {}
        self._resurrecting: dict[str, tuple[threading.Thread, threading.Event]] = {}
        self._deployed_at: dict[str, float] = {}
        self._parked_lock = threading.Lock()
        self._prov_records: list = []
        self._compile_hits = 0
        self._compile_misses = 0
        self._compile_saved_s = 0.0
        self._compile_spent_s = 0.0
        # EWMA of measured replica spin-up wall time (None until the first
        # spin-up) — the fusion policy's replicate-arm cost input.
        self._spinup_ewma_s: float | None = None
        self._prov_lock = threading.Lock()
        if snapshot_dir is not None:
            self.enable_snapshots(snapshot_dir, idle_park_s=idle_park_s)
        # --- replicated data plane ---
        self.autoscaler = None
        if autoscale:
            self.enable_autoscaler(**(autoscale_config or {}))

    # ------------------------------------------------------------- deploy

    def deploy(self, spec: FunctionSpec) -> FunctionInstance:
        if spec.name in self._specs:
            raise DeploymentError(f"function {spec.name!r} already deployed")
        self._specs[spec.name] = spec
        instance = FunctionInstance({spec.name: spec}, self)
        self.attach_instance(instance)
        instance.mark_ready()
        self.lifecycle.publish({spec.name: instance}, kind="deploy", reason="deploy")
        with self._parked_lock:
            self._deployed_at[spec.name] = self.clock.now()
        return instance

    def spec_of(self, name: str) -> FunctionSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise UnknownFunctionError(name) from None

    # ------------------------------------- scale-to-zero / warm provisioning

    def enable_snapshots(self, directory: str, *, idle_park_s: float = 0.0,
                         retain: int = 0):
        """Turn on instance snapshots (warm-provisioning level 2) backed by a
        :class:`SnapshotStore` at ``directory``. ``idle_park_s > 0`` also
        registers a reconciler tick hook that parks instances whose members
        have ALL been idle at least that long (scale-to-zero)."""
        from repro.checkpointing import SnapshotStore

        self.snapshots = SnapshotStore(directory, retain=retain, clock=self.clock)
        self._idle_park_s = float(idle_park_s)
        if self._idle_park_s > 0:
            self.lifecycle.add_tick_hook(self._idle_park_tick)
        return self.snapshots

    def scale_to_zero(self, name: str) -> tuple[str, ...]:
        """Park the instance serving ``name``: snapshot every member's
        weights (content-addressed — identical weights store once), release
        the live spec params, and unroute via a "park" epoch. The functions
        stop resolving and stop billing; the next invoke resurrects them.
        Returns the parked names (empty if nothing was routed here)."""
        if self.snapshots is None:
            raise RuntimeError("scale_to_zero requires enable_snapshots(...)")
        inst = self.registry.get(name)
        if inst is None:
            return ()
        t0 = self.clock.now()
        members = tuple(sorted(
            m for m in inst.members if self.registry.get(m) is inst
        ))
        if not members:
            return ()
        recs: dict[str, _ParkedFunction] = {}
        live_specs: dict[str, FunctionSpec] = {}
        for m in members:
            spec = self.spec_of(m)
            recs[m] = _ParkedFunction(
                spec=dataclasses.replace(spec, params=None),
                digest=self.snapshots.put(spec.params),
                like=_structs_of(spec.params),
                parked_t=t0,
            )
            live_specs[m] = spec
        with self._parked_lock:
            if any(m in self._parked for m in members):
                # a concurrent park of this instance won (e.g. the idle tick
                # racing an explicit scale_to_zero) — claiming is atomic with
                # this check, so exactly one caller installs the park state
                return ()
            for m in members:
                self._parked[m] = recs[m]
                # drop the live param references: the snapshot is now the
                # only copy, so the weights' memory actually frees when the
                # instance retires below
                self._specs[m] = recs[m].spec
        event = self.lifecycle.park(inst, reason=f"scale-to-zero {'+'.join(members)}")
        if event is None:
            # a publish raced the park (redeploy/merge rerouted the names):
            # the functions are still live — undo the bookkeeping
            with self._parked_lock:
                for m in members:
                    self._parked.pop(m, None)
                    self._specs[m] = live_specs[m]
            return ()
        # a parked fused group must not leave "committed" policy edges
        # behind, or the resurrected singletons could never re-merge
        self.merger.forget_instance(inst)
        self.note_provisioning("park", self.clock.now() - t0, warm=True,
                               functions=members)
        return members

    def _ensure_live(self, name: str) -> None:
        """Data-path gate: if ``name`` is parked, resurrect it (one thread
        does the work, the rest wait on its event). No-op for live names —
        one dict lookup under a short lock."""
        if self.snapshots is None:
            return
        while True:
            with self._parked_lock:
                rec = self._parked.get(name)
                waiter = self._resurrecting.get(name)
                if waiter is not None and waiter[0] is threading.current_thread():
                    # re-entrant: the resurrect's own canary health check
                    # dispatches through the data path
                    return
                if rec is None and waiter is None:
                    return  # live
                if rec is not None and waiter is None:
                    ev = threading.Event()
                    self._resurrecting[name] = (threading.current_thread(), ev)
                    break  # we own the resurrect
                ev = waiter[1]
            ev.wait(60.0)  # owner finished (or failed) -> re-check
        try:
            self._resurrect(name)
        finally:
            with self._parked_lock:
                self._resurrecting.pop(name, None)
            ev.set()

    def _resurrect(self, name: str) -> None:
        """PROVISIONING fast path: restore(snapshot) -> health-check on the
        captured canary -> publish. The restored params are digest-verified
        bit-exact, and the program normally comes from the executable index —
        a warm resurrect performs zero XLA compiles.

        When a request trace is active (the data-path gate resurrecting on
        the invoke path), the whole restore is a "cold-provision" span in
        that trace — the canary execute nests under it, not beside it."""
        t0 = self.clock.now()
        cur = self.tracer.current()
        if cur is None:
            self._resurrect_impl(name, t0)
            return
        ctx, parent = cur
        sid = ctx.alloc_id()
        try:
            with self.tracer.activate(ctx, sid):
                self._resurrect_impl(name, t0)
        finally:
            ctx.emit(f"resurrect:{name}", "cold-provision", t0,
                     self.clock.now(), parent_id=parent, span_id=sid,
                     args={"function": name})

    def _resurrect_impl(self, name: str, t0: float) -> None:
        with self._parked_lock:
            rec = self._parked[name]
        params = self.snapshots.restore(rec.digest, rec.like)
        spec = dataclasses.replace(rec.spec, params=params)
        inst = FunctionInstance({name: spec}, self)
        self.attach_instance(inst)
        canary = self.handler.canary(name)
        if canary is not None:
            inst.execute(name, canary)  # health check before routing
        inst.mark_ready()
        self._specs[name] = spec
        self.lifecycle.publish({name: inst}, kind="resurrect",
                               reason=f"resurrect {name}")
        with self._parked_lock:
            self._parked.pop(name, None)
            self._deployed_at[name] = self.clock.now()
        profile = inst.provision_profile()
        self.note_provisioning(
            "resurrect", self.clock.now() - t0,
            warm=profile["cache_misses"] == 0,
            functions=(name,), resident_bytes=inst.resident_bytes(),
            billed=True,  # restore time IS billed; parked idle time was not
        )

    def _idle_park_tick(self) -> None:
        """Reconciler tick hook: scale-to-zero instances whose members have
        all been idle >= idle_park_s (never-invoked members age from their
        deploy time)."""
        if self.snapshots is None or self._idle_park_s <= 0:
            return
        now = self.clock.now()
        for inst in self.registry.live_instances():
            members = sorted(inst.members)
            idle = True
            for m in members:
                last = self.handler.last_activity(m)
                if last is None:
                    with self._parked_lock:
                        last = self._deployed_at.get(m, now)
                if now - last < self._idle_park_s:
                    idle = False
                    break
            if idle:
                try:
                    self.scale_to_zero(members[0])
                except Exception:  # noqa: BLE001 — a failed park must not
                    pass  # kill the reconciler; the instance stays live

    def note_compile(self, *, hit: bool, seconds: float, saved_s: float = 0.0) -> None:
        """FunctionInstance callback: one program came into being (index hit
        or real XLA compile). Feeds platform.stats()['provisioning']."""
        with self._prov_lock:
            if hit:
                self._compile_hits += 1
                self._compile_saved_s += saved_s
            else:
                self._compile_misses += 1
                self._compile_spent_s += seconds

    def note_provisioning(self, kind: str, seconds: float, *, warm: bool,
                          functions=(), resident_bytes: int = 0,
                          billed: bool = False) -> None:
        """Record one provisioning transition (merge/split/resurrect/park)
        with its warm-vs-cold classification; billed records also reach the
        billing meter (restore time is billed, idle snapshot time is not)."""
        from repro.core.billing import ProvisioningRecord

        rec = ProvisioningRecord(
            kind=kind, functions=tuple(functions), seconds=float(seconds),
            resident_bytes=int(resident_bytes), warm=bool(warm), billed=bool(billed),
        )
        with self._prov_lock:
            self._prov_records.append(rec)
        self.meter.record_provisioning(rec)
        # Control-plane timeline: the transition becomes a span ending now,
        # so merges/splits/parks/resurrects are visually attributable to the
        # traffic around them in the same exported trace.
        t1 = self.clock.now()
        self.tracer.control_span(
            f"{kind}:{'+'.join(rec.functions) or '?'}", t1 - rec.seconds, t1,
            args={"kind": kind, "warm": rec.warm, "billed": rec.billed,
                  "seconds": rec.seconds})
        if kind == "merge":
            # feed the measured merge stall (and the queue depth it was
            # inflicted on) back into the policy's cost model — this is the
            # measured replacement for the static saturation_penalty
            try:
                depth = self.scheduler.signals_for(rec.functions).queue_depth
            except Exception:  # noqa: BLE001 — feedback is best-effort
                depth = 0
            self.edge_costs.observe_merge_stall(rec.seconds, depth)

    def provisioning_stats(self) -> dict:
        """Warm/cold provisioning latency aggregates + compile-cache and
        snapshot-store counters — platform.stats()['provisioning']."""
        from repro.launch.compile_cache import EXECUTABLE_INDEX

        with self._prov_lock:
            records = list(self._prov_records)
            compile_cache = {
                "hits": self._compile_hits,
                "misses": self._compile_misses,
                "saved_s": round(self._compile_saved_s, 4),
                "spent_s": round(self._compile_spent_s, 4),
            }
        builds = [r for r in records if r.kind != "park"]
        warm = [r for r in builds if r.warm]
        cold = [r for r in builds if not r.warm]
        warm_mean = sum(r.seconds for r in warm) / len(warm) if warm else 0.0
        cold_mean = sum(r.seconds for r in cold) / len(cold) if cold else 0.0
        counts: dict[str, int] = {}
        for r in records:
            counts[r.kind] = counts.get(r.kind, 0) + 1
        with self._parked_lock:
            parked = sorted(self._parked)
        out = {
            "counts": counts,
            "warm": len(warm),
            "cold": len(cold),
            "warm_mean_s": round(warm_mean, 4),
            "cold_mean_s": round(cold_mean, 4),
            "warm_speedup": (
                round(cold_mean / warm_mean, 2) if warm and cold and warm_mean > 0
                else None
            ),
            "compile_cache": compile_cache,
            "executable_index": EXECUTABLE_INDEX.stats(),
            "parked": parked,
            "events": [
                {"kind": r.kind, "functions": list(r.functions),
                 "seconds": round(r.seconds, 4), "warm": r.warm, "billed": r.billed}
                for r in records[-32:]
            ],
        }
        if self.snapshots is not None:
            out["snapshots"] = self.snapshots.stats()
        return out

    # ------------------------------------- replicated data plane / autoscaling

    def enable_autoscaler(self, **knobs):
        """Turn on rho-driven replica autoscaling: registers an
        :class:`repro.core.autoscaler.Autoscaler` as a reconciler tick hook.
        ``knobs`` forward to its constructor (rho_high, rho_low, depth_high,
        sustain, max_replicas, min_replicas, cooldown_s, eval_interval_s)."""
        from repro.core.autoscaler import Autoscaler

        self.autoscaler = Autoscaler(self, **knobs)
        self.lifecycle.add_tick_hook(self.autoscaler.tick)
        return self.autoscaler

    def request_replica(self, name: str, reason: str = "") -> None:
        """Scale-out hint (the fusion policy's replicate arm routes here).
        No-op without an autoscaler — the hint is advisory, and the
        autoscaler owns the max-replica/cooldown guards."""
        scaler = self.autoscaler
        if scaler is not None:
            scaler.request_scale_out(name, reason=reason)

    def replica_spinup_estimate(self, name: str | None = None) -> float | None:
        """EWMA of measured warm replica spin-up seconds, or None before any
        replica has ever spun up (the policy's replicate arm then stays
        cold — it never bets on an unmeasured cost)."""
        with self._prov_lock:
            return self._spinup_ewma_s

    def _spawn_replica(self, name: str) -> FunctionInstance | None:
        """Build one replica of the unit currently routed for ``name`` and
        publish it through a scale-out epoch. With the executable index warm
        (PR 8) the replica's programs restore instead of rebuilding — the
        canary warm-up below performs zero XLA compiles.

        The canary health check runs via DIRECT ``replica.execute`` — never
        ``invoke`` — so spin-up traffic stamps no demand (note_demand) and
        bills nothing: per-replica demand attribution stays consistent with
        what clients actually sent. Returns None when the route vanished
        under us (a racing park/merge won)."""
        inst = self.registry.get(name)
        if inst is None:
            return None
        t0 = self.clock.now()
        specs = {m: self.spec_of(m) for m in inst.members}
        replica = FunctionInstance(specs, self)
        self.attach_instance(replica)
        for m in sorted(replica.members):
            canary = self.handler.canary(m)
            if canary is None:
                continue
            if replica.get_compiled(m, canary) is None:
                # boundary entry: replaying it would dispatch outbound calls
                # through live routing (edge stats + billing pollution);
                # get_compiled above still warmed what could be warmed
                continue
            replica.execute(m, canary)
        replica.mark_ready()
        event = self.lifecycle.scale_out(
            replica, tuple(sorted(replica.members)),
            reason=f"replica of {inst.instance_id}",
        )
        if event is None:
            self.detach_instance(replica)
            return None
        seconds = self.clock.now() - t0
        profile = replica.provision_profile()
        self.note_provisioning(
            "scale-out", seconds, warm=profile["cache_misses"] == 0,
            functions=tuple(sorted(replica.members)),
            resident_bytes=replica.resident_bytes(), billed=True,
        )
        with self._prov_lock:
            prev = self._spinup_ewma_s
            self._spinup_ewma_s = seconds if prev is None else 0.5 * prev + 0.5 * seconds
        return replica

    def replica_stats(self, per_instance: dict | None = None) -> dict:
        """Per-replica view for ``stats()["replicas"]``: replica ids, spread
        pick counts, in-flight counts, per-replica billing split, and the
        name-level demand rate. Demand is stamped ONCE per client request at
        the entry points (note_demand) — never per replica pick — so the
        fission divergence signals see replicated traffic exactly once.
        ``stats()`` passes the per-instance split from its coherent meter
        snapshot; standalone callers let it be computed fresh."""
        summary = self.registry.replica_summary()
        if per_instance is None:
            per_instance = self.meter.by_instance()
        functions = {}
        for name, info in summary.items():
            functions[name] = {
                **info,
                "demand_rps": round(self.handler.recent_rate(name), 3),
                "billing": {
                    iid: per_instance[iid]
                    for iid in info["replicas"]
                    if iid in per_instance
                },
            }
        out = {
            "spread": self.registry.spread_name,
            "spinup_estimate_s": self.replica_spinup_estimate(),
            "functions": functions,
        }
        if self.autoscaler is not None:
            out["autoscaler"] = self.autoscaler.stats()
        return out

    # ------------------------------------------------------------- shapes

    def output_structs(self, name: str, args: tuple):
        self._ensure_live(name)  # a parked spec is a params-free stub
        key = (name, _struct_key(args))
        with self._shape_lock:
            if key in self._shape_cache:
                return self._shape_cache[key]
            if name in self._shape_stack:
                raise InvocationError(f"call cycle through {name!r}: {self._shape_stack}")
            spec = self.spec_of(name)
            self._shape_stack.append(name)
            try:
                def run(params, *a):
                    return spec.fn(AbstractContext(self, name), params, *a)

                out = jax.eval_shape(run, _structs_of(spec.params), *_structs_of(args))
            finally:
                self._shape_stack.pop()
            self._shape_cache[key] = out
            return out

    # ------------------------------------------------------------- hooks

    def _on_candidate(self, caller: str, callee: str) -> None:
        with self._pending_lock:
            if (caller, callee) not in self._pending_candidates:
                self._pending_candidates.append((caller, callee))

    def _drain_candidates(self) -> None:
        if not self._draining.acquire(blocking=False):
            return  # a merge in progress is already invoking health checks
        try:
            while True:
                with self._pending_lock:
                    if not self._pending_candidates:
                        return
                    caller, callee = self._pending_candidates.pop(0)
                self.merger.submit(caller, callee)
        finally:
            self._draining.release()

    def attach_instance(self, instance: FunctionInstance) -> None:
        """Backend hook: provision execution resources for an instance."""

    def detach_instance(self, instance: FunctionInstance) -> None:
        """Backend hook: tear down resources for a never-promoted instance."""

    def retire_instance(self, instance: FunctionInstance) -> int:
        freed = instance.retire()
        self.detach_instance(instance)
        return freed

    # ------------------------------------------------------------- running

    def _run_request(self, instance: FunctionInstance, entry: str, args: tuple):
        instance.begin_request()
        self.handler.enter(entry, instance)
        try:
            out = instance.execute(entry, args)
        except BaseException:
            # failed attempts are not billed — the retry path would otherwise
            # double-bill the same request (swap races, redeploys)
            self.handler.abort(entry)
            raise
        else:
            self.handler.exit(entry)
            return out
        finally:
            instance.end_request()

    def _run_batch(self, instance: FunctionInstance, entry: str, args_list: list[tuple]) -> list:
        instance.begin_request()
        self.handler.enter(entry, instance, batch_size=len(args_list))
        try:
            out = instance.execute_batch(entry, args_list, max_bucket=self.scheduler.max_batch)
        except BaseException:
            self.handler.abort(entry)
            raise
        else:
            self.handler.exit(entry)
            return out
        finally:
            instance.end_request()

    def _invoke_with_retry(self, name: str, args: tuple):
        """Serial dispatch with swap-race recovery. Also the Merger's canary
        replay path — no latency observation here, so control-plane traffic
        never pollutes the external latency percentiles."""
        self._ensure_live(name)
        try:
            try:
                return self._dispatch_sync(name, args)
            except UnknownFunctionError:
                # raced a scale-to-zero park: the route vanished between
                # _ensure_live and resolve — resurrect and retry (a truly
                # unknown name stays unknown and re-raises)
                self._ensure_live(name)
                return self._dispatch_sync(name, args)
            except InvocationError:
                # A request can race a merge swap: it resolved the old
                # instance, the Merger retired it mid-flight. Re-resolving
                # picks up the new routing; only if THAT fails is the
                # container actually gone and a fresh one provisioned.
                try:
                    return self._dispatch_sync(name, args)
                except InvocationError:
                    self._redeploy(name)
                    return self._dispatch_sync(name, args)
        finally:
            self._drain_candidates()

    def invoke(self, name: str, *args):
        """External (client) invocation — serial path. Mints the request's
        trace and activates it so every phase below (execute, cross-function
        hops, resurrects) nests under this root."""
        self.handler.record_canary(name, args)
        self.handler.note_demand(name)
        t0 = self.clock.now()
        ctx = self.tracer.begin_request(name, "invoke", t0=t0)
        try:
            with self.tracer.activate(ctx):
                out = self._invoke_with_retry(name, args)
        except BaseException as exc:
            if ctx is not None:
                ctx.finish(args={"error": type(exc).__name__})
            raise
        t1 = self.clock.now()
        if ctx is not None:
            ctx.finish(t1)
        self.meter.observe_latency(name, t1 - t0)
        return out

    def invoke_async(self, name: str, *args, priority: int = 0,
                     slo: SLOClass | None = None) -> Future:
        """External invocation through the request scheduler. Returns a
        Future; compatible concurrent requests may execute as one batch.
        ``slo=SLOClass(name, target_p95_ms)`` admits the request into its
        class's own lane (single-class batches, window from the class's
        target slack); ``priority=PRIORITY_HIGH`` is the two-level shim —
        it maps to the zero-target class, jumps queued normal traffic, and
        closes an open batching window early (SLO admission)."""
        self.handler.record_canary(name, args)
        self.handler.note_demand(name)
        return self.scheduler.submit(name, args, priority=priority, slo=slo)

    def scheduler_signals(self, names):
        """Live scheduler feedback for the fusion policy (Merger.submit)."""
        return self.scheduler.signals_for(names)

    def _dispatch_batch(self, name: str, args_list: list[tuple]) -> list:
        """Scheduler callback: execute one coalesced batch."""
        self._ensure_live(name)
        try:
            try:
                return self._dispatch_batch_impl(name, args_list)
            except UnknownFunctionError:
                self._ensure_live(name)  # raced a park — resurrect and retry
                return self._dispatch_batch_impl(name, args_list)
            except InvocationError:
                try:  # routing may have swapped mid-flight (see invoke)
                    return self._dispatch_batch_impl(name, args_list)
                except InvocationError:
                    self._redeploy(name)
                    return self._dispatch_batch_impl(name, args_list)
        finally:
            self._drain_candidates()

    def _redeploy(self, name: str) -> None:
        if self.snapshots is not None:
            with self._parked_lock:
                parked = name in self._parked
            if parked:
                # a parked spec is a params-free stub — resurrect instead of
                # rebuilding from it
                self._ensure_live(name)
                return
        spec = self.spec_of(name)
        fresh = FunctionInstance({name: spec}, self)
        self.attach_instance(fresh)
        fresh.mark_ready()
        # Epoch transition: the displaced (dead-routed) instance is drained
        # AND retired — before the control plane owned this, the old worker
        # thread stayed alive and ram_bytes() kept counting the corpse.
        self.lifecycle.publish({name: fresh}, kind="redeploy", reason=f"redeploy {name}")

    def _fission_tick(self) -> None:
        """Reconciler-tick hook: rate-limited regret evaluation over the
        committed fusion groups (control-plane work, off the data path)."""
        now = self.clock.now()
        if now - self._last_fission_eval < self._fission_interval_s:
            return
        self._last_fission_eval = now
        self.merger.evaluate_splits()

    def remote_call(self, caller_instance: FunctionInstance, caller_fn: str, callee: str, args: tuple):
        """Blocking function-to-function dispatch (runs inside the caller's
        pure_callback — the caller's program is parked until this returns)."""
        self.handler.record_canary(callee, args)
        # Boundary hop: the wait is a distinct "cross-function-sync" span in
        # the caller's trace (a fused-inline call records no hop — see
        # EagerContext.call), and the measured wait feeds the edge-cost EWMA
        # the fusion policy weighs instead of its static knobs.
        cur = self.tracer.current()
        sid = cur[0].alloc_id() if cur is not None else None
        self._ensure_live(callee)
        t0 = self.clock.now()
        with self.tracer.activate(cur[0] if cur else None, sid or 1):
            try:
                out = self._dispatch_sync(callee, args)
            except UnknownFunctionError:
                self._ensure_live(callee)  # raced a park — resurrect and retry
                out = self._dispatch_sync(callee, args)
        wait = self.clock.now() - t0
        if cur is not None:
            cur[0].emit(f"{caller_fn}->{callee}", "cross-function-sync",
                        t0, t0 + wait, parent_id=cur[1], span_id=sid,
                        args={"caller": caller_fn, "callee": callee})
        self.handler.attribute_blocked(wait)
        self.handler.observe_edge(caller_fn, callee, sync=True, wait_s=wait)
        self.edge_costs.observe_sync_edge(caller_fn, callee, wait)
        return out

    def async_call(self, caller_instance: FunctionInstance, caller_fn: str, callee: str, args: tuple) -> None:
        self.handler.observe_edge(caller_fn, callee, sync=False)
        self._dispatch_async(callee, args)

    # ------------------------------------------------------------- metrics

    def ram_bytes(self) -> int:
        return sum(inst.resident_bytes() for inst in self.registry.live_instances())

    def stats(self) -> dict:
        # ONE billing-meter snapshot feeds billing, latency, AND the
        # per-replica split: totals inside a stats() dict are mutually
        # consistent even mid-traffic (each sub-view derives from the same
        # records copy taken under a single lock acquisition).
        meter_snap = self.meter.snapshot()
        return {
            "backend": self.backend_name,
            "ram_bytes": self.ram_bytes(),
            "instances": [repr(i) for i in self.registry.live_instances()],
            "edges": self.handler.stats(),
            "merges": [
                {
                    "members": e.members,
                    "freed_bytes": e.freed_bytes,
                    "build_s": round(e.build_s, 4),
                    "healthy": e.healthy,
                    "epoch": e.epoch,
                    "reason": e.reason,
                    "warm": e.warm,
                }
                for e in self.merger.merge_log
            ],
            "splits": [
                {
                    "members": e.members,
                    "partition": e.partition,
                    "healthy": e.healthy,
                    "epoch": e.epoch,
                    "reason": e.reason,
                    "build_s": round(e.build_s, 4),
                    "warm": e.warm,
                }
                for e in self.merger.split_log
            ],
            "lifecycle": self.lifecycle.stats(),
            "provisioning": self.provisioning_stats(),
            "billing": meter_snap["billing"],
            "latency": meter_snap["latency"],
            "scheduler": self.scheduler.stats(),
            "replicas": self.replica_stats(per_instance=meter_snap["by_instance"]),
            "edge_costs": self.edge_costs.stats(),
        }

    # ------------------------------------------------------------- backend API

    def _dispatch_sync(self, name: str, args: tuple):
        raise NotImplementedError

    def _dispatch_async(self, name: str, args: tuple) -> None:
        raise NotImplementedError

    def _dispatch_batch_impl(self, name: str, args_list: list[tuple]) -> list:
        raise NotImplementedError

    def shutdown(self) -> None:
        self.lifecycle.shutdown()
        self.scheduler.shutdown()


class TinyJaxBackend(ProvusePlatform):
    """tinyFaaS analogue: direct in-thread dispatch, minimal overhead."""

    backend_name = "tinyjax"

    def __init__(self, *args, async_workers: int = 2, **kwargs):
        super().__init__(*args, **kwargs)
        self._async_pool = ThreadPoolExecutor(max_workers=async_workers, thread_name_prefix="tinyjax-async")

    def _dispatch_sync(self, name: str, args: tuple):
        instance = self.registry.resolve(name)
        return self._run_request(instance, name, args)

    def _dispatch_batch_impl(self, name: str, args_list: list[tuple]) -> list:
        instance = self.registry.resolve(name)
        return self._run_batch(instance, name, args_list)

    def _dispatch_async(self, name: str, args: tuple) -> None:
        self._async_pool.submit(self._safe_async, name, args)

    def _safe_async(self, name: str, args: tuple) -> None:
        try:
            self._dispatch_sync(name, args)
        except Exception:
            pass  # async branches are fire-and-forget; failures are logged by billing absence

    def shutdown(self) -> None:
        super().shutdown()
        self._async_pool.shutdown(wait=True)


class _Worker:
    """A Pod: serial request loop over a queue."""

    def __init__(self, platform: "OrchestratedBackend", instance: FunctionInstance):
        self.instance = instance
        self.platform = platform
        self.q: "queue.Queue[tuple | None]" = queue.Queue()  # (entry, payload, fut, is_batch, trace-ctx)
        self.thread = threading.Thread(target=self._loop, daemon=True, name=f"worker-{instance.instance_id}")
        self.thread.start()

    def _loop(self):
        tracer = self.platform.tracer
        while True:
            item = self.q.get()
            if item is None:
                return
            entry, payload, fut, is_batch, cur = item
            try:
                # re-activate the submitter's trace context: spans emitted
                # inside the pod (handler execute, nested calls) land in the
                # request's tree even though it hopped threads
                with tracer.activate_snapshot(cur):
                    if is_batch:
                        fut.set_result(self.platform._run_batch(self.instance, entry, payload))
                    else:
                        fut.set_result(self.platform._run_request(self.instance, entry, payload))
            except Exception as exc:  # noqa: BLE001
                fut.set_exception(exc)

    def submit(self, entry: str, args: tuple) -> Future:
        fut: Future = Future()
        self.q.put((entry, args, fut, False, self.platform.tracer.current()))
        return fut

    def submit_batch(self, entry: str, args_list: list[tuple]) -> Future:
        fut: Future = Future()
        self.q.put((entry, args_list, fut, True, self.platform.tracer.current()))
        return fut

    def stop(self):
        self.q.put(None)


class OrchestratedBackend(ProvusePlatform):
    """Kubernetes analogue: queue+thread Pods, Service indirection, rolling
    swaps with readiness gating."""

    backend_name = "orchestrated"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._workers: dict[str, _Worker] = {}
        self._workers_lock = threading.Lock()

    def attach_instance(self, instance: FunctionInstance) -> None:
        with self._workers_lock:
            self._workers[instance.instance_id] = _Worker(self, instance)

    def detach_instance(self, instance: FunctionInstance) -> None:
        with self._workers_lock:
            worker = self._workers.pop(instance.instance_id, None)
        if worker:
            worker.stop()

    def _worker_for(self, instance: FunctionInstance) -> _Worker:
        with self._workers_lock:
            worker = self._workers.get(instance.instance_id)
        if worker is None:
            raise InvocationError(f"no worker for {instance.instance_id}")
        return worker

    def _dispatch_sync(self, name: str, args: tuple):
        instance = self.registry.resolve(name)
        current = threading.current_thread()
        worker = self._worker_for(instance)
        if worker.thread is current:
            # self-call inside the same pod: run inline (avoids deadlock)
            return self._run_request(instance, name, args)
        return worker.submit(name, args).result()

    def _dispatch_batch_impl(self, name: str, args_list: list[tuple]) -> list:
        instance = self.registry.resolve(name)
        worker = self._worker_for(instance)
        if worker.thread is threading.current_thread():
            return self._run_batch(instance, name, args_list)
        return worker.submit_batch(name, args_list).result()

    def _dispatch_async(self, name: str, args: tuple) -> None:
        instance = self.registry.resolve(name)
        self._worker_for(instance).submit(name, args)

    def shutdown(self) -> None:
        super().shutdown()
        with self._workers_lock:
            for worker in self._workers.values():
                worker.stop()
            self._workers = {}
