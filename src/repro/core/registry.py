"""Routing table: function name -> serving instance, versioned by epoch.

The paper's analogue of the tinyFaaS API-gateway entries / Kubernetes
Service selectors. All mutations funnel through :meth:`publish` — an atomic
multi-route update under one lock — and ``version`` is the platform's
routing *epoch*: it bumps exactly when some route actually changes, so epoch
numbers in the control plane's event log are meaningful (an empty or no-op
swap is not a new generation).

The lock is exposed (``mutex``) so the control plane can make lifecycle
state flips atomic WITH the route flip: an instance is only ever marked
DRAINING inside the same critical section that removed its last route, which
is what lets ``resolve_entry`` guarantee it never observes a DRAINING
instance through a live route.
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Iterable

from repro.core.errors import UnknownFunctionError

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.function import FunctionInstance, InstanceState


class RoutingTable:
    def __init__(self):
        self._lock = threading.RLock()
        self._routes: dict[str, "FunctionInstance"] = {}
        self.version = 0

    @property
    def mutex(self) -> threading.RLock:
        """The routing lock — reentrant so the control plane can compose an
        atomic publish + lifecycle-state transition."""
        return self._lock

    def publish(self, updates: dict[str, "FunctionInstance"]) -> dict[str, "FunctionInstance"]:
        """Atomically apply ``updates`` (name -> new instance); returns the
        displaced previous instances. ``version`` bumps once iff at least one
        route actually changed — republishing identical routes (or an empty
        update) is not a new epoch."""
        with self._lock:
            old = {}
            changed = False
            for name, instance in updates.items():
                prev = self._routes.get(name)
                if prev is not None:
                    old[name] = prev
                if prev is not instance:
                    self._routes[name] = instance
                    changed = True
            if changed:
                self.version += 1
            return old

    def register(self, name: str, instance: "FunctionInstance") -> None:
        self.publish({name: instance})

    def unpublish(self, names: Iterable[str]) -> dict[str, "FunctionInstance"]:
        """Atomically remove routes (scale-to-zero park): the names simply
        stop resolving. Returns the removed mapping; ``version`` bumps once
        iff something was actually routed."""
        with self._lock:
            removed = {}
            for name in names:
                inst = self._routes.pop(name, None)
                if inst is not None:
                    removed[name] = inst
            if removed:
                self.version += 1
            return removed

    def resolve(self, name: str) -> "FunctionInstance":
        with self._lock:
            try:
                return self._routes[name]
            except KeyError:
                raise UnknownFunctionError(name) from None

    def resolve_entry(self, name: str) -> tuple["FunctionInstance", "InstanceState"]:
        """Resolve plus the instance's lifecycle state, read atomically with
        the route under the routing lock. Because displacement marks an
        instance DRAINING in the same critical section that unroutes it, the
        returned state is never DRAINING or RETIRED."""
        with self._lock:
            try:
                instance = self._routes[name]
            except KeyError:
                raise UnknownFunctionError(name) from None
            return instance, instance.state

    def get(self, name: str) -> "FunctionInstance | None":
        with self._lock:
            return self._routes.get(name)

    def swap(self, names: Iterable[str], instance: "FunctionInstance") -> dict[str, "FunctionInstance"]:
        """Atomically point every name at ``instance``; returns the previous
        instances (for draining/retirement)."""
        return self.publish({name: instance for name in names})

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._routes)

    def live_instances(self) -> list["FunctionInstance"]:
        with self._lock:
            seen: dict[int, "FunctionInstance"] = {}
            for inst in self._routes.values():
                seen[id(inst)] = inst
            return list(seen.values())
