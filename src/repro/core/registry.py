"""Routing table: function name -> ordered replica set, versioned by epoch.

The paper's analogue of the tinyFaaS API-gateway entries / Kubernetes
Service selectors, generalized from one-instance-per-name to an ordered
**replica set** per name. All mutations funnel through :meth:`publish` /
:meth:`add_replicas` / :meth:`remove_replicas` — atomic multi-route updates
under one lock — and ``version`` is the platform's routing *epoch*: it bumps
exactly when some route's ordered replica set actually changes, so epoch
numbers in the control plane's event log are meaningful (an empty or no-op
swap is not a new generation).

Each resolve picks one replica through a pluggable :class:`SpreadPolicy`
(least-outstanding by default, round-robin fallback). The lock is exposed
(``mutex``) so the control plane can make lifecycle state flips atomic WITH
the route flip: an instance is only ever marked DRAINING inside the same
critical section that removed its last route, which is what lets
``resolve_entry`` guarantee it never observes a DRAINING replica through a
live route.
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Iterable, Sequence

from repro.core.errors import UnknownFunctionError

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.function import FunctionInstance, InstanceState


class SpreadPolicy:
    """Picks which replica of a name serves the next resolve.

    ``select`` is called with a non-empty replica tuple while the routing
    lock is held, so the tuple is a consistent snapshot; implementations keep
    their own cursor state under their own lock (ordered strictly after the
    routing lock — never call back into the table).
    """

    name = "spread"

    def select(self, name: str, replicas: Sequence["FunctionInstance"]) -> "FunctionInstance":
        raise NotImplementedError


class RoundRobinSpread(SpreadPolicy):
    """Cycle through the replica set in publish order, one pick per resolve."""

    name = "round-robin"

    GUARDED_FIELDS = {"_cursor": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._cursor: dict[str, int] = {}

    def select(self, name: str, replicas: Sequence["FunctionInstance"]) -> "FunctionInstance":
        with self._lock:
            i = self._cursor.get(name, 0) % len(replicas)
            self._cursor[name] = i + 1
        return replicas[i]


class LeastOutstandingSpread(SpreadPolicy):
    """Default spread: the replica with the fewest in-flight requests wins;
    ties rotate round-robin so idle replicas still share picks. In-flight
    counts come from ``FunctionInstance.outstanding()`` (begin/end_request
    bracketing), which slightly undercounts queued-but-unstarted pod work on
    the orchestrated backend — acceptable: ties then fall to the rotor."""

    name = "least-outstanding"

    GUARDED_FIELDS = {"_cursor": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._cursor: dict[str, int] = {}

    def select(self, name: str, replicas: Sequence["FunctionInstance"]) -> "FunctionInstance":
        loads = [r.outstanding() for r in replicas]
        low = min(loads)
        tied = [r for r, load in zip(replicas, loads) if load == low]
        if len(tied) == 1:
            return tied[0]
        with self._lock:
            i = self._cursor.get(name, 0) % len(tied)
            self._cursor[name] = i + 1
        return tied[i]


SPREAD_POLICIES = {
    LeastOutstandingSpread.name: LeastOutstandingSpread,
    RoundRobinSpread.name: RoundRobinSpread,
}


def make_spread(spread: "SpreadPolicy | str | None") -> SpreadPolicy:
    """Resolve a spread policy from a name (``least-outstanding`` /
    ``round-robin``), an instance, or None (the default)."""
    if spread is None:
        return LeastOutstandingSpread()
    if isinstance(spread, SpreadPolicy):
        return spread
    try:
        return SPREAD_POLICIES[spread]()
    except KeyError:
        raise ValueError(
            f"unknown spread policy {spread!r}; known: {sorted(SPREAD_POLICIES)}"
        ) from None


class RoutingTable:
    GUARDED_FIELDS = {"_routes": "_lock", "_picks": "_lock", "version": "_lock"}

    def __init__(self, spread: "SpreadPolicy | str | None" = None):
        self._lock = threading.RLock()
        self._routes: dict[str, tuple["FunctionInstance", ...]] = {}
        self._picks: dict[str, dict[str, int]] = {}
        self._spread = make_spread(spread)
        self.version = 0

    @property
    def mutex(self) -> threading.RLock:
        """The routing lock — reentrant so the control plane can compose an
        atomic publish + lifecycle-state transition."""
        return self._lock

    @property
    def spread_name(self) -> str:
        return self._spread.name

    @staticmethod
    def _as_replicas(value) -> tuple["FunctionInstance", ...]:
        if isinstance(value, (tuple, list)):
            return tuple(value)
        return (value,)

    def publish(self, updates) -> dict[str, tuple["FunctionInstance", ...]]:
        """Atomically apply ``updates`` (name -> new instance, or an ordered
        replica sequence); each named route's FULL replica set is replaced
        (an empty sequence unroutes the name). Returns the displaced previous
        replica tuples. ``version`` bumps once iff at least one route's
        ordered replica set actually changed — republishing identical routes
        (or an empty update) is not a new epoch."""
        with self._lock:
            old: dict[str, tuple["FunctionInstance", ...]] = {}
            changed = False
            for name, value in updates.items():
                replicas = self._as_replicas(value)
                prev = self._routes.get(name, ())
                if prev:
                    old[name] = prev
                if not replicas:
                    if prev:
                        del self._routes[name]
                        self._picks.pop(name, None)
                        changed = True
                    continue
                if prev != replicas:
                    self._routes[name] = replicas
                    changed = True
            if changed:
                self.version += 1
            return old

    def register(self, name: str, instance: "FunctionInstance") -> None:
        self.publish({name: instance})

    def unpublish(self, names: Iterable[str]) -> dict[str, tuple["FunctionInstance", ...]]:
        """Atomically remove routes (scale-to-zero park): the names simply
        stop resolving — every replica of each name. Returns the removed
        replica tuples; ``version`` bumps once iff something was actually
        routed."""
        with self._lock:
            removed: dict[str, tuple["FunctionInstance", ...]] = {}
            for name in names:
                replicas = self._routes.pop(name, ())
                if replicas:
                    removed[name] = replicas
                    self._picks.pop(name, None)
            if removed:
                self.version += 1
            return removed

    def add_replicas(self, names: Iterable[str], instance: "FunctionInstance") -> tuple[str, ...]:
        """Scale-out: append ``instance`` to each named route's replica set.
        Names with no live route (a racing park/merge won) or already holding
        this replica are skipped. One ``version`` bump covers the whole
        update. Returns the names whose sets changed."""
        with self._lock:
            changed = []
            for name in names:
                prev = self._routes.get(name)
                if not prev or any(r is instance for r in prev):
                    continue
                self._routes[name] = prev + (instance,)
                changed.append(name)
            if changed:
                self.version += 1
            return tuple(changed)

    def remove_replicas(self, names: Iterable[str], instance: "FunctionInstance",
                        *, keep_last: bool = True) -> tuple[str, ...]:
        """Scale-in: remove ``instance`` from each named route's replica set.
        With ``keep_last`` (the default) a name's only replica is never
        removed — scale-in shrinks a set but never unroutes a function (that
        is :meth:`unpublish`'s job). One ``version`` bump covers the whole
        update. Returns the names whose sets changed."""
        with self._lock:
            changed = []
            for name in names:
                prev = self._routes.get(name, ())
                if not any(r is instance for r in prev):
                    continue
                if keep_last and len(prev) == 1:
                    continue
                self._routes[name] = tuple(r for r in prev if r is not instance)
                changed.append(name)
            if changed:
                self.version += 1
            return tuple(changed)

    def _pick(self, name: str, replicas: tuple["FunctionInstance", ...]) -> "FunctionInstance":
        with self._lock:  # reentrant: resolve paths already hold the lock
            if len(replicas) == 1:
                instance = replicas[0]
            else:
                instance = self._spread.select(name, replicas)
            counts = self._picks.setdefault(name, {})
            counts[instance.instance_id] = counts.get(instance.instance_id, 0) + 1
            return instance

    def resolve(self, name: str) -> "FunctionInstance":
        with self._lock:
            replicas = self._routes.get(name)
            if not replicas:
                raise UnknownFunctionError(name)
            return self._pick(name, replicas)

    def resolve_entry(self, name: str) -> tuple["FunctionInstance", "InstanceState"]:
        """Resolve (spread-selected replica) plus the replica's lifecycle
        state, read atomically with the route under the routing lock. Because
        removal from a replica's last route marks it DRAINING in the same
        critical section, the returned state is never DRAINING or RETIRED."""
        with self._lock:
            replicas = self._routes.get(name)
            if not replicas:
                raise UnknownFunctionError(name)
            instance = self._pick(name, replicas)
            return instance, instance.state

    def get(self, name: str) -> "FunctionInstance | None":
        """The PRIMARY (first-published) replica for ``name``, or None. This
        is the identity the control plane's CAS guards and park/split checks
        compare against — scale-out appends AFTER the primary, so those
        transactions are replica-oblivious."""
        with self._lock:
            replicas = self._routes.get(name)
            return replicas[0] if replicas else None

    def replicas(self, name: str) -> tuple["FunctionInstance", ...]:
        with self._lock:
            return self._routes.get(name, ())

    def replica_count(self, name: str) -> int:
        with self._lock:
            return len(self._routes.get(name, ()))

    def is_routed(self, instance: "FunctionInstance") -> bool:
        with self._lock:
            return any(
                any(r is instance for r in replicas)
                for replicas in self._routes.values()
            )

    def swap(self, names: Iterable[str], instance: "FunctionInstance") -> dict[str, tuple["FunctionInstance", ...]]:
        """Atomically point every name at ``instance`` (collapsing any replica
        set to that single unit); returns the previous replica tuples (for
        draining/retirement)."""
        return self.publish({name: instance for name in names})

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._routes)

    def live_instances(self) -> list["FunctionInstance"]:
        with self._lock:
            seen: dict[int, "FunctionInstance"] = {}
            for replicas in self._routes.values():
                for inst in replicas:
                    seen[id(inst)] = inst
            return list(seen.values())

    def replica_summary(self) -> dict:
        """Per-name replica view for ``platform.stats()["replicas"]``:
        replica ids in publish order, per-replica in-flight counts, and
        cumulative spread pick counts."""
        with self._lock:
            out = {}
            for name, replicas in self._routes.items():
                out[name] = {
                    "replicas": [r.instance_id for r in replicas],
                    "outstanding": {r.instance_id: r.outstanding() for r in replicas},
                    "picks": dict(self._picks.get(name, {})),
                }
            return out
