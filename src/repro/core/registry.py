"""Routing table: function name -> serving instance.

The paper's analogue of the tinyFaaS API-gateway entries / Kubernetes
Service selectors. Swaps are atomic (single lock) and versioned so the
Merger can redirect a whole fusion group in one step while requests keep
flowing ("routes incoming requests for the local functions to the combined
instance", §3).
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Iterable

from repro.core.errors import UnknownFunctionError

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.function import FunctionInstance


class RoutingTable:
    def __init__(self):
        self._lock = threading.Lock()
        self._routes: dict[str, "FunctionInstance"] = {}
        self.version = 0

    def register(self, name: str, instance: "FunctionInstance") -> None:
        with self._lock:
            self._routes[name] = instance
            self.version += 1

    def resolve(self, name: str) -> "FunctionInstance":
        with self._lock:
            try:
                return self._routes[name]
            except KeyError:
                raise UnknownFunctionError(name) from None

    def swap(self, names: Iterable[str], instance: "FunctionInstance") -> dict[str, "FunctionInstance"]:
        """Atomically point every name at ``instance``; returns the previous
        instances (for draining/retirement)."""
        with self._lock:
            old = {}
            for name in names:
                if name in self._routes:
                    old[name] = self._routes[name]
                self._routes[name] = instance
            self.version += 1
            return old

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._routes)

    def live_instances(self) -> list["FunctionInstance"]:
        with self._lock:
            seen: dict[int, "FunctionInstance"] = {}
            for inst in self._routes.values():
                seen[id(inst)] = inst
            return list(seen.values())
