"""The control plane: instance lifecycle + generation-versioned routing epochs.

Every routing mutation the platform performs — deploy, merge swap, redeploy,
split (fission) — is an *epoch transition*: an atomic publish against the
routing table that, under ONE lock,

  1. flips every affected route to its new instance,
  2. marks the newly-routed instances SERVING,
  3. marks displaced instances that are no longer routed anywhere DRAINING,

then (outside the lock) drains and retires the displaced instances. Because
steps 1–3 share the routing table's lock with ``resolve``, a concurrent
request can never resolve a DRAINING instance: an instance only enters
DRAINING in the same critical section that removes its last route.

The instance state machine (:class:`repro.core.function.InstanceState`):

    PROVISIONING -> READY -> SERVING -> DRAINING -> RETIRED

PROVISIONING while the unit is being built/compiled, READY once health-checked
but not yet routed, SERVING while routed, DRAINING after displacement while
in-flight requests finish, RETIRED once drained and its memory freed.

The control plane also owns the *reconciler*: a background thread that
executes queued transitions (deferred merges, fission splits) during observed
traffic troughs — the scheduler's arrival-gap EWMAs say when the platform is
quiet enough that a recompile stall lands on nobody (ProFaaStinate's
deferral, applied to control-plane work). Every queued transition carries a
``max_defer_s`` deadline so a platform that never troughs still converges.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import TYPE_CHECKING, Callable

from repro.scheduler.clock import SYSTEM_CLOCK

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.function import FunctionInstance

_EVENT_LOG_MAX = 512  # bounded epoch history (stats() reports the tail)


@dataclasses.dataclass
class EpochEvent:
    """One routing-epoch transition, as recorded in ``platform.stats()``."""

    epoch: int
    # "deploy" | "merge" | "split" | "redeploy" | "park" | "resurrect"
    # | "scale-out" | "scale-in"
    kind: str
    names: tuple[str, ...]
    reason: str = ""
    retired: tuple[str, ...] = ()  # instance_ids drained + retired by this epoch
    freed_bytes: int = 0
    t_completed: float = 0.0
    deferred_s: float = 0.0  # how long the reconciler held it for a trough


@dataclasses.dataclass
class _QueuedTransition:
    action: Callable[[], None]
    kind: str
    names: tuple[str, ...]
    reason: str
    t_enqueued: float
    deadline: float


class ControlPlane:
    """Owns epoch transitions, instance lifecycle, and the reconciler.

    ``trough_quiet_s`` / ``trough_gap_mult`` parameterize the scheduler's
    trough test (see :meth:`RequestScheduler.is_trough`); ``max_defer_s`` is
    the default deadline after which a queued transition runs trough or not.
    """

    # provlint: _idle_cv is Condition(self._queue_lock) — either name
    # counts as holding the queue lock.
    GUARDED_FIELDS = {
        "events": "_events_lock",
        "_queue": "_queue_lock",
        "_executing": "_queue_lock",
        "_wake_flag": "_wake_cv",
    }

    def __init__(self, platform, registry, *, tick_s: float = 0.02,
                 max_defer_s: float = 1.0, trough_quiet_s: float = 0.01,
                 trough_gap_mult: float = 3.0, drain_timeout_s: float = 0.5,
                 clock=None):
        self.platform = platform
        self.registry = registry
        # Injectable time source: defer deadlines, tick waits, and event
        # timestamps run on it, so reconciler behavior (trough deferral,
        # max_defer expiry) is drivable by a virtual clock in tests.
        self.clock = clock or SYSTEM_CLOCK
        self.tick_s = tick_s
        self.max_defer_s = max_defer_s
        self.drain_timeout_s = drain_timeout_s
        self.trough_quiet_s = trough_quiet_s
        self.trough_gap_mult = trough_gap_mult
        self.events: collections.deque[EpochEvent] = collections.deque(maxlen=_EVENT_LOG_MAX)
        self._events_lock = threading.Lock()
        self._queue: collections.deque[_QueuedTransition] = collections.deque()
        self._queue_lock = threading.Lock()
        self._idle_cv = threading.Condition(self._queue_lock)
        self._executing = 0
        # tick wake-up: a condition (not an Event) so the reconciler's
        # tick_s wait goes through the clock like every other timed wait
        self._wake_cv = threading.Condition()
        self._wake_flag = False
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._tick_hooks: list[Callable[[], None]] = []

    # --------------------------------------------------------------- epochs

    @property
    def epoch(self) -> int:
        """Current routing generation (bumps only on actual route changes)."""
        return self.registry.version

    def publish(self, routes: dict[str, "FunctionInstance"], *, kind: str,
                reason: str = "", expect: dict[str, "FunctionInstance"] | None = None,
                deferred_s: float = 0.0) -> EpochEvent | None:
        """Atomically publish a new routing epoch.

        ``routes`` maps every affected function name to the instance that will
        serve it from this epoch on. ``expect`` (optional) is a compare-and-swap
        guard: if any named route no longer points at the expected instance —
        another transition raced this one — nothing is published and ``None``
        is returned so the caller can abort its transaction.

        Displaced instances that end up routed nowhere are marked DRAINING
        inside the publish critical section (so a concurrent ``resolve`` can
        never return a DRAINING instance) and then drained + retired outside
        the lock. Returns the recorded :class:`EpochEvent`.
        """
        platform = self.platform
        registry = self.registry
        with registry.mutex:
            if expect is not None:
                for name, inst in expect.items():
                    if registry.get(name) is not inst:
                        return None
            displaced = registry.publish(routes)
            fresh: dict[int, "FunctionInstance"] = {}
            for value in routes.values():
                for inst in (value if isinstance(value, (tuple, list)) else (value,)):
                    fresh[id(inst)] = inst
            for inst in fresh.values():
                inst.mark_serving()
            still_routed = {id(i) for i in registry.live_instances()}
            doomed = [
                inst
                for inst in {
                    id(v): v for tup in displaced.values() for v in tup
                }.values()
                if id(inst) not in still_routed
            ]
            for inst in doomed:
                inst.begin_drain()
            epoch = registry.version
        # Drain + retirement happen OUTSIDE the routing lock. Two barriers
        # compose here: queued scheduler requests re-resolve the NEW routes at
        # dispatch (nothing queued can reach a displaced instance), and each
        # displaced instance's retire() waits out the requests already inside
        # it. A scheduler-wide quiesce would be wrong here — under saturation
        # (exactly when fission publishes) some batch is ALWAYS in flight, and
        # an epoch that waits for a globally empty pipe never lands.
        freed = 0
        for inst in doomed:
            freed += platform.retire_instance(inst)
        event = EpochEvent(
            epoch=epoch, kind=kind, names=tuple(sorted(routes)), reason=reason,
            retired=tuple(i.instance_id for i in doomed), freed_bytes=freed,
            t_completed=self.clock.now(), deferred_s=round(deferred_s, 4),
        )
        return self._record(event)

    def park(self, instance: "FunctionInstance", *, reason: str = "") -> EpochEvent | None:
        """Scale-to-zero epoch: atomically UNROUTE an instance's functions
        (they stop resolving — the platform resurrects them from snapshot on
        the next invoke), then drain + retire it outside the lock.

        Only names still routed to THIS instance are removed — a publish that
        raced the park (redeploy, merge) keeps its routes. Returns the
        recorded event, or None if nothing was routed here anymore."""
        platform = self.platform
        registry = self.registry
        with registry.mutex:
            names = tuple(sorted(
                m for m in instance.members if registry.get(m) is instance
            ))
            if not names:
                return None
            registry.unpublish(names)
            instance.begin_drain()
            epoch = registry.version
        freed = platform.retire_instance(instance)
        event = EpochEvent(
            epoch=epoch, kind="park", names=names, reason=reason,
            retired=(instance.instance_id,), freed_bytes=freed,
            t_completed=self.clock.now(),
        )
        return self._record(event)

    def scale_out(self, instance: "FunctionInstance", names, *,
                  reason: str = "") -> EpochEvent | None:
        """Scale-out epoch: atomically APPEND ``instance`` as a replica of
        every still-routed name in ``names`` and mark it SERVING. Names whose
        route vanished (a racing park or merge won) or that already hold this
        replica are skipped; returns None when nothing changed so the caller
        can retire the unused unit instead of leaking it."""
        registry = self.registry
        with registry.mutex:
            added = registry.add_replicas(names, instance)
            if not added:
                return None
            instance.mark_serving()
            epoch = registry.version
        event = EpochEvent(
            epoch=epoch, kind="scale-out", names=added, reason=reason,
            t_completed=self.clock.now(),
        )
        return self._record(event)

    def scale_in(self, instance: "FunctionInstance", *,
                 reason: str = "") -> EpochEvent | None:
        """Scale-in epoch: atomically REMOVE ``instance`` from every replica
        set that holds it and mark it DRAINING in the same critical section —
        the displacement invariant, so a concurrent resolve can never pick a
        draining replica. Refuses (returns None) if the instance holds no
        route, or if it is ANY name's only replica — scale-in shrinks sets,
        it never unroutes a function (that is :meth:`park`). Drain + retire
        happen outside the lock, so in-flight requests finish before the
        unit's memory is freed."""
        platform = self.platform
        registry = self.registry
        with registry.mutex:
            holding = tuple(sorted(
                m for m in instance.members
                if any(r is instance for r in registry.replicas(m))
            ))
            if not holding:
                return None
            if any(len(registry.replicas(m)) <= 1 for m in holding):
                return None
            removed = registry.remove_replicas(holding, instance)
            instance.begin_drain()
            epoch = registry.version
        freed = platform.retire_instance(instance)
        event = EpochEvent(
            epoch=epoch, kind="scale-in", names=removed, reason=reason,
            retired=(instance.instance_id,), freed_bytes=freed,
            t_completed=self.clock.now(),
        )
        return self._record(event)

    def _record(self, event: EpochEvent) -> EpochEvent:
        """Append to the epoch log and stamp the transition as an instant on
        the control-plane trace timeline — epoch swaps become visible next
        to the request traffic that triggered them."""
        with self._events_lock:
            self.events.append(event)
        tracer = getattr(self.platform, "tracer", None)
        if tracer is not None:
            tracer.control_event(
                f"epoch:{event.kind}", t=event.t_completed,
                args={"epoch": event.epoch, "names": list(event.names),
                      "reason": event.reason})
        return event

    # ----------------------------------------------------------- reconciler

    def enqueue(self, action: Callable[[], None], *, kind: str, names=(),
                reason: str = "", max_defer_s: float | None = None) -> None:
        """Queue a transition for the reconciler: it executes at the next
        observed traffic trough, or unconditionally once ``max_defer_s`` has
        elapsed — control-plane stalls land in quiet gaps when quiet gaps
        exist, and bounded-late otherwise."""
        defer = self.max_defer_s if max_defer_s is None else max_defer_s
        now = self.clock.now()
        item = _QueuedTransition(action, kind, tuple(names), reason, now, now + defer)
        with self._queue_lock:
            self._queue.append(item)
        self._ensure_thread()
        self._kick()

    def add_tick_hook(self, hook: Callable[[], None]) -> None:
        """Run ``hook`` on every reconciler tick (fission evaluation lives
        here — regret detection is control-plane work, never data-path)."""
        self._tick_hooks.append(hook)
        self._ensure_thread()

    def queued_transitions(self) -> int:
        with self._queue_lock:
            return len(self._queue)

    def is_trough(self) -> bool:
        scheduler = getattr(self.platform, "scheduler", None)
        if scheduler is None:
            return True
        return scheduler.is_trough(
            min_quiet_s=self.trough_quiet_s, gap_mult=self.trough_gap_mult
        )

    def run_pending(self, *, force: bool = False) -> int:
        """Execute queued transitions whose moment has come (trough observed
        or deadline passed; ``force=True`` runs everything now). Returns the
        number executed. The reconciler thread calls this each tick; tests
        and synchronous platforms may call it directly."""
        ran = 0
        while True:
            now = self.clock.now()
            with self._queue_lock:
                if not self._queue:
                    return ran
                head = self._queue[0]
                due = force or now >= head.deadline
                if not due:
                    # trough test outside this lock would race other pops;
                    # it is cheap (scheduler snapshot) so keep it inline
                    due = self.is_trough()
                if not due:
                    return ran
                self._queue.popleft()
                self._executing += 1
            try:
                # drain barrier before a deferred transition: wait (bounded)
                # for the affected functions' in-flight batches to clear so
                # the control-plane stall starts on a drained pipe — at a
                # trough this returns immediately, past the deadline it gives
                # up after drain_timeout_s rather than stall the transition
                scheduler = getattr(self.platform, "scheduler", None)
                if scheduler is not None and head.names:
                    scheduler.quiesce(
                        head.names, timeout=self.drain_timeout_s, include_queued=False
                    )
                head.action()
            except Exception:  # noqa: BLE001 — a failed transition must not
                pass  # kill the reconciler; the action logs its own outcome
            finally:
                with self._idle_cv:
                    self._executing -= 1
                    self._idle_cv.notify_all()
            ran += 1

    def wait_idle(self, timeout: float = 120.0) -> bool:
        """Block until no transition is queued OR executing (the reconciler
        may have popped one and be mid-build). Returns False on timeout."""
        deadline = self.clock.now() + timeout
        with self._idle_cv:
            while self._queue or self._executing:
                remaining = deadline - self.clock.now()
                if remaining <= 0:
                    return False
                self.clock.wait_on(self._idle_cv, min(remaining, 0.05))
        return True

    def _kick(self) -> None:
        with self._wake_cv:
            self._wake_flag = True
            self._wake_cv.notify_all()

    def _ensure_thread(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="lifecycle-reconciler"
        )
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._wake_cv:
                if not self._wake_flag:
                    self.clock.wait_on(self._wake_cv, self.tick_s)
                self._wake_flag = False
            if self._stop.is_set():
                return
            for hook in list(self._tick_hooks):
                try:
                    hook()
                except Exception:  # noqa: BLE001
                    pass
            self.run_pending()

    def shutdown(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._kick()
        th = self._thread
        if th is not None and th.is_alive():
            th.join(timeout)

    # -------------------------------------------------------------- metrics

    def stats(self) -> dict:
        with self._events_lock:
            events = list(self.events)[-32:]
        with self.registry.mutex:
            states = {
                inst.instance_id: inst.state.value
                for inst in self.registry.live_instances()
            }
        return {
            "epoch": self.epoch,
            "instance_states": states,
            "queued_transitions": self.queued_transitions(),
            "events": [dataclasses.asdict(e) for e in events],
        }
