"""Platform error types."""


class ProvuseError(Exception):
    """Base class for platform errors."""


class UnknownFunctionError(ProvuseError):
    pass


class DeploymentError(ProvuseError):
    pass


class HealthCheckError(ProvuseError):
    """Merged instance failed its canary health check — swap aborted."""


class InvocationError(ProvuseError):
    pass
