"""GB-second billing accounting — quantifies the *double billing* effect.

FaaS bills each function instance for wall-time x allocated memory, including
time the instance spends *blocked* on a synchronous downstream call
[Baldini et al., serverless trilemma]. The meter records every invocation's
(duration, resident_bytes, blocked_time); billed GB-s therefore double-counts
chains exactly like a real provider would — and the fusion benchmark's
before/after delta on this meter is the paper's cost-reduction claim.
"""
from __future__ import annotations

import dataclasses
import threading
import time


@dataclasses.dataclass
class ArenaLease:
    """One request's stay in the paged KV arena: the per-request RAM bill.

    With per-client cache pytrees every request was billed (implicitly) for
    a full ``max_len`` cache; under paging a request holds only the pages
    its tokens occupy, so its GB-s is ``pages x page_bytes x residency`` —
    the platform-side RAM reduction the paper claims, made billable."""

    function: str
    request_id: str
    pages: int          # peak pages held
    page_bytes: int     # bytes per page across the whole chain (all stages)
    t_alloc: float
    t_free: float
    # pages weighted by 1/refcount at release: a fleet sharing a prompt
    # prefix splits the prefix pages' bill across the sharers. None means
    # unshared serving — the nominal `pages` count is billed.
    amortized_pages: float | None = None

    @property
    def duration_s(self) -> float:
        return self.t_free - self.t_alloc

    @property
    def billed_pages(self) -> float:
        return float(self.pages) if self.amortized_pages is None else self.amortized_pages

    @property
    def gb_seconds(self) -> float:
        return self.duration_s * self.billed_pages * self.page_bytes / 1e9


@dataclasses.dataclass
class ProvisioningRecord:
    """One provisioning transition's bill. Restore/resurrect time IS billed
    (the function is being readied on a customer's invoke path); time spent
    idle as a snapshot is not billed at all — scale-to-zero's whole point —
    so parks and platform-initiated merges/splits carry ``billed=False`` and
    appear in the summary only as counts."""

    kind: str  # "resurrect" | "park" | "merge" | "split"
    functions: tuple[str, ...]
    seconds: float
    resident_bytes: int
    warm: bool
    billed: bool = False

    @property
    def gb_seconds(self) -> float:
        return self.seconds * self.resident_bytes / 1e9


@dataclasses.dataclass
class InvocationRecord:
    function: str
    instance: str
    t_start: float
    t_end: float
    resident_bytes: int
    blocked_s: float = 0.0
    # Requests co-batched into this execution. Each request in a micro-batch
    # gets its own record, but the instance was held ONCE for the batch
    # duration — so billed GB-s splits evenly across the co-batched requests
    # (summing the batch's records reproduces the instance's true cost).
    batch_size: int = 1

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    @property
    def gb_seconds(self) -> float:
        return self.duration_s * self.resident_bytes / 1e9 / max(1, self.batch_size)


class BillingMeter:
    GUARDED_FIELDS = {
        "records": "_lock",
        "arena_leases": "_lock",
        "provisioning": "_lock",
    }

    def __init__(self, clock=None):
        self._lock = threading.Lock()
        self.records: list[InvocationRecord] = []
        self.arena_leases: list[ArenaLease] = []
        self.provisioning: list[ProvisioningRecord] = []
        from repro.scheduler.metrics import LatencyWindow

        # the platform's time source: latency durations arrive already
        # measured, but the window stamps each completion to compute
        # sustained throughput — mixing a virtual duration with a wall-clock
        # stamp would put the two on different axes
        self._latency = LatencyWindow(clock=clock)

    def record(self, rec: InvocationRecord) -> None:
        with self._lock:
            self.records.append(rec)

    def record_arena(self, lease: ArenaLease) -> None:
        """One request left the paged KV arena; bill its page residency."""
        with self._lock:
            self.arena_leases.append(lease)

    def record_provisioning(self, rec: ProvisioningRecord) -> None:
        with self._lock:
            self.provisioning.append(rec)

    def observe_latency(self, function: str, seconds: float) -> None:
        """One *external* request completed end-to-end (admission/arrival ->
        response ready) after ``seconds``. Serial `invoke` and the scheduler's
        batched path both report here — and only client traffic does; the
        Merger's canary replays bypass this — so percentiles cover exactly
        the external request stream regardless of dispatch mode."""
        self._latency.observe(seconds)

    def reset(self) -> None:
        with self._lock:
            self.records = []
            self.arena_leases = []
            self.provisioning = []
        self._latency.reset()

    def arena_gb_seconds(self) -> float:
        with self._lock:
            return sum(l.gb_seconds for l in self.arena_leases)

    def arena_summary(self) -> dict:
        """Per-request page residency: the serve path's RAM story."""
        with self._lock:
            leases = list(self.arena_leases)
        if not leases:
            return {
                "requests": 0, "gb_s": 0.0, "mean_pages": 0.0, "max_pages": 0,
                "mean_billed_pages": 0.0,
            }
        return {
            "requests": len(leases),
            "gb_s": sum(l.gb_seconds for l in leases),
            "mean_pages": sum(l.pages for l in leases) / len(leases),
            "max_pages": max(l.pages for l in leases),
            # amortized by sharing: the RAM the platform ACTUALLY spent per
            # request (shared prefix pages counted once across the fleet)
            "mean_billed_pages": sum(l.billed_pages for l in leases) / len(leases),
            "mean_residency_s": sum(l.duration_s for l in leases) / len(leases),
        }

    def total_gb_seconds(self) -> float:
        with self._lock:
            return sum(r.gb_seconds for r in self.records)

    def blocked_gb_seconds(self) -> float:
        """The double-billed component: memory held while blocked downstream."""
        with self._lock:
            return sum(r.blocked_s * r.resident_bytes / 1e9 for r in self.records)

    def latency_summary(self) -> dict:
        """p50/p95/p99 of external request latency + sustained throughput."""
        return self._latency.snapshot()

    def by_instance(self) -> dict[str, dict]:
        """Billing split by the execution unit that actually served each
        request — the per-replica view behind ``platform.stats()['replicas']``.
        Each client request appears in exactly one instance's bucket (the
        replica the spread routed it to), so bucket call counts sum to the
        total client request count no matter how many replicas share a name;
        micro-batched requests already split their shared GB-s by batch."""
        with self._lock:
            records = list(self.records)
        return self._by_instance(records)

    @staticmethod
    def _by_instance(records: list[InvocationRecord]) -> dict[str, dict]:
        out: dict[str, dict] = {}
        for r in records:
            d = out.setdefault(r.instance, {"calls": 0, "gb_s": 0.0})
            d["calls"] += 1
            d["gb_s"] += r.gb_seconds
        return out

    def snapshot(self) -> dict:
        """One COHERENT view of the meter: records, leases, and provisioning
        are copied under a single lock acquisition, then every derived view
        (summary, per-instance split, arena, latency) is computed from that
        one copy. ``platform.stats()`` assembles from this, so its totals
        are conserved even while invokes land concurrently — summing the
        per-instance calls always equals summing the per-function calls
        (regression-tested in test_obs.py)."""
        with self._lock:
            records = list(self.records)
            leases = list(self.arena_leases)
            prov = list(self.provisioning)
        by_fn: dict[str, dict] = {}
        for r in records:
            d = by_fn.setdefault(r.function, {"calls": 0, "gb_s": 0.0, "blocked_gb_s": 0.0})
            d["calls"] += 1
            d["gb_s"] += r.gb_seconds
            d["blocked_gb_s"] += r.blocked_s * r.resident_bytes / 1e9
        billing = {
            "total_gb_s": sum(d["gb_s"] for d in by_fn.values()),
            "blocked_gb_s": sum(d["blocked_gb_s"] for d in by_fn.values()),
            "by_function": by_fn,
        }
        if leases:
            billing["arena"] = {
                "requests": len(leases),
                "gb_s": sum(l.gb_seconds for l in leases),
                "mean_pages": sum(l.pages for l in leases) / len(leases),
                "max_pages": max(l.pages for l in leases),
                "mean_billed_pages": sum(l.billed_pages for l in leases) / len(leases),
                "mean_residency_s": sum(l.duration_s for l in leases) / len(leases),
            }
        if prov:
            # a SEPARATE line item, not folded into total_gb_s: invocation
            # GB-s is the paper's double-billing comparison and must not
            # shift when provisioning accounting is enabled
            billing["provisioning"] = {
                "events": len(prov),
                "billed_gb_s": sum(p.gb_seconds for p in prov if p.billed),
                "billed_s": sum(p.seconds for p in prov if p.billed),
                "warm": sum(1 for p in prov if p.warm),
                "cold": sum(1 for p in prov if not p.warm),
            }
        return {
            "billing": billing,
            "by_instance": self._by_instance(records),
            "latency": self._latency.snapshot(),
        }

    def summary(self) -> dict:
        return self.snapshot()["billing"]


def now() -> float:
    return time.perf_counter()
