"""GB-second billing accounting — quantifies the *double billing* effect.

FaaS bills each function instance for wall-time x allocated memory, including
time the instance spends *blocked* on a synchronous downstream call
[Baldini et al., serverless trilemma]. The meter records every invocation's
(duration, resident_bytes, blocked_time); billed GB-s therefore double-counts
chains exactly like a real provider would — and the fusion benchmark's
before/after delta on this meter is the paper's cost-reduction claim.
"""
from __future__ import annotations

import dataclasses
import threading
import time


@dataclasses.dataclass
class InvocationRecord:
    function: str
    instance: str
    t_start: float
    t_end: float
    resident_bytes: int
    blocked_s: float = 0.0

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    @property
    def gb_seconds(self) -> float:
        return self.duration_s * self.resident_bytes / 1e9


class BillingMeter:
    def __init__(self):
        self._lock = threading.Lock()
        self.records: list[InvocationRecord] = []

    def record(self, rec: InvocationRecord) -> None:
        with self._lock:
            self.records.append(rec)

    def reset(self) -> None:
        with self._lock:
            self.records = []

    def total_gb_seconds(self) -> float:
        with self._lock:
            return sum(r.gb_seconds for r in self.records)

    def blocked_gb_seconds(self) -> float:
        """The double-billed component: memory held while blocked downstream."""
        with self._lock:
            return sum(r.blocked_s * r.resident_bytes / 1e9 for r in self.records)

    def summary(self) -> dict:
        with self._lock:
            by_fn: dict[str, dict] = {}
            for r in self.records:
                d = by_fn.setdefault(r.function, {"calls": 0, "gb_s": 0.0, "blocked_gb_s": 0.0})
                d["calls"] += 1
                d["gb_s"] += r.gb_seconds
                d["blocked_gb_s"] += r.blocked_s * r.resident_bytes / 1e9
            return {
                "total_gb_s": sum(d["gb_s"] for d in by_fn.values()),
                "blocked_gb_s": sum(d["blocked_gb_s"] for d in by_fn.values()),
                "by_function": by_fn,
            }


def now() -> float:
    return time.perf_counter()
