"""Pallas TPU kernels for the platform's compute hot spots.

flash_attention / decode_attention / paged_attention / ssd_scan / moe_gmm,
each with a pure-jnp oracle in ref.py and a jit'd dispatcher in ops.py
(kernel on TPU, oracle on CPU, interpret mode for validation).
paged_attention adds block-table indirection over the split-K decode
schedule (scalar-prefetched page ids) for the serving subsystem's shared
KV arena; its off-TPU fallback is one XLA gather + the dense oracle.
"""
