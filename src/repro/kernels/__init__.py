"""Pallas TPU kernels for the platform's compute hot spots.

flash_attention / decode_attention / ssd_scan / moe_gmm, each with a
pure-jnp oracle in ref.py and a jit'd dispatcher in ops.py (kernel on TPU,
oracle on CPU, interpret mode for validation).
"""
