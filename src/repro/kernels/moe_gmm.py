"""Per-expert grouped GEMM — Pallas TPU kernel (MegaBlocks-style dense
capacity buffers). [arXiv:2211.15841]

Computes out[e] = xe[e] @ w[e] for every expert with explicit VMEM tiling:
grid = (E, C/bc, F/bf, D/bd); the contraction axis is minor so the (bc, bf)
fp32 accumulator lives in scratch across the d sweep. Block sizes default to
MXU-native 128s; per-expert capacity C is already padded to a multiple of 8
by the MoE layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, num_d_blocks: int):
    idb = pl.program_id(3)

    @pl.when(idb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bc, bd)
    w = w_ref[0]  # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(idb == num_d_blocks - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def moe_gmm(
    xe: jax.Array,
    w: jax.Array,
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """xe: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    e, c, d = xe.shape
    f = w.shape[2]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    if c % block_c or f % block_f or d % block_d:
        raise ValueError(f"dims ({c},{f},{d}) must divide blocks ({block_c},{block_f},{block_d})")
    grid = (e, c // block_c, f // block_f, d // block_d)

    kernel = functools.partial(_gmm_kernel, num_d_blocks=d // block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda ie, ic, if_, id_: (ie, ic, id_)),
            pl.BlockSpec((1, block_d, block_f), lambda ie, ic, if_, id_: (ie, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda ie, ic, if_, id_: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), xe.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(xe, w)
