"""Causal GQA flash attention — Pallas TPU kernel.

Tiling: grid = (B, H, T/bq, S/bk). TPU executes the grid sequentially with
the last axis minor, so the kv index ``ik`` sweeps fully for each q block
``iq``; the online-softmax running state (m, l, acc) lives in VMEM scratch
and is carried across the ``ik`` sweep [FlashAttention, arXiv:2205.14135,
re-tiled for the MXU: bq = bk = 128 and head_dim-sized accumulators].

GQA: the BlockSpec index maps route q head ``h`` to kv head ``h // G`` —
grouped heads reuse the same K/V block stream (no replication in HBM).

Causality is handled two ways:
  * blocks fully above the diagonal contribute nothing — masked to -inf and
    skipped cheaply (their contribution to l is 0);
  * the diagonal block applies the per-element triangular mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal: bool, scale: float, block_q: int, block_k: int, num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]  # (bq, hd)
    k = k_ref[0, :, 0, :]  # (bk, hd)
    v = v_ref[0, :, 0, :]  # (bk, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])  # (bq, bk)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, T, H, hd); k, v: (B, S, KV, hd) -> (B, T, H, hd)."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_q = min(block_q, t)
    block_k = min(block_k, s)
    if t % block_q or s % block_k:
        raise ValueError(f"seq lengths ({t},{s}) must divide blocks ({block_q},{block_k})")
    grid = (b, h, t // block_q, s // block_k)
    scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=s // block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda ib, ih, iq, ik, g=g: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda ib, ih, iq, ik, g=g: (ib, ik, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m: running row max
            pltpu.VMEM((block_q,), jnp.float32),      # l: running row sum
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc: unnormalized output
        ],
        interpret=interpret,
    )(q, k, v)
