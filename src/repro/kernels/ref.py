"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each oracle is the mathematically transparent O(T^2)/dense formulation —
slow and memory-hungry by design. Kernel tests sweep shapes/dtypes and
assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q: (B, T, H, hd); k, v: (B, S, KV, hd) with H % KV == 0 -> (B, T, H, hd)."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, t, kv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k, preferred_element_type=jnp.float32) * scale
    if causal:
        qi = jnp.arange(t) + q_offset
        si = jnp.arange(s)
        mask = si[None, :] <= qi[:, None]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v)
    return out.reshape(b, t, h, hd)


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array, cur_len: jax.Array) -> jax.Array:
    """q: (B, H, hd); k, v: (B, S, KV, hd); cur_len: (B,) -> (B, H, hd)."""
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k, preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s)[None, :] < cur_len[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v.dtype), v)
    return out.reshape(b, h, hd)


def ssd_ref(x, bm, cm, dt, a_log, d_skip):
    """Naive O(T^2) SSD (exact dual form, no chunking).

    x: (B,T,H,P); bm/cm: (B,T,G,N); dt: (B,T,H) fp32; a_log, d_skip: (H,)
    -> (B,T,H,P) fp32 and final state (B,H,P,N) fp32."""
    b, t, h, p = x.shape
    grp = bm.shape[2]
    hpg = h // grp
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * a  # (B,T,H)
    cum = jnp.cumsum(dta, axis=1)
    # decay[i, j] = exp(cum_i - cum_j), i >= j
    li = cum[:, :, None, :] - cum[:, None, :, :]  # (B, Ti, Tj, H)
    iq = jnp.arange(t)
    causal = iq[:, None] >= iq[None, :]
    decay = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
    lmat = decay * dt.astype(jnp.float32)[:, None, :, :]  # (B,Ti,Tj,H)
    scores = jnp.einsum("bign,bjgn->bijg", cm.astype(jnp.float32), bm.astype(jnp.float32))
    scores = jnp.repeat(scores, hpg, axis=3) * lmat
    y = jnp.einsum("bijh,bjhp->bihp", scores, x.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    # final state
    w_j = jnp.exp(cum[:, -1:, :] - cum) * dt.astype(jnp.float32)  # (B,T,H)
    bh = jnp.repeat(bm, hpg, axis=2).astype(jnp.float32)  # (B,T,H,N)
    state = jnp.einsum("bthp,bthn->bhpn", x.astype(jnp.float32) * w_j[..., None], bh)
    return y, state


def gmm_ref(xe: jax.Array, w: jax.Array) -> jax.Array:
    """Per-expert GEMM. xe: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", xe, w, preferred_element_type=jnp.float32).astype(xe.dtype)
