"""Paged flash-decoding attention — block-table indirection over the
split-K decode schedule.

Same partial-softmax sweep as ``decode_attention.py`` (grid minor axis walks
the KV sequence, (m, l, acc) carried in VMEM scratch), but K/V live in a
shared page arena ``(num_pages, page, KV, hd)`` instead of a per-sequence
contiguous buffer: the kv-block index maps read the sequence's *block
table* — scalar-prefetched so the physical page id is known before the
kernel body runs and the DMA fetches exactly that page. Sequences of any
ragged length batch together; pages past ``cur_len`` are masked, and padded
block-table entries point at the arena's reserved scratch page (reads are
safe, contributions masked to zero).

``gather_pages`` is the non-TPU/interpret fallback shape: it reconstructs
the contiguous (B, S, KV, hd) view with one advanced-indexing gather, which
XLA fuses into the surrounding decode program (see
``models/attention.py: paged_decode_attention`` for the dispatch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """pages: (P, page, KV, hd); block_table: (B, n) -> (B, n*page, KV, hd).

    The contiguous-gather fallback: one XLA gather rebuilds each sequence's
    logical cache from its pages (garbage past cur_len — callers mask)."""
    b, n = block_table.shape
    _, page, kv, hd = pages.shape
    return pages[block_table].reshape(b, n * page, kv, hd)


def _paged_kernel(
    bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, page: int, num_page_blocks: int,
):
    ib, _, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :]  # (hd,)
    k = k_ref[0, :, 0, :]  # (page, hd)
    v = v_ref[0, :, 0, :]  # (page, hd)
    cur = len_ref[ib]

    s = jnp.einsum("kh,h->k", k.astype(jnp.float32), q.astype(jnp.float32)) * scale
    # logical position of this page's rows = page index * page + row
    cols = ik * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    s = jnp.where(cols < cur, s, NEG_INF)

    m_prev = m_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_cur)
    # explicit zero for masked positions: when EVERY score so far is masked
    # (cur_len 0 — a batcher's empty slot), m_cur is still NEG_INF and
    # exp(s - m_cur) would be 1 per position, making the output a mean of
    # scratch-page garbage; with the guard l stays 0 and _finalize emits
    # exact zeros, matching the "masked contributes nothing" contract
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_cur))
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    m_ref[0] = m_cur
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum(
        "k,kh->h", p, v.astype(jnp.float32)
    )[None, :]

    @pl.when(ik == num_page_blocks - 1)
    def _finalize():
        l = l_ref[0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    cur_len: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, hd); k_pages/v_pages: (P, page, KV, hd);
    block_table: (B, n_pages) int32; cur_len: (B,) -> (B, H, hd)."""
    b, h, hd = q.shape
    _, page, kv, _ = k_pages.shape
    n = block_table.shape[1]
    g = h // kv
    grid = (b, h, n)
    scale = 1.0 / (hd**0.5)

    kernel = functools.partial(_paged_kernel, scale=scale, page=page, num_page_blocks=n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, cur_len
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda ib, ih, ik, bt, ln: (ib, ih, 0)),
            # the indirection: physical page id comes from the prefetched table
            pl.BlockSpec((1, page, 1, hd), lambda ib, ih, ik, bt, ln, g=g: (bt[ib, ik], 0, ih // g, 0)),
            pl.BlockSpec((1, page, 1, hd), lambda ib, ih, ik, bt, ln, g=g: (bt[ib, ik], 0, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda ib, ih, ik, bt, ln: (ib, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), cur_len.astype(jnp.int32), q, k_pages, v_pages)


def _paged_chunk_kernel(
    bt_ref, start_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, page: int, num_page_blocks: int, chunk: int,
):
    """Chunked-prefill attention over the page arena: C query rows (one
    prefill chunk starting at absolute position ``start``) sweep the
    sequence's pages with the same online-softmax schedule as the decode
    kernel, carrying per-row (m, l, acc) in VMEM scratch. Row i masks
    columns past ``start + i`` (causal)."""
    ib, _, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]  # (C, hd)
    k = k_ref[0, :, 0, :]  # (page, hd)
    v = v_ref[0, :, 0, :]  # (page, hd)
    start = start_ref[ib]

    s = jnp.einsum(
        "th,kh->tk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # (C, page)
    cols = ik * page + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 1)
    rows = start + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 0)
    s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]  # (C,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    # same explicit-zero guard as the decode kernel: a fully-masked row must
    # contribute nothing, not a mean of scratch-page garbage
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_cur[:, None]))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.einsum(
        "tk,kh->th", p, v.astype(jnp.float32)
    )

    @pl.when(ik == num_page_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_chunk_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    start: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, C, H, hd); k_pages/v_pages: (P, page, KV, hd);
    block_table: (B, n) int32; start: (B,) absolute position of q[:, 0]
    -> (B, C, H, hd)."""
    b, c, h, hd = q.shape
    _, page, kv, _ = k_pages.shape
    n = block_table.shape[1]
    g = h // kv
    grid = (b, h, n)
    scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _paged_chunk_kernel, scale=scale, page=page, num_page_blocks=n, chunk=c
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, start
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, 1, hd), lambda ib, ih, ik, bt, st: (ib, 0, ih, 0)),
            pl.BlockSpec((1, page, 1, hd), lambda ib, ih, ik, bt, st, g=g: (bt[ib, ik], 0, ih // g, 0)),
            pl.BlockSpec((1, page, 1, hd), lambda ib, ih, ik, bt, st, g=g: (bt[ib, ik], 0, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, hd), lambda ib, ih, ik, bt, st: (ib, 0, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((c,), jnp.float32),
            pltpu.VMEM((c,), jnp.float32),
            pltpu.VMEM((c, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), start.astype(jnp.int32), q, k_pages, v_pages)
