"""jit'd dispatch wrappers: Pallas kernel on TPU, jnp oracle elsewhere.

The models call these entry points; the CPU container (tests, dry-run
lowering) takes the ref path, a real TPU deployment takes the kernel path.
``REPRO_USE_PALLAS=1`` forces kernels (with ``interpret=True`` off-TPU — used
by the kernel benchmarks).
"""
from __future__ import annotations

import os

import jax

from repro.analysis.dispatch import TRACER
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.moe_gmm import moe_gmm as _gmm_kernel
from repro.kernels.paged_attention import gather_pages
from repro.kernels.paged_attention import paged_chunk_attention as _paged_chunk_kernel
from repro.kernels.paged_attention import paged_decode_attention as _paged_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def _mode() -> str:
    """'kernel' | 'interpret' | 'ref'."""
    forced = os.environ.get("REPRO_USE_PALLAS", "")
    if jax.default_backend() == "tpu":
        return "ref" if forced == "0" else "kernel"
    if forced == "1":
        return "interpret"
    return "ref"


def dispatch_mode() -> str:
    """Public view of the kernel dispatch mode: 'kernel' | 'interpret' |
    'ref'. Part of every executable-cache key (``launch/compile_cache``):
    the same entry lowers to a different program per mode, so a mode flip
    must miss the cache rather than reuse a stale lowering."""
    return _mode()


def _aligned(*dims_and_blocks: tuple[int, int]) -> bool:
    return all(d % b == 0 for d, b in dims_and_blocks)


def attention(q, k, v, *, causal: bool = True):
    TRACER.note_kernel_call("attention", q)
    mode = _mode()
    if mode != "ref" and _aligned((q.shape[1], 128), (k.shape[1], 128)):
        return _flash_kernel(q, k, v, causal=causal, interpret=(mode == "interpret"))
    return ref.mha_ref(q, k, v, causal=causal)


def decode_attention(q, k, v, cur_len):
    TRACER.note_kernel_call("decode_attention", q)
    mode = _mode()
    if mode != "ref" and _aligned((k.shape[1], 512)):
        return _decode_kernel(q, k, v, cur_len, interpret=(mode == "interpret"))
    return ref.decode_attn_ref(q, k, v, cur_len)


def paged_decode_attention(q, k_pages, v_pages, block_table, cur_len):
    """q: (B, H, hd); pages (P, page, KV, hd); block_table (B, n) int32.

    Kernel/interpret mode runs the block-table-indirect split-K kernel; the
    ref path gathers pages contiguous (one XLA gather, fused into the
    surrounding program) and reuses the dense decode oracle — bit-identical
    to a dense cache of the same gathered width."""
    TRACER.note_kernel_call("paged_decode_attention", q)
    mode = _mode()
    if mode != "ref" and _aligned((k_pages.shape[1], 128)):
        return _paged_kernel(q, k_pages, v_pages, block_table, cur_len,
                             interpret=(mode == "interpret"))
    k = gather_pages(k_pages, block_table)
    v = gather_pages(v_pages, block_table)
    return ref.decode_attn_ref(q, k, v, cur_len)


def paged_chunk_attention(q, k_pages, v_pages, block_table, start):
    """q: (B, C, H, hd); chunked-prefill attention over the page arena.

    Returns the kernel result in kernel/interpret mode, or None when the
    shapes don't fit the kernel tiling / ref mode is active — the caller
    (``models/attention.py: paged_chunk_attention``) then runs the bit-exact
    gather + q-chunked fallback."""
    TRACER.note_kernel_call("paged_chunk_attention", q)
    mode = _mode()
    if mode != "ref" and _aligned((k_pages.shape[1], 128), (q.shape[1], 8)):
        return _paged_chunk_kernel(q, k_pages, v_pages, block_table, start,
                                   interpret=(mode == "interpret"))
    return None


def ssd(x, bm, cm, dt, a_log, d_skip, *, chunk: int = 256):
    TRACER.note_kernel_call("ssd", x)
    mode = _mode()
    if mode != "ref" and _aligned((x.shape[1], chunk)):
        return _ssd_kernel(x, bm, cm, dt, a_log, d_skip, chunk=chunk, interpret=(mode == "interpret"))
    y, _ = ref.ssd_ref(x, bm, cm, dt, a_log, d_skip)
    return y.astype(x.dtype)


def gmm(xe, w):
    TRACER.note_kernel_call("gmm", xe)
    mode = _mode()
    e, c, d = xe.shape
    f = w.shape[2]
    if mode != "ref" and _aligned((c, 128), (d, 128), (f, 128)):
        return _gmm_kernel(xe, w, interpret=(mode == "interpret"))
    return ref.gmm_ref(xe, w)
