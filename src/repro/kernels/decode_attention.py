"""Flash-decoding attention — Pallas TPU kernel for the serve path.

One query token per sequence against a long KV cache. Tiling:
grid = (B, H, S/bk); the kv sweep is the minor axis, so the partial-softmax
state (m, l, acc) is carried in VMEM scratch across kv blocks — the split-K
decode schedule of FlashDecoding [arXiv:2311.01282] mapped onto the TPU's
sequential grid. Valid-length masking comes from the per-sequence
``cur_len`` vector (continuous batching: each request has its own length).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float, block_k: int, num_k_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :]  # (hd,)
    k = k_ref[0, :, 0, :]  # (bk, hd)
    v = v_ref[0, :, 0, :]  # (bk, hd)
    cur = len_ref[0]

    s = jnp.einsum("kh,h->k", k.astype(jnp.float32), q.astype(jnp.float32)) * scale  # (bk,)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
    s = jnp.where(cols < cur, s, NEG_INF)

    m_prev = m_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)  # (bk,)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    m_ref[0] = m_cur
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum(
        "k,kh->h", p, v.astype(jnp.float32)
    )[None, :]

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cur_len: jax.Array,
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, hd); k, v: (B, S, KV, hd); cur_len: (B,) -> (B, H, hd)."""
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_k = min(block_k, s)
    if s % block_k:
        raise ValueError(f"S={s} must divide block_k={block_k}")
    grid = (b, h, s // block_k)
    scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, num_k_blocks=s // block_k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda ib, ih, ik: (ib, ih, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda ib, ih, ik, g=g: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda ib, ih, ik, g=g: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda ib, ih, ik: (ib, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, cur_len)
