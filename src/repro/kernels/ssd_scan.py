"""Mamba-2 SSD chunked scan — Pallas TPU kernel. [arXiv:2405.21060]

Tiling: grid = (B, H, T/Q). The chunk axis is minor, so the inter-chunk
recurrent state (P, N) is carried in VMEM scratch across chunks of one
(batch, head) stream. Per chunk the kernel does the SSD dual form:
three (Q x Q)/(Q x N)/(Q x P) MXU matmuls for the intra-chunk part, one
rank-Q update for the state — this is the TPU-native re-blocking of the
CUDA chunk kernel in the paper (VMEM-resident decay matrices; chunk Q is
chosen 128-multiple so every matmul hits the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref, d_ref, y_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))
    d_skip = d_ref[0].astype(jnp.float32)

    dta = dt * a
    cum = jnp.cumsum(dta)  # (Q,)
    li = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(li), 0.0)
    lmat = decay * dt[None, :]

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    scores = scores * lmat  # (Q, Q)
    y_intra = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    state = state_ref[...]  # (P, N)
    y_inter = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, None]  # (Q, P)

    y = y_intra + y_inter + x * d_skip
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    total = cum[chunk - 1]
    w_j = jnp.exp(total - cum) * dt  # (Q,)
    ds = jax.lax.dot_general(
        (x * w_j[:, None]), bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    state_ref[...] = state * jnp.exp(total) + ds


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    bm: jax.Array,
    cm: jax.Array,
    dt: jax.Array,
    a_log: jax.Array,
    d_skip: jax.Array,
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: (B,T,H,P); bm/cm: (B,T,G,N); dt: (B,T,H); a_log/d_skip: (H,)
    -> y: (B,T,H,P) (fp32 accumulated, cast to x.dtype)."""
    b, t, h, p = x.shape
    grp, n = bm.shape[2], bm.shape[3]
    hpg = h // grp
    chunk = min(chunk, t)
    if t % chunk:
        raise ValueError(f"T={t} must divide chunk={chunk}")
    grid = (b, h, t // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic, hpg=hpg: (ib, ic, ih // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic, hpg=hpg: (ib, ic, ih // hpg, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, bm, cm, dt, a_log, d_skip)
