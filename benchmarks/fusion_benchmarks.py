"""Paper-fidelity benchmarks: {TREE, IOT} x {tinyjax, orchestrated} x
{vanilla, fusion} at a constant request rate.

Mirrors §5 of the paper:
  * Fig. 5 — end-to-end latency time series with merge-event markers
  * Fig. 6 — median end-to-end latency across the four configurations
  * RAM table — resident platform memory before/after fusion
  * Billing table — GB-s incl. the double-billed (blocked) component

Writes results/fusion_benchmarks.json and returns summary rows.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.apps import APPS, make_request
from repro.core import FusionPolicy, OrchestratedBackend, TinyJaxBackend

BACKENDS = {"tinyjax": TinyJaxBackend, "orchestrated": OrchestratedBackend}


def run_app(app: str, backend: str, fusion: bool, *, requests: int = 150, rate_hz: float = 5.0, warmup: int = 3) -> dict:
    policy = FusionPolicy(min_observations=3, merge_cost_s=0.0, enabled=fusion)
    platform = BACKENDS[backend](policy)
    try:
        entry = APPS[app](platform)
        x = make_request(0)
        for i in range(warmup):  # cold-start compiles excluded, as in Fig. 5
            platform.invoke(entry, make_request(i))
        platform.meter.reset()
        ram_start = platform.ram_bytes()

        period = 1.0 / rate_hz
        t0 = time.perf_counter()
        series = []
        for i in range(requests):
            target = t0 + i * period
            now = time.perf_counter()
            if now < target:
                time.sleep(target - now)
            s = time.perf_counter()
            platform.invoke(entry, make_request(i))
            e = time.perf_counter()
            series.append({"t": s - t0, "latency_ms": (e - s) * 1e3})
        platform.merger.wait_idle()
        ram_end = platform.ram_bytes()
        merges = [
            {"t": m.t_completed - t0, "members": list(m.members), "freed_bytes": m.freed_bytes, "build_s": m.build_s}
            for m in platform.merger.merge_log
            if m.healthy
        ]
        lat = np.array([p["latency_ms"] for p in series])
        post = lat[len(lat) // 2 :]  # steady-state window (paper reports run medians)
        billing = platform.meter.summary()
        return {
            "app": app,
            "backend": backend,
            "fusion": fusion,
            "median_ms": float(np.median(lat)),
            "median_ms_steady": float(np.median(post)),
            "p95_ms": float(np.percentile(lat, 95)),
            "ram_start": ram_start,
            "ram_end": ram_end,
            "merges": merges,
            "gb_s": billing["total_gb_s"],
            "blocked_gb_s": billing["blocked_gb_s"],
            "series": series,
        }
    finally:
        platform.shutdown()


def run_all(requests: int = 150, rate_hz: float = 5.0) -> dict:
    results = []
    for app in ("TREE", "IOT"):
        for backend in ("tinyjax", "orchestrated"):
            vanilla = run_app(app, backend, fusion=False, requests=requests, rate_hz=rate_hz)
            fused = run_app(app, backend, fusion=True, requests=requests, rate_hz=rate_hz)
            results.append({"vanilla": vanilla, "fusion": fused})
    summary = []
    for pair in results:
        v, f = pair["vanilla"], pair["fusion"]
        lat_red = 100.0 * (1 - f["median_ms_steady"] / v["median_ms_steady"])
        ram_red = 100.0 * (1 - f["ram_end"] / max(1, v["ram_end"]))
        bill_red = 100.0 * (1 - f["gb_s"] / max(1e-12, v["gb_s"]))
        summary.append(
            {
                "app": v["app"],
                "backend": v["backend"],
                "vanilla_median_ms": round(v["median_ms_steady"], 2),
                "fusion_median_ms": round(f["median_ms_steady"], 2),
                "latency_reduction_pct": round(lat_red, 1),
                "vanilla_ram_mb": round(v["ram_end"] / 1e6, 2),
                "fusion_ram_mb": round(f["ram_end"] / 1e6, 2),
                "ram_reduction_pct": round(ram_red, 1),
                "billing_reduction_pct": round(bill_red, 1),
                "vanilla_blocked_gb_s": round(v["blocked_gb_s"], 6),
                "fusion_blocked_gb_s": round(f["blocked_gb_s"], 6),
                "merges": len(f["merges"]),
            }
        )
    mean_lat = float(np.mean([s["latency_reduction_pct"] for s in summary]))
    mean_ram = float(np.mean([s["ram_reduction_pct"] for s in summary]))
    out = {
        "summary": summary,
        "mean_latency_reduction_pct": round(mean_lat, 2),
        "mean_ram_reduction_pct": round(mean_ram, 2),
        "paper_claims": {"latency_reduction_pct": 26.33, "ram_reduction_pct": 53.57},
        "detail": results,
    }
    os.makedirs("results", exist_ok=True)
    with open("results/fusion_benchmarks.json", "w") as fjson:
        json.dump(out, fjson, indent=2)
    return out
