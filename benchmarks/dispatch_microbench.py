"""Microbenchmark: cost of one function boundary (the overhead Provuse
removes). A -> B identity-chain invoked unfused (interpreter glue + platform
dispatch) vs fused (single compiled program)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FunctionSpec, FusionPolicy, TinyJaxBackend


def run(iters: int = 200) -> dict:
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.05

    def fn_b(ctx, p, x):
        return x @ p

    def fn_a(ctx, p, x):
        return ctx.call("micro/B", x @ p)

    def bench(fusion: bool) -> float:
        platform = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0, enabled=fusion))
        try:
            platform.deploy(FunctionSpec("micro/A", fn_a, w, trust_domain="m"))
            platform.deploy(FunctionSpec("micro/B", fn_b, w, trust_domain="m"))
            x = jnp.ones((4, 64))
            for _ in range(10):
                platform.invoke("micro/A", x)  # warm + trigger fusion if enabled
            t0 = time.perf_counter()
            for _ in range(iters):
                platform.invoke("micro/A", x)
            return (time.perf_counter() - t0) / iters * 1e6
        finally:
            platform.shutdown()

    unfused_us = bench(False)
    fused_us = bench(True)
    return {
        "unfused_us_per_call": round(unfused_us, 1),
        "fused_us_per_call": round(fused_us, 1),
        "boundary_overhead_us": round(unfused_us - fused_us, 1),
    }
