"""The paper's technique in roofline terms: UNFUSED (vanilla) function-chain
serving vs the Provuse-FUSED single program, for one decode cell.

Vanilla deployment = the model served as independent functions (embed ->
block-group_0..G-1 -> head), each its own compiled XLA program: we lower
every stage separately and sum the roofline terms. The fused deployment is
the monolithic decode program (same numbers as the dry-run grid cell).

The unfused chain pays (per token):
  * boundary I/O — every stage writes its residual-stream output to HBM and
    the next reads it back, and XLA cannot fuse across the boundary;
  * G+1 extra program launches (host dispatch, ~30 us each on TPU hosts);
and exactly this is what the platform's runtime fusion removes — the FaaS
double-billing chain, in compiled-program form.

  PYTHONPATH=src python -m benchmarks.provuse_roofline --arch llama3.2-1b --shape decode_32k
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json

DISPATCH_US = 30.0  # typical TPU host launch latency per extra program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.configs import get_arch, get_shape
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as tfm
    from repro.models.layers import apply_norm, embed_tokens, unembed
    from repro.models.model import build_model
    from repro.models.params import param_structs
    from repro.sharding.specs import decode_rules, to_pspec

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    if shape.kind != "decode":
        raise SystemExit("provuse_roofline quantifies the decode chain; use --shape decode_32k")
    mesh = make_production_mesh()
    rules = decode_rules(mesh, kv_heads=cfg.num_kv_heads or None, batch=shape.global_batch)
    model = build_model(cfg, rules)
    kind = "moe" if cfg.family == "moe" else "dense"
    L = cfg.num_layers
    g = cfg.num_function_groups
    while L % g:
        g -= 1
    per = L // g

    HW = {"c": 197e12, "m": 819e9, "i": 50e9}

    def terms_of(compiled):
        s = analyze(compiled.as_text())
        return {
            "compute_s": s.flops / HW["c"],
            "memory_s": s.bytes / HW["m"],
            "collective_s": s.collective_bytes / HW["i"],
        }

    with mesh:
        p_structs = param_structs(model.param_defs, mesh, rules)
        in_structs = param_structs(model.input_defs(shape), mesh, rules)
        cache_structs = param_structs(model.cache_defs(shape), mesh, rules)

        # ---------- fused (Provuse-converged) ----------
        fused = jax.jit(model.decode_fn, donate_argnums=2).lower(p_structs, in_structs, cache_structs).compile()
        fused_terms = terms_of(fused)

        # ---------- unfused chain: per-stage programs ----------
        b = shape.global_batch
        hid = jax.ShapeDtypeStruct(
            (b, 1, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, to_pspec((b, 1, cfg.d_model), ("batch", None, None), rules, strict=True)),
        )
        stage_terms = []

        def embed_stage(emb, batch):
            return embed_tokens(emb, batch["tokens"])

        c = jax.jit(embed_stage).lower(p_structs["embed"], in_structs).compile()
        stage_terms.append(terms_of(c))

        def slice_tree(tree, lo, hi):
            def one(x):
                if isinstance(x, jax.ShapeDtypeStruct):
                    return jax.ShapeDtypeStruct((hi - lo, *x.shape[1:]), x.dtype, sharding=x.sharding)
                return x[lo:hi]

            return jax.tree.map(one, tree)

        for i in range(g):
            blk_structs = slice_tree(p_structs["blocks"], i * per, (i + 1) * per)
            cache_slice = slice_tree(cache_structs, i * per, (i + 1) * per)

            def group_stage(blk, x, cache, cur_len, _kind=kind):
                return tfm.apply_stack_decode(blk, x, cache, cfg, _kind, rules, cur_len)[:2]

            c = jax.jit(group_stage, donate_argnums=2).lower(
                blk_structs, hid, cache_slice, in_structs["cur_len"]
            ).compile()
            stage_terms.append(terms_of(c))

        def head_stage(params, x):
            h = apply_norm(params["ln_f"], x, cfg)
            return unembed(params["embed"], h)[:, 0]

        c = jax.jit(head_stage).lower({"ln_f": p_structs["ln_f"], "embed": p_structs["embed"]}, hid).compile()
        stage_terms.append(terms_of(c))

    unfused = {k: sum(t[k] for t in stage_terms) for k in stage_terms[0]}
    boundaries = len(stage_terms) - 1
    dispatch_s = (len(stage_terms)) * DISPATCH_US / 1e6

    def bound(t):
        return max(t.values())

    out = {
        "arch": args.arch,
        "shape": args.shape,
        "stages": len(stage_terms),
        "fused": {k: round(v, 6) for k, v in fused_terms.items()},
        "fused_bound_s": round(bound(fused_terms), 6),
        "unfused_sum": {k: round(v, 6) for k, v in unfused.items()},
        "unfused_dispatch_s": round(dispatch_s, 6),
        "unfused_bound_s": round(bound(unfused) + dispatch_s, 6),
        "fusion_speedup": round((bound(unfused) + dispatch_s) / bound(fused_terms), 3),
        "boundary_memory_delta_s": round(unfused["memory_s"] - fused_terms["memory_s"], 6),
    }
    print(json.dumps(out, indent=2))
    os.makedirs("results", exist_ok=True)
    with open("results/provuse_roofline.json", "w") as f:
        json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
