"""Benchmark driver — one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV (one row per measured quantity).
Full structured outputs land in results/*.json.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --quick     # shorter runs
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-fusion", action="store_true", help="skip the (slow) paper-fidelity runs")
    args = ap.parse_args()

    rows: list[tuple[str, float, str]] = []

    # --- dispatch-boundary microbench (paper §1 motivation) ---
    from benchmarks.dispatch_microbench import run as micro_run

    micro = micro_run(iters=100 if args.quick else 200)
    rows.append(("dispatch_unfused", micro["unfused_us_per_call"], "us/call through 1 boundary"))
    rows.append(("dispatch_fused", micro["fused_us_per_call"], "us/call same chain fused"))
    rows.append(("boundary_overhead", micro["boundary_overhead_us"], "us eliminated per boundary"))

    # --- kernel reference timings ---
    from benchmarks.kernel_bench import run as kernels_run

    for r in kernels_run():
        rows.append((r["name"], r["us_per_call"], "jnp oracle on host CPU"))

    # --- paper Figs 5/6 + RAM + billing: {TREE, IOT} x {2 backends} ---
    if not args.skip_fusion:
        from benchmarks.fusion_benchmarks import run_all

        fus = run_all(requests=60 if args.quick else 150, rate_hz=5.0)
        for s in fus["summary"]:
            tag = f"{s['app']}_{s['backend']}"
            rows.append((f"{tag}_vanilla_median", s["vanilla_median_ms"] * 1e3, "us median E2E latency"))
            rows.append((f"{tag}_fusion_median", s["fusion_median_ms"] * 1e3, "us median E2E latency"))
            rows.append((f"{tag}_latency_reduction", s["latency_reduction_pct"], "% (paper: 26.33% avg)"))
            rows.append((f"{tag}_ram_reduction", s["ram_reduction_pct"], "% (paper: 53.57% avg)"))
            rows.append((f"{tag}_billing_reduction", s["billing_reduction_pct"], "% GB-s incl. double billing"))
        rows.append(("mean_latency_reduction", fus["mean_latency_reduction_pct"], "% across all 4 configs (paper: 26.33)"))
        rows.append(("mean_ram_reduction", fus["mean_ram_reduction_pct"], "% across all 4 configs (paper: 53.57)"))

    # --- roofline summary from the dry-run grid ---
    from benchmarks.roofline import load, summary

    dr = load()
    if dr:
        s = summary(dr)
        rows.append(("dryrun_cells_ok", s["cells_ok"], f"compiled cells (skipped={s['cells_skipped']}, failed={s['cells_failed']})"))
        rows.append(("dryrun_fits_16gb", s["fits_16gb"], "cells within 16GB/chip"))
        for term, n in sorted(s["dominant_terms"].items()):
            rows.append((f"dominant_{term}", n, "cells bound by this roofline term"))
    else:
        print("# note: results/dryrun.jsonl not found — run `python -m repro.launch.dryrun --all`", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
