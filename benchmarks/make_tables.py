"""Regenerate the §Dry-run/§Roofline tables in EXPERIMENTS.md from
results/dryrun.jsonl (idempotent: replaces the marked block)."""
from __future__ import annotations

import json

from benchmarks.roofline import load, summary

BEGIN = "<!-- ROOFLINE-TABLE-BEGIN -->"
END = "<!-- ROOFLINE-TABLE-END -->"


def full_table(rows, mesh):
    out = [
        "",
        f"### Mesh {mesh}",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | HBM GB (cpu) | HBM GB (tpu est) | fits | 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: {r.get('reason','')[:58]} | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r.get('status')} | — | — | — | — | — |")
            continue
        rf = r["roofline"]
        fits = "Y" if r["fits_16gb"] else ("Y*" if r.get("fits_16gb_tpu_est") else "N")
        m = r.get("memory", {})
        floor_gb = (m.get("argument_bytes", 0) + m.get("output_bytes", 0)) / 2**30
        if isinstance(r.get("hbm_tpu_estimate_gb"), (int, float)):
            r["hbm_tpu_estimate_gb"] = round(max(r["hbm_tpu_estimate_gb"], floor_gb), 3)
        frac = rf["compute_s"] / rf["bound_s"] if rf["bound_s"] else 0
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} | {rf['memory_s']:.4g} | "
            f"{rf['collective_s']:.4g} | {rf['dominant'].replace('_s','')} | {r['hbm_per_device_gb']} | "
            f"{r.get('hbm_tpu_estimate_gb','—')} | {fits} | {round(r['useful_flops_ratio'],3)} | {frac:.4f} |"
        )
    return out


def main():
    rows = load()
    s = summary(rows)
    lines = [
        BEGIN,
        "",
        f"Grid status: **{s['cells_ok']} compiled OK, {s['cells_skipped']} skipped by design, "
        f"{s['cells_failed']} failed** across 40 cells x 2 meshes. "
        f"{s['fits_16gb']}/{s['cells_ok']} fit 16 GB/chip under conservative CPU accounting "
        "(`Y*` = fits under the TPU estimate; see memory accounting note above). "
        f"Dominant terms: {s['dominant_terms']}.",
        "",
    ]
    lines += full_table(rows, "pod16x16")
    lines += ["", "Multi-pod (2x16x16 = 512 chips) — proves the 'pod' axis shards; same table:"]
    lines += full_table(rows, "pod2x16x16")
    lines += ["", END]

    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    block = "\n".join(lines)
    if BEGIN in doc:
        pre = doc.split(BEGIN)[0]
        post = doc.split(END)[1]
        doc = pre + block + post
    else:
        marker = "*(full 40-cell table inserted after the final grid — results/dryrun.jsonl)*"
        doc = doc.replace(marker, block)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print(f"tables written: {s}")


if __name__ == "__main__":
    main()
