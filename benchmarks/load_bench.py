"""Open/closed-loop load generator for the Provuse request scheduler.

Drives concurrent decode traffic through a ServingEngine chain and measures
throughput + tail latency under four regimes: {unfused, fused} x {serial
`invoke`, micro-batched `invoke_async`}. The headline comparison (fused
chain, batched vs serial dispatch at --concurrency 8) is the scheduler's
reason to exist: the paper's fusion makes one request faster; the scheduler
makes the fused unit serve many at once.

Closed loop (default): C client threads, each with its own KV cache, decode
as fast as responses return for --steps iterations.
Open loop (--rate R): a single generator submits `invoke_async` arrivals at
R req/s (uniform spacing) for --duration seconds and waits for completions —
latency then includes queueing behind the instance, the classic
open-vs-closed distinction.

Usage:
    PYTHONPATH=src python benchmarks/load_bench.py --concurrency 8
    PYTHONPATH=src python benchmarks/load_bench.py --concurrency 8 --backend orchestrated
    PYTHONPATH=src python benchmarks/load_bench.py --rate 200 --duration 5 --modes fused-batched
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.core import FusionPolicy, OrchestratedBackend, TinyJaxBackend
from repro.models.model import build_model
from repro.scheduler import percentiles_ms
from repro.serving.engine import ServingEngine

BACKENDS = {"tinyjax": TinyJaxBackend, "orchestrated": OrchestratedBackend}
MODES = ("unfused-serial", "unfused-batched", "fused-serial", "fused-batched")


def build_engine(args, fused: bool):
    cfg = reduced_config(get_arch(args.arch))
    model = build_model(cfg)
    policy = FusionPolicy(min_observations=2, merge_cost_s=0.0, enabled=fused)
    platform = BACKENDS[args.backend](
        policy, max_batch=args.max_batch or args.concurrency, max_delay_ms=args.max_delay_ms
    )
    engine = ServingEngine(model, platform, max_len=args.max_len)
    return engine, platform


def warm(engine, steps: int = 6):
    """Trigger observation->fusion (when enabled) and all compiles."""
    tokens = jnp.ones((1, 4), jnp.int32)
    engine.generate({"tokens": tokens}, steps=steps)
    engine.platform.merger.wait_idle()


class Client:
    """One closed-loop stream: prefill once, then decode step after step.

    The next-token choice is elided (a constant token is fed every step):
    token identity changes neither shapes nor decode cost, and per-step
    argmax/host-roundtrip in N GIL-sharing client threads would measure the
    load generator, not the platform under test. Caches and cur_len advance
    normally, so every step is a real full decode."""

    def __init__(self, engine, cid: int, prompt_len: int):
        self.engine = engine
        tokens = jnp.full((1, prompt_len), 1 + cid % 17, jnp.int32)
        _, self.caches, cur_len = engine.prefill({"tokens": tokens})
        # host-side step counter: numpy += 1 is ~1000x cheaper than a JAX
        # dispatch, and N client threads share one GIL
        self.cur_len = np.asarray(cur_len)
        self.tokens = jnp.full((1, 1), 1 + cid % 17, jnp.int32)
        self.latencies: list[float] = []

    def step_serial(self):
        t0 = time.perf_counter()
        _, self.caches = self.engine.decode_step(self.tokens, self.cur_len, self.caches)
        self.latencies.append(time.perf_counter() - t0)
        self.cur_len = self.cur_len + 1

    def step_batched(self):
        t0 = time.perf_counter()
        fut = self.engine.decode_step_async(self.tokens, self.cur_len, self.caches)
        _, self.caches = fut.result()
        self.latencies.append(time.perf_counter() - t0)
        self.cur_len = self.cur_len + 1


def run_closed_loop(args, mode: str) -> dict:
    fused = mode.startswith("fused")
    batched = mode.endswith("batched")
    engine, platform = build_engine(args, fused)
    try:
        warm(engine)
        clients = [Client(engine, i, args.prompt_len) for i in range(args.concurrency)]
        # per-mode warmup: compile the batched buckets before the timed window
        barrier = threading.Barrier(args.concurrency)

        def drive(client: Client, steps: int):
            barrier.wait()
            for _ in range(steps):
                client.step_batched() if batched else client.step_serial()

        for phase_steps, timed in ((args.warmup_steps, False), (args.steps, True)):
            for c in clients:
                c.latencies.clear()
            threads = [
                threading.Thread(target=drive, args=(c, phase_steps), daemon=True)
                for c in clients
            ]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            elapsed = time.perf_counter() - t0
        total = args.steps * args.concurrency
        lats = [l for c in clients for l in c.latencies]
        out = {
            "mode": mode,
            "loop": "closed",
            "requests": total,
            "elapsed_s": round(elapsed, 3),
            "throughput_rps": round(total / elapsed, 2),
            **{k: round(v, 3) for k, v in percentiles_ms(lats).items()},
            "scheduler": platform.scheduler.stats() if batched else None,
        }
        return out
    finally:
        platform.shutdown()


def run_open_loop(args, mode: str) -> dict:
    fused = mode.startswith("fused")
    engine, platform = build_engine(args, fused)
    try:
        warm(engine)
        clients = [Client(engine, i, args.prompt_len) for i in range(args.concurrency)]
        # warm the batch buckets so open-loop timing excludes compiles
        futs = [engine.decode_step_async(c.tokens, c.cur_len, c.caches) for c in clients]
        for f in futs:
            f.result()
        interval = 1.0 / args.rate
        deadline = time.perf_counter() + args.duration
        pending = []
        lats: list[float] = []
        lats_lock = threading.Lock()

        def stamp_completion(t_submit):
            # done-callbacks fire ON completion, so latency includes queueing
            # behind the instance but NOT time spent waiting in a drain loop
            def cb(fut):
                dt = time.perf_counter() - t_submit
                with lats_lock:
                    lats.append(dt)
            return cb

        i = 0
        t_next = time.perf_counter()
        t0 = time.perf_counter()
        while time.perf_counter() < deadline:
            now = time.perf_counter()
            if now < t_next:
                time.sleep(min(t_next - now, interval))
                continue
            t_next += interval
            c = clients[i % len(clients)]
            i += 1
            # open loop: fire-and-record, do not wait for the response
            fut = engine.decode_step_async(c.tokens, c.cur_len, c.caches)
            fut.add_done_callback(stamp_completion(time.perf_counter()))
            pending.append(fut)
        for fut in pending:
            fut.result()
        elapsed = time.perf_counter() - t0
        return {
            "mode": mode,
            "loop": "open",
            "offered_rps": args.rate,
            "requests": len(pending),
            "elapsed_s": round(elapsed, 3),
            "throughput_rps": round(len(pending) / elapsed, 2),
            **{k: round(v, 3) for k, v in percentiles_ms(lats).items()},
            "scheduler": platform.scheduler.stats(),
        }
    finally:
        platform.shutdown()


def main():
    ap = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--backend", default="tinyjax", choices=sorted(BACKENDS))
    ap.add_argument("--concurrency", type=int, default=8, help="closed-loop clients / open-loop streams")
    ap.add_argument("--steps", type=int, default=48, help="timed decode steps per closed-loop client")
    ap.add_argument("--warmup-steps", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-batch", type=int, default=0, help="0 = match --concurrency")
    ap.add_argument("--max-delay-ms", type=float, default=4.0, help="micro-batch window")
    ap.add_argument("--rate", type=float, default=0.0, help=">0 switches to open loop at this req/s")
    ap.add_argument("--duration", type=float, default=5.0, help="open-loop run time (s)")
    ap.add_argument("--modes", nargs="*", default=["fused-serial", "fused-batched"], choices=MODES)
    ap.add_argument("--json", action="store_true", help="emit machine-readable results")
    args = ap.parse_args()

    results = []
    for mode in args.modes:
        if args.rate > 0:
            if mode.endswith("serial"):
                # open loop submits without waiting — that IS the scheduled
                # path; a "serial" open-loop row would silently measure the
                # same thing under a different label
                print(f"[{mode:>16}] skipped: open loop (--rate) only supports *-batched modes")
                continue
            res = run_open_loop(args, mode)
        else:
            res = run_closed_loop(args, mode)
        results.append(res)
        if not args.json:
            sched = res.pop("scheduler", None)
            print(f"[{res['mode']:>16}] {res['throughput_rps']:8.1f} req/s   "
                  f"p50 {res['p50_ms']:7.1f} ms   p95 {res['p95_ms']:7.1f} ms   "
                  f"p99 {res['p99_ms']:7.1f} ms   ({res['requests']} reqs in {res['elapsed_s']}s)")
            if sched:
                print(f"{'':18}mean batch {sched['mean_batch']:.2f}, max {sched['max_batch_seen']}, "
                      f"{sched['batches']} batches")

    by_mode = {r["mode"]: r for r in results}
    if "fused-serial" in by_mode and "fused-batched" in by_mode:
        speedup = by_mode["fused-batched"]["throughput_rps"] / max(by_mode["fused-serial"]["throughput_rps"], 1e-9)
        if args.json:
            for r in results:
                r.pop("scheduler", None)
            print(json.dumps({"results": results, "batched_vs_serial_speedup": round(speedup, 2)}, indent=2))
        else:
            print(f"\nbatched vs serial (fused chain): {speedup:.2f}x throughput")
    elif args.json:
        print(json.dumps({"results": results}, indent=2))


if __name__ == "__main__":
    main()
