"""Open/closed-loop load generator for the Provuse request scheduler.

Drives concurrent decode traffic through a ServingEngine chain and measures
throughput + tail latency under four regimes: {unfused, fused} x {serial
`invoke`, micro-batched `invoke_async`}. The headline comparison (fused
chain, batched vs serial dispatch at --concurrency 8) is the scheduler's
reason to exist: the paper's fusion makes one request faster; the scheduler
makes the fused unit serve many at once.

Closed loop (default): C client threads, each with its own KV cache, decode
as fast as responses return for --steps iterations.
Open loop (--rate R): a single generator submits `invoke_async` arrivals at
R req/s for --duration seconds and waits for completions — latency then
includes queueing behind the instance, the classic open-vs-closed
distinction. --pattern shapes the arrivals: `uniform` spacing, `bursty`
(back-to-back groups of --burst with --intra-gap-ms inside a burst), or
`trickle` (synonym for uniform at a rate whose gap exceeds any batching
window — the worst case for a static window).

--adaptive runs the feedback-window comparison: the bursty and trickle
scenarios each execute twice — static window (--max-delay-ms, the PR 1
behavior) vs adaptive (same initial window, per-key retuning) — and the
occupancy / tail-latency deltas are printed. --smoke is the CI gate: a tiny
closed-loop run that fails loudly when coalescing stops working.

Usage:
    PYTHONPATH=src python benchmarks/load_bench.py --concurrency 8
    PYTHONPATH=src python benchmarks/load_bench.py --concurrency 8 --backend orchestrated
    PYTHONPATH=src python benchmarks/load_bench.py --rate 200 --duration 5 --modes fused-batched
    PYTHONPATH=src python benchmarks/load_bench.py --adaptive
    PYTHONPATH=src python benchmarks/load_bench.py --coldstart
    PYTHONPATH=src python benchmarks/load_bench.py --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from concurrent.futures import TimeoutError as FuturesTimeout

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dispatch import TRACER
from repro.configs import get_arch, reduced_config
from repro.core import FusionPolicy, OrchestratedBackend, TinyJaxBackend
from repro.models.model import build_model
from repro.scheduler import percentiles_ms
from repro.serving.continuous import ContinuousBatcher
from repro.serving.engine import ServingEngine

BACKENDS = {"tinyjax": TinyJaxBackend, "orchestrated": OrchestratedBackend}
MODES = ("unfused-serial", "unfused-batched", "fused-serial", "fused-batched")


def build_engine(args, fused: bool, adaptive: bool = False, kv_pages: int = 0,
                 tracing: bool = True):
    cfg = reduced_config(get_arch(args.arch))
    model = build_model(cfg)
    policy = FusionPolicy(min_observations=2, merge_cost_s=0.0, enabled=fused)
    platform = BACKENDS[args.backend](
        policy, max_batch=args.max_batch or args.concurrency, max_delay_ms=args.max_delay_ms,
        adaptive=adaptive, tracing=tracing,
    )
    engine = ServingEngine(model, platform, max_len=args.max_len,
                           kv_pages=kv_pages, kv_page_size=args.page_size)
    return engine, platform


def warm(engine, steps: int = 6):
    """Trigger observation->fusion (when enabled) and all compiles."""
    tokens = jnp.ones((1, 4), jnp.int32)
    engine.generate({"tokens": tokens}, steps=steps)
    engine.platform.merger.wait_idle()


class Client:
    """One closed-loop stream: prefill once, then decode step after step.

    The next-token choice is elided (a constant token is fed every step):
    token identity changes neither shapes nor decode cost, and per-step
    argmax/host-roundtrip in N GIL-sharing client threads would measure the
    load generator, not the platform under test. Caches and cur_len advance
    normally, so every step is a real full decode."""

    def __init__(self, engine, cid: int, prompt_len: int):
        self.engine = engine
        tokens = jnp.full((1, prompt_len), 1 + cid % 17, jnp.int32)
        _, self.caches, cur_len = engine.prefill({"tokens": tokens})
        # host-side step counter: numpy += 1 is ~1000x cheaper than a JAX
        # dispatch, and N client threads share one GIL
        self.cur_len = np.asarray(cur_len)
        self.tokens = jnp.full((1, 1), 1 + cid % 17, jnp.int32)
        self.latencies: list[float] = []

    def step_serial(self):
        t0 = time.perf_counter()
        _, self.caches = self.engine.decode_step(self.tokens, self.cur_len, self.caches)
        self.latencies.append(time.perf_counter() - t0)
        self.cur_len = self.cur_len + 1

    def step_batched(self):
        t0 = time.perf_counter()
        fut = self.engine.decode_step_async(self.tokens, self.cur_len, self.caches)
        _, self.caches = fut.result()
        self.latencies.append(time.perf_counter() - t0)
        self.cur_len = self.cur_len + 1


def run_closed_loop(args, mode: str, tracing: bool = True) -> dict:
    fused = mode.startswith("fused")
    batched = mode.endswith("batched")
    engine, platform = build_engine(args, fused, tracing=tracing)
    try:
        warm(engine)
        clients = [Client(engine, i, args.prompt_len) for i in range(args.concurrency)]
        # per-mode warmup: compile the batched buckets before the timed window
        barrier = threading.Barrier(args.concurrency)

        def drive(client: Client, steps: int):
            barrier.wait()
            for _ in range(steps):
                client.step_batched() if batched else client.step_serial()

        for phase_steps, timed in ((args.warmup_steps, False), (args.steps, True)):
            for c in clients:
                c.latencies.clear()
            threads = [
                threading.Thread(target=drive, args=(c, phase_steps), daemon=True)
                for c in clients
            ]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            elapsed = time.perf_counter() - t0
        total = args.steps * args.concurrency
        lats = [l for c in clients for l in c.latencies]
        out = {
            "mode": mode,
            "loop": "closed",
            "requests": total,
            "elapsed_s": round(elapsed, 3),
            "throughput_rps": round(total / elapsed, 2),
            **{k: round(v, 3) for k, v in percentiles_ms(lats).items()},
            "scheduler": platform.scheduler.stats() if batched else None,
        }
        return out
    finally:
        platform.shutdown()


def arrival_offsets(args):
    """Submit offsets (seconds from start) for one open-loop run. `uniform`
    and `trickle` space arrivals at 1/rate; `bursty` fires back-to-back
    groups of --burst (spaced --intra-gap-ms inside the group) with the
    same long-run rate."""
    if args.pattern == "bursty":
        burst = max(1, args.burst)
        interval = burst / args.rate
        gap = args.intra_gap_ms / 1e3
        t = 0.0
        while t < args.duration:
            for j in range(burst):
                yield t + j * gap
            t += interval
    else:
        interval = 1.0 / args.rate
        t = 0.0
        while t < args.duration:
            yield t
            t += interval


def run_open_loop(args, mode: str, adaptive: bool = False) -> dict:
    fused = mode.startswith("fused")
    engine, platform = build_engine(args, fused, adaptive=adaptive)
    try:
        warm(engine)
        clients = [Client(engine, i, args.prompt_len) for i in range(args.concurrency)]
        # warm the batch buckets so open-loop timing excludes compiles, then
        # drop the warmup from the stats and the controllers' learned state —
        # the measured occupancy/tails/windows must reflect measured traffic
        futs = [engine.decode_step_async(c.tokens, c.cur_len, c.caches) for c in clients]
        for f in futs:
            f.result()
        platform.scheduler.reset_stats()
        pending = []
        lats: list[float] = []
        lats_lock = threading.Lock()

        def stamp_completion(t_submit):
            # done-callbacks fire ON completion, so latency includes queueing
            # behind the instance but NOT time spent waiting in a drain loop
            def cb(fut):
                dt = time.perf_counter() - t_submit
                with lats_lock:
                    lats.append(dt)
            return cb

        t0 = time.perf_counter()
        for i, off in enumerate(arrival_offsets(args)):
            now = time.perf_counter()
            if now < t0 + off:
                time.sleep(t0 + off - now)
            c = clients[i % len(clients)]
            # open loop: fire-and-record, do not wait for the response
            fut = engine.decode_step_async(c.tokens, c.cur_len, c.caches)
            fut.add_done_callback(stamp_completion(time.perf_counter()))
            pending.append(fut)
        for fut in pending:
            fut.result()
        elapsed = time.perf_counter() - t0
        # fut.result() returns before that future's done-callbacks are
        # guaranteed to have run — join on the counter so the percentile
        # snapshot isn't short a few tail samples
        join_deadline = time.perf_counter() + 5.0
        while time.perf_counter() < join_deadline:
            with lats_lock:
                if len(lats) >= len(pending):
                    break
            time.sleep(0.001)
        sched = platform.scheduler.stats()
        max_batch = platform.scheduler.max_batch
        return {
            "mode": mode,
            "loop": "open",
            "pattern": args.pattern,
            "window": "adaptive" if adaptive else "static",
            "offered_rps": args.rate,
            "requests": len(pending),
            "elapsed_s": round(elapsed, 3),
            "throughput_rps": round(len(pending) / elapsed, 2),
            **{k: round(v, 3) for k, v in percentiles_ms(lats).items()},
            "mean_batch": round(sched["mean_batch"], 3),
            "occupancy": round(sched["mean_batch"] / max_batch, 3),
            "scheduler": sched,
        }
    finally:
        platform.shutdown()


def run_adaptive_compare(args) -> dict:
    """The feedback-window demonstration: bursty and trickle arrivals, each
    served with the static --max-delay-ms window and with adaptive retuning
    seeded at the same value. The win a single static window cannot have
    both ways: on bursts the adaptive window grows (occupancy up at equal or
    better tails), on trickle it decays to ~0 (no queueing tax on lone
    requests)."""
    import copy

    scenarios = {
        "bursty": dict(pattern="bursty", rate=args.rate, burst=args.burst,
                       intra_gap_ms=args.intra_gap_ms),
        "trickle": dict(pattern="trickle", rate=args.trickle_rate, burst=1,
                        intra_gap_ms=0.0),
    }
    out: dict = {}
    for scen, overrides in scenarios.items():
        for label, adaptive in (("static", False), ("adaptive", True)):
            a = copy.copy(args)
            for k, v in overrides.items():
                setattr(a, k, v)
            res = run_open_loop(a, "fused-batched", adaptive=adaptive)
            out[f"{scen}/{label}"] = res
            print(f"[{scen:>7}/{label:<8}] occupancy {res['occupancy']:.2f} "
                  f"(mean batch {res['mean_batch']:.2f})   p50 {res['p50_ms']:7.1f} ms   "
                  f"p95 {res['p95_ms']:7.1f} ms   ({res['requests']} reqs)")
    b_s, b_a = out["bursty/static"], out["bursty/adaptive"]
    t_s, t_a = out["trickle/static"], out["trickle/adaptive"]
    summary = {
        "bursty_occupancy_static": b_s["occupancy"],
        "bursty_occupancy_adaptive": b_a["occupancy"],
        "bursty_p95_static_ms": b_s["p95_ms"],
        "bursty_p95_adaptive_ms": b_a["p95_ms"],
        "trickle_p50_static_ms": t_s["p50_ms"],
        "trickle_p50_adaptive_ms": t_a["p50_ms"],
        "trickle_added_ms": round(t_a["p50_ms"] - max(t_s["p50_ms"] - args.max_delay_ms, 0.0), 3),
    }
    print(f"\nbursty : occupancy {b_s['occupancy']:.2f} -> {b_a['occupancy']:.2f}   "
          f"p95 {b_s['p95_ms']:.1f} -> {b_a['p95_ms']:.1f} ms")
    print(f"trickle: p50 {t_s['p50_ms']:.1f} -> {t_a['p50_ms']:.1f} ms "
          f"(static window was {args.max_delay_ms:.1f} ms; adaptive decays it to ~0)")
    out["summary"] = summary
    return out


def run_churn(args, *, smoke: bool = False) -> dict:
    """Fission demonstration: a phase-shift workload on the orchestrated
    backend (each execution unit = one pod).

    Phase 1 — a hot synchronous chain H -> L (serial traffic): the platform
    observes the blocking edge and fuses {H, L} into one unit (the merge is
    queued on the reconciler and lands in the post-phase trough).
    Phase 2 — traffic turns concurrent and *direct*: heavy open-loop H
    arrivals oversubscribe the single fused pod while light L arrivals
    starve behind its FIFO. The scheduler's signals (occupancy ~1, queue
    depth) feed FusionPolicy.decide_split, the control plane executes the
    fission epoch, and L's delivered throughput recovers on its own pod.

    Asserts (hard): the merge AND the split both happened, with the regret
    reason recorded; every submitted request resolved (zero dropped or hung
    futures across all epoch transitions). The recovery ratio is printed
    always and enforced only in the full (non-smoke) run.
    """
    from repro.core import FunctionSpec

    duration = 2.5 if smoke else max(4.0, args.duration)
    rate_l = 100.0
    # Two-stage host calibration so the scenario saturates at ANY host
    # speed without outrunning the single-thread submit loop: first size H's
    # compute (fori_loop iteration count — constant compile cost) so one
    # batch-of-4 costs ~80ms on THIS host, then derive H's offered rate from
    # the fused pod's measured capacity (1.4x oversubscription, below).
    wh = jnp.asarray(np.random.RandomState(0).randn(256, 256).astype(np.float32) * 0.05)
    wl = jnp.asarray(np.random.RandomState(1).randn(256, 256).astype(np.float32) * 0.05)
    probe_iters, target_batch_s = 200, 0.080
    probe = jax.jit(
        lambda v: jax.lax.fori_loop(0, probe_iters, lambda i, h: jnp.tanh(h @ wh), v)
    )
    xb = jnp.ones((4, 8, 256), jnp.float32)
    probe(xb).block_until_ready()  # compile
    trials = []
    for _ in range(3):  # best-of-3: contention only ever ADDS time
        t_p = time.perf_counter()
        probe(xb).block_until_ready()
        trials.append(time.perf_counter() - t_p)
    probe_s = max(min(trials), 1e-4)
    heavy_iters = max(100, int(probe_iters * target_batch_s / probe_s))

    # Saturation here is depth-dominant: the oversubscribed pod's queue
    # grows without bound, while mean occupancy blends H's full batches
    # with L's pre-starvation singletons (~0.33 at phase-2 onset) — an
    # occupancy-heavy threshold would make the trigger timing bimodal.
    # min_group_age_s also gives the starvation ~a second to become visible
    # so the measured recovery reflects a real collapse, not an early exit.
    policy = FusionPolicy(
        min_observations=2, merge_cost_s=0.0,
        split_occupancy=0.3, split_depth=10, split_sustain=3,
        min_group_age_s=0.5, remerge_backoff_s=300.0,
    )
    platform = BACKENDS["orchestrated"](
        policy, max_batch=4, max_delay_ms=2.0, adaptive=True,
        fission=True, fission_interval_s=0.1, trough_merges=True, max_defer_s=1.0,
    )

    def fn_h(ctx, params, x):
        h = jax.lax.fori_loop(0, heavy_iters, lambda i, v: jnp.tanh(v @ params), x)
        return ctx.call("L", h)

    def fn_l(ctx, params, x):
        return jnp.tanh(x @ params)

    try:
        platform.deploy(FunctionSpec("H", fn_h, wh))
        platform.deploy(FunctionSpec("L", fn_l, wl))
        x = jnp.ones((8, 256), jnp.float32)

        # --- phase 1: hot sync chain -> fuse (reconciler lands it in the trough)
        for _ in range(4):
            platform.invoke("H", x)
        platform.merger.wait_idle()
        merges = [m for m in platform.merger.merge_log if m.healthy]
        assert merges and set(merges[-1].members) == {"H", "L"}, "phase 1 must fuse {H, L}"

        # warm the fused unit's batch buckets so phase 2 measures traffic, not
        # compiles, then measure one warm batch to size the overload
        for name in ("H", "L"):
            futs = [platform.invoke_async(name, x) for _ in range(4)]
            for f in futs:
                f.result()
        walls = []
        for _ in range(3):  # best-of-3: an overestimated batch cost would
            t_m = time.perf_counter()  # undersize rate_h and never saturate
            futs = [platform.invoke_async("H", x) for _ in range(4)]
            for f in futs:
                f.result()
            walls.append(time.perf_counter() - t_m)
        capacity_rps = 4.0 / max(min(walls), 1e-3)
        # heavy_iters calibration pins capacity near 50 rps, so this stays
        # far below what the submit loop can offer; 300 is a sanity clamp,
        # not a working bound (a binding cap would defeat the saturation)
        rate_h = min(300.0, max(20.0, 1.6 * capacity_rps))
        platform.scheduler.reset_stats()

        # --- phase 2: concurrent direct traffic; H oversubscribes the fused pod
        done: list[tuple[str, float]] = []
        done_lock = threading.Lock()
        failures: list[BaseException] = []

        def stamp(name):
            def cb(fut):
                exc = fut.exception()
                t = time.perf_counter()
                with done_lock:
                    if exc is not None:
                        failures.append(exc)
                    else:
                        done.append((name, t))
            return cb

        pending = []
        t0 = time.perf_counter()
        next_h, next_l = 0.0, 0.0
        # Offer traffic until ~1.5s past the observed split (bounded), so the
        # post-split recovery window always exists — a split landing near the
        # end of a fixed window would leave nothing to measure and flake CI.
        hard_cap = duration + 4.0
        split_seen_at: float | None = None
        while True:
            now = time.perf_counter() - t0
            if split_seen_at is None and any(s.healthy for s in platform.merger.split_log):
                split_seen_at = now
            if now >= hard_cap:
                break
            if split_seen_at is not None and now >= max(duration, split_seen_at + 1.5):
                break
            if now >= next_h:
                fut = platform.invoke_async("H", x)
                fut.add_done_callback(stamp("H"))
                pending.append(fut)
                next_h += 1.0 / rate_h
            if now >= next_l:
                fut = platform.invoke_async("L", x)
                fut.add_done_callback(stamp("L"))
                pending.append(fut)
                next_l += 1.0 / rate_l
            time.sleep(max(0.0, min(next_h, next_l) - (time.perf_counter() - t0)))
        t_submit_end = time.perf_counter()

        hung = 0
        # ONE shared drain budget: a real hang regression must fail fast
        # with the churn diagnostic, not serialize a fresh timeout per
        # stranded future until the CI job itself is killed
        wait_deadline = time.perf_counter() + 120.0
        for fut in pending:
            try:
                fut.result(timeout=max(0.0, wait_deadline - time.perf_counter()))
            except FuturesTimeout:
                hung += 1
            except Exception:
                pass  # already counted via the done-callback
        # done-callbacks fire after result() returns; join on the counter
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with done_lock:
                if len(done) + len(failures) >= len(pending):
                    break
            time.sleep(0.001)

        splits = [s for s in platform.merger.split_log if s.healthy]
        stats = platform.stats()
        assert splits, "phase 2 must split the saturated fused group"
        split_t = splits[0].t_completed
        assert not failures, f"requests failed across epoch transitions: {failures[:3]}"
        assert hung == 0, f"{hung} requests hung across epoch transitions"

        # L's delivered throughput: starved behind the fused pod's FIFO
        # before the split, back at its offered rate after it
        settle = 0.5  # post-split compile/settling excluded from the rate
        l_pre = [t for (n, t) in done if n == "L" and t0 <= t < split_t]
        l_post = [t for (n, t) in done if n == "L" and split_t + settle <= t <= t_submit_end]
        pre_rate = len(l_pre) / max(split_t - t0, 1e-9)
        post_span = max(t_submit_end - (split_t + settle), 1e-9)
        post_rate = len(l_post) / post_span
        # floor the denominator at 1 req/s: total pre-split starvation
        # (pre_rate 0) is the strongest possible recovery, not a 1e11x ratio
        recovery = post_rate / max(pre_rate, 1.0)
        out = {
            "mode": "churn",
            "requests": len(pending),
            "failed": len(failures),
            "hung": hung,
            "merge_epoch": merges[-1].epoch,
            "split_epoch": splits[0].epoch,
            "split_reason": splits[0].reason,
            "epoch": stats["lifecycle"]["epoch"],
            "l_rate_pre_split": round(pre_rate, 1),
            "l_rate_post_split": round(post_rate, 1),
            "recovery": round(recovery, 2),
        }
        print(f"[churn] merge @epoch {out['merge_epoch']} -> split @epoch {out['split_epoch']} "
              f"({out['split_reason']})")
        print(f"[churn] L throughput {pre_rate:.1f} -> {post_rate:.1f} req/s "
              f"({recovery:.2f}x recovery), {len(pending)} requests, "
              f"0 failed, 0 hung, final epoch {out['epoch']} "
              f"(H offered {rate_h:.0f} rps vs ~{capacity_rps:.0f} rps capacity)")
        assert split_t < t_submit_end, "split must land while traffic is still offered"
        # the smoke floor is loose (shared CI boxes); the full run is a demo
        # and must show a real recovery
        assert recovery >= (1.2 if smoke else 1.3), (
            f"fission must recover the starved member's throughput (got {recovery:.2f}x)"
        )
        return out
    finally:
        platform.shutdown()


def run_coldstart(args, *, smoke: bool = False) -> dict:
    """Restore-not-rebuild gate: warm provisioning must beat cold builds.

    Scenario A — warm churn. One platform fuses a hot H -> L chain, splits
    it, and re-fuses it, ``cycles`` times. Cycle 1 pays the cold compiles;
    every later cycle must be served ENTIRELY from the executable index:
    the dispatch tracer is armed from cycle 2 and asserts zero backend
    compiles, and the warm merges' build time must beat the cold one.

    Scenario B — resurrect-from-zero. A standalone function is deployed
    cold (first invoke pays trace + XLA compile), then parked via
    ``scale_to_zero`` (params snapshotted, routes dropped) and invoked
    again: the resurrect restores the snapshot, hits the executable index,
    and must produce a bit-identical answer with zero compiles, >=Nx
    faster than the cold start.

    Both ratios are enforced: >=3x in smoke (shared 2-core CI boxes),
    >=5x in the full run — the PR's headline claim.
    """
    import tempfile

    from repro.core import FunctionSpec
    from repro.launch.compile_cache import EXECUTABLE_INDEX

    cycles = 3 if smoke else 5
    floor = 3.0 if smoke else 5.0
    EXECUTABLE_INDEX.clear()

    # --- scenario A: merge -> split -> re-merge churn --------------------
    rs = np.random.RandomState(0)
    wh = jnp.asarray(rs.randn(256, 256).astype(np.float32) * 0.05)
    wl = jnp.asarray(rs.randn(256, 256).astype(np.float32) * 0.05)
    policy = FusionPolicy(min_observations=2, merge_cost_s=0.0,
                          min_group_age_s=0.0, remerge_backoff_s=0.0)
    platform = BACKENDS["tinyjax"](policy)

    def fn_h(ctx, params, x):
        h = jnp.tanh(x @ params)
        return ctx.call("L", h)

    def fn_l(ctx, params, x):
        return jnp.tanh(x @ params)

    armed = False
    try:
        platform.deploy(FunctionSpec("H", fn_h, wh))
        platform.deploy(FunctionSpec("L", fn_l, wl))
        x = jnp.ones((8, 256), jnp.float32)
        base = TRACER.snapshot()
        for cycle in range(cycles):
            for _ in range(4):
                platform.invoke("H", x)
            platform.merger.wait_idle()
            merges = [m for m in platform.merger.merge_log if m.healthy]
            assert len(merges) == cycle + 1, (
                f"cycle {cycle}: expected {cycle + 1} merges, saw {len(merges)}"
            )
            ev = platform.merger.split(
                frozenset({"H", "L"}), [{"H"}, {"L"}], reason="coldstart churn"
            )
            assert ev is not None and ev.healthy, f"cycle {cycle}: split failed"
            if cycle == 0:
                # everything this loop will ever build is now compiled and
                # indexed — from here on, churn must restore, not rebuild
                base = TRACER.snapshot()
                TRACER.arm()
                armed = True
        churn_delta = TRACER.delta(base)
        TRACER.disarm()
        armed = False

        merges = [m for m in platform.merger.merge_log if m.healthy]
        splits = [s for s in platform.merger.split_log if s.healthy]
        assert len(merges) == cycles and len(splits) == cycles
        assert all(m.warm for m in merges[1:]), (
            f"re-merges must be index-served: {[m.warm for m in merges]}"
        )
        assert all(s.warm for s in splits[1:]), (
            f"re-splits must be index-served: {[s.warm for s in splits]}"
        )
        assert churn_delta.compiles == 0, (
            f"steady-state churn recompiled {churn_delta.compiles} programs"
        )
        cold_build = merges[0].build_s
        warm_builds = [m.build_s for m in merges[1:]]
        churn_ratio = cold_build / max(sum(warm_builds) / len(warm_builds), 1e-9)
        cstats = platform.provisioning_stats()["compile_cache"]
    finally:
        if armed:
            TRACER.disarm()
        platform.shutdown()

    # --- scenario B: park (scale-to-zero) -> resurrect -------------------
    snapdir = tempfile.mkdtemp(prefix="coldstart_snap_")
    platform2 = BACKENDS["tinyjax"](
        FusionPolicy(enabled=False), snapshot_dir=snapdir
    )

    def leaf_fn(ctx, params, x):
        h = x
        for w in params["ws"]:  # unrolled: XLA compile cost scales with depth
            h = jnp.tanh(h @ w)
        return h

    rs = np.random.RandomState(7)
    ws = tuple(jnp.asarray(rs.randn(192, 192).astype(np.float32) * 0.05)
               for _ in range(8))
    armed = False
    try:
        platform2.deploy(FunctionSpec("leaf", leaf_fn, {"ws": ws}))
        x2 = jnp.asarray(rs.randn(4, 192).astype(np.float32))
        t0 = time.perf_counter()
        r_cold = np.asarray(platform2.invoke("leaf", x2))
        t_cold = time.perf_counter() - t0

        r_ref = np.asarray(platform2.invoke("leaf", x2))
        assert np.array_equal(r_cold, r_ref)
        parked = platform2.scale_to_zero("leaf")
        assert parked == ("leaf",), f"park failed: {parked!r}"
        assert platform2.provisioning_stats()["parked"] == ["leaf"]

        base = TRACER.snapshot()
        TRACER.arm()
        armed = True
        t0 = time.perf_counter()
        r_warm = np.asarray(platform2.invoke("leaf", x2))
        t_warm = time.perf_counter() - t0
        rez_delta = TRACER.delta(base)
        TRACER.disarm()
        armed = False

        assert rez_delta.compiles == 0, (
            f"resurrect recompiled {rez_delta.compiles} programs"
        )
        assert np.array_equal(r_warm, r_ref), "resurrected output must be bit-identical"
        rez_ratio = t_cold / max(t_warm, 1e-9)
        billing = platform2.meter.summary().get("provisioning", {})
    finally:
        if armed:
            TRACER.disarm()
        platform2.shutdown()

    out = {
        "mode": "coldstart",
        "cycles": cycles,
        "churn_cold_build_s": round(cold_build, 4),
        "churn_warm_build_s": round(sum(warm_builds) / len(warm_builds), 4),
        "churn_ratio": round(churn_ratio, 1),
        "steady_state_compiles": churn_delta.compiles,
        "executable_cache": cstats,
        "resurrect_cold_s": round(t_cold, 4),
        "resurrect_warm_s": round(t_warm, 4),
        "resurrect_ratio": round(rez_ratio, 1),
        "resurrect_compiles": rez_delta.compiles,
        "billing_provisioning": billing,
    }
    print(f"[coldstart] churn: cold build {cold_build * 1e3:.1f} ms, warm "
          f"{out['churn_warm_build_s'] * 1e3:.1f} ms ({churn_ratio:.1f}x), "
          f"{churn_delta.compiles} steady-state compiles over {cycles - 1} warm cycles")
    print(f"[coldstart] resurrect: cold start {t_cold * 1e3:.1f} ms, "
          f"resurrect {t_warm * 1e3:.1f} ms ({rez_ratio:.1f}x), "
          f"{rez_delta.compiles} compiles, bit-identical output")
    assert churn_ratio >= floor, (
        f"warm re-merge must be >={floor}x faster than cold (got {churn_ratio:.1f}x)"
    )
    assert rez_ratio >= floor, (
        f"resurrect must be >={floor}x faster than cold start (got {rez_ratio:.1f}x)"
    )
    return out


def run_coldstart_smoke(args) -> int:
    """CI gate for warm provisioning; one retry (same policy as the other
    smokes — timing ratios can flake on shared boxes, counter assertions
    cannot, and a real regression fails both attempts)."""
    try:
        run_coldstart(args, smoke=True)
        return 0
    except AssertionError:
        print("[coldstart-smoke] attempt 1 flaked; retrying once")
        try:
            run_coldstart(args, smoke=True)
            return 0
        except AssertionError as exc:
            print(f"[coldstart-smoke] FAIL: {exc}")
            return 1


def run_replicas(args, *, smoke: bool = False) -> dict:
    """Replicated-data-plane gate: rho-driven autoscaling must recover the
    throughput a single hot instance caps, on hot-skewed load at fixed
    concurrency.

    The hot function models the I/O-bound FaaS handler replication exists
    for: eager local compute, a fixed host-side wait (the downstream RPC
    most real handlers block on), then a boundary ``ctx.call`` to the
    downstream function — so the entry runs on the platform's eager glue
    path, per request, on its pod's own thread. The wait releases the GIL,
    so replica pods overlap their waits — the speedup is a property of the
    data plane, not of how many cores the CI box happens to have — while
    the single-instance baseline serializes every request through one
    pod's FIFO. (A compiled-program sleep via ``pure_callback`` would NOT
    show this: XLA host callbacks share one runtime thread on small boxes,
    serializing the waits platform-wide.)

    Run A (baseline): one instance, no autoscaler. Run B: the same offered
    load with ``autoscale_config`` — the scheduler's predicted rho crosses
    the threshold, the autoscaler spins replicas out through the warm
    provisioning path, and least-outstanding spread fans the lanes across
    the set. Asserted:

    * autoscaled throughput >= 1.5x the single-instance baseline;
    * the strict class meets the SAME fixed p95 target in BOTH runs
      (replication must not cost conformance);
    * every scale-out provisioning record is warm, and the dispatch tracer
      (armed from the end of run B's warmup) sees ZERO compiles — replica
      spin-up restores from the executable index, never rebuilds;
    * spread picks land on >= 2 replicas (the set actually shares load).
    """
    from repro.core import FunctionSpec
    from repro.scheduler.slo import SLOClass

    from repro.scheduler.adaptive import AdaptiveConfig

    duration = 2.0 if smoke else max(4.0, args.duration)
    ramp = 1.5  # run B: unmeasured window for the autoscaler to act in
    io_wait_s = 0.005  # the simulated downstream RPC — host-independent
    max_batch = 4
    strict = SLOClass("gold", 250.0)
    strict_rate = 10.0

    w = jnp.asarray(np.random.RandomState(0).randn(64, 64).astype(np.float32) * 0.05)

    def fn_hot(ctx, params, x):
        y = jnp.tanh(x @ params)      # eager local compute
        time.sleep(io_wait_s)         # the downstream RPC's network wait
        return ctx.call("downstream", y)  # boundary: keeps the entry eager

    def fn_downstream(ctx, params, x):
        return x + 1.0

    # hot-skewed load: 8 shape-distinct closed-loop BE clients (one lane
    # each — replication is under test here, not coalescing) + a strict
    # trickle on its own shape, all on ONE function
    n_clients = 8
    lane_xs = [jnp.ones((4 + lane, 64), jnp.float32) for lane in range(n_clients)]
    x_strict = jnp.ones((3, 64), jnp.float32)

    def build(autoscale: bool):
        platform = BACKENDS["orchestrated"](
            FusionPolicy(enabled=False), max_batch=max_batch, max_delay_ms=2.0,
            adaptive=True,  # predicted_rho needs the adaptive estimators
            # single-client lanes never fill a batch: an uncapped window
            # would grow toward occupancy and dominate every round trip
            adaptive_config=AdaptiveConfig(max_delay_s=0.002),
            be_shed_depth=10**6,  # measure conservation, not shedding
            autoscale=autoscale,
            autoscale_config=dict(
                rho_high=0.35, rho_low=0.05, sustain=2,
                max_replicas=3, cooldown_s=0.25, eval_interval_s=0.05,
            ) if autoscale else None,
        )
        platform.deploy(FunctionSpec("downstream", fn_downstream, None))
        platform.deploy(FunctionSpec("hot", fn_hot, w))
        # compile (and index) every program the run can touch — one
        # downstream program per lane shape; the hot entry itself is
        # boundary glue (nothing to compile). A mid-run compile after this
        # would trip the spin-up tracer gate.
        for x in (*lane_xs, x_strict):
            platform.invoke("hot", x)
        return platform

    def drive(platform, span_s: float) -> dict:
        """Closed-loop BE clients + open-loop strict trickle for span_s."""
        strict_lats: list[float] = []
        lock = threading.Lock()
        counts = [0] * n_clients
        t_end = time.perf_counter() + span_s

        def be_client(cid: int):
            x = lane_xs[cid % len(lane_xs)]
            while time.perf_counter() < t_end:
                platform.invoke_async("hot", x).result(timeout=120)
                counts[cid] += 1

        def strict_client():
            futs = []
            while time.perf_counter() < t_end:
                t_s = time.perf_counter()
                fut = platform.invoke_async("hot", x_strict, slo=strict)

                def cb(_fut, t_submit=t_s):
                    dt = time.perf_counter() - t_submit
                    with lock:
                        strict_lats.append(dt)
                fut.add_done_callback(cb)
                futs.append(fut)
                time.sleep(1.0 / strict_rate)
            for f in futs:
                f.result(timeout=120)

        threads = [threading.Thread(target=be_client, args=(i,), daemon=True)
                   for i in range(n_clients)]
        threads.append(threading.Thread(target=strict_client, daemon=True))
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        with lock:
            lats = list(strict_lats)
        return {
            "requests": sum(counts),
            "elapsed_s": elapsed,
            "throughput_rps": sum(counts) / elapsed,
            "strict_p95_ms": percentiles_ms(lats)["p95_ms"] if lats else 0.0,
            "strict_requests": len(lats),
        }

    # --- run A: single instance, no autoscaler -------------------------
    platform = build(autoscale=False)
    try:
        base = drive(platform, duration)
        assert platform.registry.replica_count("hot") == 1
    finally:
        platform.shutdown()

    # --- run B: same load, rho-driven autoscaling ----------------------
    platform = build(autoscale=True)
    armed = False
    try:
        tr0 = TRACER.snapshot()
        TRACER.arm()  # spin-ups from here on must be restore-not-rebuild
        armed = True
        drive(platform, ramp)  # unmeasured: the autoscaler reacts in here
        n_replicas = platform.registry.replica_count("hot")
        assert n_replicas >= 2, (
            f"autoscaler never scaled out under saturation (replicas={n_replicas})"
        )
        auto = drive(platform, duration)
        spinups = TRACER.delta(tr0)
        TRACER.disarm()
        armed = False

        replicas = platform.stats()["replicas"]
        info = replicas["functions"]["hot"]
        prov = platform.provisioning_stats()
        scale_outs = [e for e in prov["events"] if e["kind"] == "scale-out"]
    finally:
        if armed:
            TRACER.disarm()
        platform.shutdown()

    ratio = auto["throughput_rps"] / max(base["throughput_rps"], 1e-9)
    out = {
        "mode": "replicas",
        "baseline_rps": round(base["throughput_rps"], 1),
        "autoscaled_rps": round(auto["throughput_rps"], 1),
        "speedup": round(ratio, 2),
        "replicas": len(info["replicas"]),
        "picks": info["picks"],
        "spread": replicas["spread"],
        "spinup_estimate_s": replicas["spinup_estimate_s"],
        "scale_outs": len(scale_outs),
        "spinup_compiles": spinups.compiles,
        "strict_target_ms": strict.target_p95_ms,
        "baseline_strict_p95_ms": round(base["strict_p95_ms"], 1),
        "autoscaled_strict_p95_ms": round(auto["strict_p95_ms"], 1),
    }
    print(f"[replicas] single instance: {base['throughput_rps']:8.1f} req/s   "
          f"strict p95 {base['strict_p95_ms']:6.1f} ms   ({base['requests']} reqs)")
    print(f"[replicas] autoscaled x{out['replicas']}: {auto['throughput_rps']:8.1f} req/s   "
          f"strict p95 {auto['strict_p95_ms']:6.1f} ms   ({auto['requests']} reqs)")
    print(f"[replicas] speedup {ratio:.2f}x   {out['scale_outs']} warm scale-outs "
          f"({spinups.compiles} compiles)   picks {out['picks']}")
    assert scale_outs and all(e["warm"] for e in scale_outs), (
        f"replica spin-up must be warm (restore-not-rebuild): {scale_outs}"
    )
    assert spinups.compiles == 0, (
        f"replica spin-ups recompiled {spinups.compiles} programs — the "
        f"executable index is not covering the replicated route"
    )
    busy = [iid for iid, n in info["picks"].items() if n > 0]
    assert len(busy) >= 2, f"spread never fanned out: picks {info['picks']}"
    for label, res in (("baseline", base), ("autoscaled", auto)):
        assert res["strict_p95_ms"] <= strict.target_p95_ms, (
            f"{label} strict p95 {res['strict_p95_ms']:.1f}ms > "
            f"{strict.target_p95_ms:.1f}ms target"
        )
    assert ratio >= 1.5, (
        f"autoscaled replica set must deliver >=1.5x the single-instance "
        f"baseline (got {ratio:.2f}x)"
    )
    return out


def run_replicas_smoke(args) -> int:
    """CI gate for the replicated data plane; one retry (same policy as the
    other smokes — timing ratios can flake on shared boxes, the warm/compile
    counter assertions cannot, and a real regression fails both attempts)."""
    try:
        run_replicas(args, smoke=True)
        return 0
    except AssertionError:
        print("[replicas-smoke] attempt 1 flaked; retrying once")
        try:
            run_replicas(args, smoke=True)
            return 0
        except AssertionError as exc:
            print(f"[replicas-smoke] FAIL: {exc}")
            return 1


def run_slo(args, *, smoke: bool = False) -> dict:
    """Multi-level SLO demonstration: three classes under mixed open-loop
    load on one calibrated function, on the tinyjax backend with adaptive
    (queueing-model) windows.

    Classes: ``strict`` (finite p95 target derived from the measured batch
    service time so the scenario is host-independent), ``standard`` (4x the
    strict target), and best-effort. Arrivals: best-effort comes in bursts
    (the traffic batching exists for), strict/standard trickle uniformly.

    The same arrival schedule then replays against a FIFO baseline — one
    class, static window, no SLO awareness — and the run asserts the two
    headline properties: the strict class MEETS its p95 target under the
    SLO-aware scheduler, and aggregate throughput stays within 15% of the
    FIFO baseline (class isolation must not cost meaningful capacity).
    """
    from repro.core import FunctionSpec
    from repro.scheduler.slo import SLOClass

    duration = 2.0 if smoke else max(4.0, args.duration)
    max_batch = 4

    # --- host calibration: size F so one batch-of-4 costs ~4ms here ---
    w = jnp.asarray(np.random.RandomState(0).randn(128, 128).astype(np.float32) * 0.05)
    probe_iters, target_batch_s = 50, 0.004
    probe = jax.jit(
        lambda v: jax.lax.fori_loop(0, probe_iters, lambda i, h: jnp.tanh(h @ w), v)
    )
    xb = jnp.ones((max_batch, 4, 128), jnp.float32)
    probe(xb).block_until_ready()  # compile
    trials = []
    for _ in range(3):  # best-of-3: contention only ever ADDS time
        t_p = time.perf_counter()
        probe(xb).block_until_ready()
        trials.append(time.perf_counter() - t_p)
    probe_s = max(min(trials), 1e-5)
    fn_iters = max(10, int(probe_iters * target_batch_s / probe_s))

    def fn_f(ctx, params, x):
        return jax.lax.fori_loop(0, fn_iters, lambda i, v: jnp.tanh(v @ params), x)

    def build(slo_aware: bool):
        platform = BACKENDS["tinyjax"](
            FusionPolicy(enabled=False), max_batch=max_batch,
            max_delay_ms=args.max_delay_ms, adaptive=slo_aware,
        )
        platform.deploy(FunctionSpec("F", fn_f, w))
        return platform

    x = jnp.ones((4, 128), jnp.float32)

    def warm(platform):
        """Compile every bucket the run will touch, outside any timing."""
        for k in (1, 2, max_batch):
            futs = [platform.invoke_async("F", x) for _ in range(k)]
            for f in futs:
                f.result()

    def measure_capacity(platform):
        walls = []
        for _ in range(3):
            t_m = time.perf_counter()
            futs = [platform.invoke_async("F", x) for _ in range(max_batch)]
            for f in futs:
                f.result()
            walls.append(time.perf_counter() - t_m)
        return max_batch / max(min(walls), 1e-4)

    def drive(platform, classes: dict[str, SLOClass], rates: dict[str, float]) -> dict:
        """One open-loop run of the mixed schedule. ``classes`` maps stream
        -> SLOClass (the FIFO baseline maps every stream to None);
        ``rates`` is the SHARED arrival schedule — probed once, replayed
        identically for both runs, so the throughput comparison measures
        class isolation and not probe-to-probe calibration noise."""
        warm(platform)
        platform.scheduler.reset_stats()
        pending: list = []
        lat_by_stream: dict[str, list[float]] = {k: [] for k in rates}
        lock = threading.Lock()

        def stamp(stream, t_submit):
            def cb(fut):
                dt = time.perf_counter() - t_submit
                with lock:
                    lat_by_stream[stream].append(dt)
            return cb

        t0 = time.perf_counter()
        next_t = dict.fromkeys(rates, 0.0)
        burst = 4  # best-effort arrives in back-to-back groups
        while True:
            now = time.perf_counter() - t0
            if now >= duration:
                break
            for stream, rate in rates.items():
                if now >= next_t[stream]:
                    n = burst if stream == "be" else 1
                    for _ in range(n):
                        fut = platform.invoke_async("F", x, slo=classes.get(stream))
                        fut.add_done_callback(stamp(stream, time.perf_counter()))
                        pending.append(fut)
                    next_t[stream] += n / rate
            time.sleep(max(0.0, min(next_t.values()) - (time.perf_counter() - t0)))
        for fut in pending:
            fut.result(timeout=60)
        # done-callbacks can trail result(); join on the counters
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with lock:
                if sum(len(v) for v in lat_by_stream.values()) >= len(pending):
                    break
            time.sleep(0.001)
        sched = platform.scheduler.stats()
        return {
            "requests": len(pending),
            "throughput_rps": sched["throughput_rps"],
            "mean_batch": round(sched["mean_batch"], 3),
            "per_stream": {
                k: {kk: round(vv, 2) for kk, vv in percentiles_ms(v).items()}
                for k, v in lat_by_stream.items()
            },
            "classes": sched.get("classes", {}),
        }

    # ONE calibration probe sizes both the targets (~10 batch-times for
    # strict: meaningful AND meetable on any host) and the shared arrival
    # schedule replayed against both platforms
    platform = build(slo_aware=True)
    try:
        warm(platform)
        capacity_rps = measure_capacity(platform)
        batch_s = max_batch / capacity_rps
        strict = SLOClass("strict", max(10 * batch_s * 1e3, 40.0))
        standard = SLOClass("standard", 4 * strict.target_p95_ms)
        classes = {"strict": strict, "standard": standard, "be": None}
        total = 0.55 * capacity_rps  # below capacity: targets are meetable
        rates = {"strict": 0.15 * total, "standard": 0.25 * total, "be": 0.60 * total}
        slo_res = drive(platform, classes, rates)
    finally:
        platform.shutdown()

    platform = build(slo_aware=False)
    try:
        fifo_res = drive(platform, dict.fromkeys(classes, None), rates)  # one class, FIFO
    finally:
        platform.shutdown()

    strict_p95 = slo_res["per_stream"]["strict"]["p95_ms"]
    fifo_strict_p95 = fifo_res["per_stream"]["strict"]["p95_ms"]
    ratio = slo_res["throughput_rps"] / max(fifo_res["throughput_rps"], 1e-9)
    out = {
        "mode": "slo",
        "strict_target_ms": strict.target_p95_ms,
        "strict_p95_ms": strict_p95,
        "fifo_strict_p95_ms": fifo_strict_p95,
        "standard_p95_ms": slo_res["per_stream"]["standard"]["p95_ms"],
        "be_p95_ms": slo_res["per_stream"]["be"]["p95_ms"],
        "throughput_rps": slo_res["throughput_rps"],
        "fifo_throughput_rps": fifo_res["throughput_rps"],
        "throughput_vs_fifo": round(ratio, 3),
        "requests": slo_res["requests"],
        "slo": slo_res,
        "fifo": fifo_res,
    }
    for stream in ("strict", "standard", "be"):
        tgt = {"strict": strict.target_p95_ms, "standard": standard.target_p95_ms,
               "be": float("inf")}[stream]
        tgt_s = f"target {tgt:7.1f} ms" if tgt != float("inf") else "best-effort   "
        print(f"[slo] {stream:>8}: p95 {slo_res['per_stream'][stream]['p95_ms']:7.1f} ms "
              f"({tgt_s})   fifo p95 {fifo_res['per_stream'][stream]['p95_ms']:7.1f} ms")
    print(f"[slo] aggregate throughput {slo_res['throughput_rps']:.1f} rps vs "
          f"FIFO {fifo_res['throughput_rps']:.1f} rps ({ratio:.2f}x), "
          f"{slo_res['requests']} reqs, mean batch {slo_res['mean_batch']:.2f} "
          f"(be lanes), capacity ~{capacity_rps:.0f} rps")
    assert strict_p95 <= strict.target_p95_ms, (
        f"strict class missed its target under mixed load: "
        f"p95 {strict_p95:.1f}ms > {strict.target_p95_ms:.1f}ms"
    )
    assert ratio >= 0.85, (
        f"SLO-aware scheduling cost too much aggregate throughput: "
        f"{ratio:.2f}x of FIFO (floor 0.85)"
    )
    return out


def run_slo_smoke(args) -> int:
    """CI gate for the SLO scheduler: tiny mixed-class run; one retry (same
    policy as the churn smoke — shared 2-core CI boxes can flake the
    calibration ~once in ten runs; a real regression fails both)."""
    try:
        run_slo(args, smoke=True)
        return 0
    except AssertionError:
        print("[slo-smoke] attempt 1 flaked; retrying once")
        try:
            run_slo(args, smoke=True)
            return 0
        except AssertionError as exc:
            print(f"[slo-smoke] FAIL: {exc}")
            return 1


def run_serve(args, *, smoke: bool = False) -> dict:
    """Paged continuous-batching serve demo vs the per-client-pytree
    baseline, at EQUAL client count on the same fused chain.

    Baseline: C closed-loop clients, each with its own full ``max_len``
    dense cache pytree, decoding through the scheduler's micro-batched
    dispatch (the PR 1-4 serve path) — every step is a rendezvous: C
    futures, C cache pytrees stacked/split across the batching boundary.

    Paged: the same C as a ContinuousBatcher capacity over one shared KV
    arena. Open-loop arrivals with MIXED prompt and generation lengths join
    the persistent in-flight batch post-prefill and leave at their step
    limit; empty slots are masked. Tokens/s and p95 inter-token latency are
    reported for both, plus per-request arena pages from the billing meter
    (RAM now proportional to tokens held, not clients x max_len)."""
    import queue as queue_mod

    from repro.serving.engine import _greedy_token

    c = min(args.concurrency, 4) if smoke else args.concurrency
    steps = 12 if smoke else max(16, args.steps // 2)
    prompt_lens = (4, 8) if smoke else (4, 8, 16)
    n_requests = 5 * c
    # the SHARED workload: mixed prompt and generation lengths
    gens = [max(6, steps + ((i * 7) % 13) - 6) for i in range(n_requests)]
    prompts = [jnp.full((1, prompt_lens[i % len(prompt_lens)]), 1 + i % 17, jnp.int32)
               for i in range(n_requests)]

    # --- paged continuous batching over the shared arena (calibrates the
    # open-loop arrival schedule both sides then replay)
    width = args.max_len // args.page_size
    kv_pages = (c + 2) * width + 1  # in-flight residents + margin + scratch
    engine, platform = build_engine(args, fused=True, kv_pages=kv_pages)
    try:
        warm(engine)  # fuse the chain + compile the dense routes
        cb = ContinuousBatcher(engine, capacity=c)
        # warmup: compile each prefill length + the capacity-C decode program
        futs = [cb.submit({"tokens": prompts[i]}, 3) for i in range(min(c, len(prompts)))]
        for f in futs:
            f.result(timeout=300)
        # the workload repeats prompts, and a repeated prompt is now a
        # whole-prefix cache hit served by one frozen decode step — compile
        # that program too before anything is timed (the calibration request
        # below is itself such a hit)
        cb.submit({"tokens": prompts[0]}, 3).result(timeout=300)
        # calibrate arrivals so the in-flight batch stays occupied (~1.5x
        # oversubscribed vs the paged solo rate); the identical offsets
        # replay against the baseline, so whichever side is slower simply
        # backs up — open-loop throughput measures capacity
        t_cal = time.perf_counter()
        cb.submit({"tokens": prompts[0]}, steps).result(timeout=300)
        per_req_s = max(time.perf_counter() - t_cal, 1e-3)
        offsets = [i * per_req_s / (1.5 * c) for i in range(n_requests)]
        # warmup + calibration must not pollute the measured leases/occupancy
        platform.meter.reset()
        cb.reset_stats()
        # dispatch-hygiene gate: warmup compiled every program the stream
        # can touch, so the timed window must compile nothing and must not
        # sync the host more than once per batched step (+ seat/finish per
        # request) — a per-token-per-lane sync or a mid-stream recompile
        # shows up here, not in a reviewer's profile later
        TRACER.arm()
        dispatch_t0 = TRACER.snapshot()
        results = []
        t0 = time.perf_counter()
        pend = []
        for i in range(n_requests):
            target = t0 + offsets[i]
            now = time.perf_counter()
            if now < target:
                time.sleep(target - now)
            pend.append(cb.submit({"tokens": prompts[i]}, gens[i]))
        for f in pend:
            results.append(f.result(timeout=600))
        paged_elapsed = time.perf_counter() - t0
        hygiene = TRACER.delta(dispatch_t0)
        TRACER.disarm()
        print(f"[serve] dispatch hygiene: {hygiene.compiles} steady-state compiles, "
              f"{hygiene.host_syncs} host syncs over {hygiene.decode_steps} decode steps "
              f"/ {n_requests} requests")
        assert hygiene.compiles == 0, (
            f"steady-state serve stream compiled {hygiene.compiles} new program(s) "
            f"after warmup — a shape bucket is leaking"
        )
        sync_budget = hygiene.decode_steps + 2 * n_requests + c
        assert hygiene.host_syncs <= sync_budget, (
            f"{hygiene.host_syncs} device->host syncs for {hygiene.decode_steps} "
            f"decode steps (budget {sync_budget}: one batched token fetch per "
            f"step + seat/finish per request) — something syncs per token"
        )
        paged_tokens = sum(r["tokens"].shape[1] for r in results)
        itl = [s for r in results for s in r["step_s"]]
        arena = platform.meter.arena_summary()
        stats = cb.stats()
        cb.shutdown()
        paged = {
            "tokens_s": round(paged_tokens / paged_elapsed, 1),
            "itl_p95_ms": round(percentiles_ms(itl)["p95_ms"], 2),
            "tokens": paged_tokens,
            "elapsed_s": round(paged_elapsed, 3),
            "mean_occupancy": round(stats["mean_occupancy"], 3),
            "mean_pages_per_request": round(arena["mean_pages"], 2),
            "max_pages_per_request": arena["max_pages"],
            "arena_gb_s": arena["gb_s"],
        }
    finally:
        platform.shutdown()

    # --- baseline: the SAME open-loop request stream served by C client
    # workers, each request with its own full max_len dense cache pytree,
    # decode steps through the scheduler's micro-batched dispatch (the
    # pre-arena serve path, at equal client count)
    engine, platform = build_engine(args, fused=True)
    try:
        warm(engine)
        # compile every prefill length and every batched decode bucket the
        # run can touch — the timed stream must measure traffic, not compiles
        for pl in prompt_lens:
            engine.generate({"tokens": jnp.full((1, pl), 2, jnp.int32)}, steps=3)
        warm_clients = [Client(engine, i, prompt_lens[0]) for i in range(c)]
        k = 1
        while k <= c:
            futs = [engine.decode_step_async(cl.tokens, cl.cur_len, cl.caches)
                    for cl in warm_clients[:k]]
            for f in futs:
                f.result()
            k *= 2
        platform.scheduler.reset_stats()
        work: "queue_mod.Queue" = queue_mod.Queue()
        base_lats: list[float] = []
        base_tokens_done = [0]
        lock = threading.Lock()

        def serve_one(prompt, gen):
            logits, caches, cur_len = engine.prefill({"tokens": prompt})
            toks = 1
            tokens = _greedy_token(jnp.asarray(logits))
            lats = []
            for _ in range(gen - 1):
                t_s = time.perf_counter()
                logits, caches = engine.decode_step_async(tokens, cur_len, caches).result()
                lats.append(time.perf_counter() - t_s)
                cur_len = cur_len + 1
                tokens = _greedy_token(jnp.asarray(logits))
                toks += 1
            with lock:
                base_lats.extend(lats)
                base_tokens_done[0] += toks

        def worker():
            while True:
                item = work.get()
                if item is None:
                    return
                target_t, prompt, gen = item
                now = time.perf_counter()
                if now < target_t:
                    time.sleep(target_t - now)
                serve_one(prompt, gen)

        threads = [threading.Thread(target=worker, daemon=True) for _ in range(c)]
        t0 = time.perf_counter()
        for i in range(n_requests):
            work.put((t0 + offsets[i], prompts[i], gens[i]))
        for _ in threads:
            work.put(None)
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        base_elapsed = time.perf_counter() - t0
        base = {
            "tokens_s": round(base_tokens_done[0] / base_elapsed, 1),
            "itl_p95_ms": round(percentiles_ms(base_lats)["p95_ms"], 2),
            "tokens": base_tokens_done[0],
            "elapsed_s": round(base_elapsed, 3),
        }
    finally:
        platform.shutdown()

    ratio = paged["tokens_s"] / max(base["tokens_s"], 1e-9)
    out = {"mode": "serve", "clients": c, "requests": n_requests,
           "baseline": base, "paged": paged, "speedup": round(ratio, 2)}
    print(f"[serve] per-client baseline: {base['tokens_s']:8.1f} tok/s   "
          f"itl p95 {base['itl_p95_ms']:7.2f} ms   ({base['tokens']} tokens)")
    print(f"[serve] paged continuous  : {paged['tokens_s']:8.1f} tok/s   "
          f"itl p95 {paged['itl_p95_ms']:7.2f} ms   ({paged['tokens']} tokens, "
          f"occupancy {paged['mean_occupancy']:.2f})")
    print(f"[serve] speedup {ratio:.2f}x   arena: {paged['mean_pages_per_request']:.1f} mean / "
          f"{paged['max_pages_per_request']} max pages per request "
          f"(vs {args.max_len // args.page_size} pages for a dense max_len cache)")
    # the smoke floor is loose (a 2-core shared box adds +-30% run-to-run
    # noise and the batcher's single loop thread absorbs it all); the full
    # run is the demo and must show the real >= 1.5x effect
    floor = 1.15 if smoke else 1.5
    assert ratio >= floor, (
        f"paged continuous batching must beat the per-client baseline "
        f"(got {ratio:.2f}x, floor {floor}x)"
    )
    out["shared_prefix"] = run_shared_prefix(args, smoke=smoke)
    return out


def run_shared_prefix(args, *, smoke: bool = False) -> dict:
    """Shared-system-prompt scenario: the prefix-cache + chunked-prefill
    story on one engine, two phases over the same burst workload.

    Phase 1 (baseline): ``serialize_prefill=True`` and every request gets a
    DISTINCT 80-token prompt — no page sharing, every admission runs its
    whole prompt in front of the batch (the pre-chunking serve path).
    Phase 2: the default chunked batcher and an IDENTICAL 80-token prompt
    for every request — the fleet-wide system prompt. After the first
    request commits, every joiner's prompt is a whole-prefix cache hit:
    its first token comes from one frozen (no-KV-write) decode step and it
    seats without computing a single prompt token.

    Two deltas are measured and asserted:
    * billed pages/request (ArenaLease amortized by refcount at release):
      sharers split the prefix pages' bill, so the mean must drop >= 2x
      vs the unshared nominal count.
    * joiner stall p95 — per request, the WORST inter-emission gap, i.e.
      what a seated resident absorbed while someone else's prompt ran.
      Serialized 80-token prefills stall every resident; cache hits don't.
    """
    c = min(args.concurrency, 4) if smoke else args.concurrency
    n = 6 * c
    sys_len = 80  # 5 full pages at the default 16-token page
    gens = [6 + (i % 5) for i in range(n)]
    width = args.max_len // args.page_size
    kv_pages = (c + 2) * width + 1
    engine, platform = build_engine(args, fused=True, kv_pages=kv_pages)
    try:
        warm(engine)

        def distinct_prompt(i):
            row = np.full((1, sys_len), 2, np.int32)
            row[0, 0] = 1 + i % 16      # two varied positions: distinct
            row[0, 1] = 1 + (i // 16) % 16  # prompts for any n < 256
            return jnp.asarray(row)

        shared_prompt = jnp.full((1, sys_len), 3, jnp.int32)

        def drive(cb, prompts):
            """Burst-submit the workload and collect per-request results."""
            pend = [cb.submit({"tokens": prompts[i]}, gens[i]) for i in range(n)]
            return [f.result(timeout=600) for f in pend]

        def stall_p95_ms(results):
            worst = [max(r["step_s"]) if r["step_s"] else 0.0 for r in results]
            return percentiles_ms(worst)["p95_ms"]

        # --- phase 1: serialized prefill, no sharing possible
        cb = ContinuousBatcher(engine, capacity=c, serialize_prefill=True)
        for f in [cb.submit({"tokens": distinct_prompt(200 + k)}, 3) for k in range(2)]:
            f.result(timeout=300)  # compile prefill-80 + the decode program
        platform.meter.reset()
        cb.reset_stats()
        res_u = drive(cb, [distinct_prompt(i) for i in range(n)])
        arena_u = platform.meter.arena_summary()
        unshared = {
            "mean_billed_pages": round(arena_u["mean_billed_pages"], 2),
            "mean_pages": round(arena_u["mean_pages"], 2),
            "stall_p95_ms": round(stall_p95_ms(res_u), 2),
        }
        cb.shutdown()

        # --- phase 2: chunked prefill + the shared system prompt
        cb = ContinuousBatcher(engine, capacity=c, prefill_chunk=16)
        for f in [cb.submit({"tokens": shared_prompt}, 3) for _ in range(2)]:
            f.result(timeout=300)  # compile the chunk + frozen-hit programs
        platform.meter.reset()
        cb.reset_stats()
        hits0 = engine.arena.stats()["shared_hits"]
        res_s = drive(cb, [shared_prompt] * n)
        arena_s = platform.meter.arena_summary()
        hits = engine.arena.stats()["shared_hits"] - hits0
        shared = {
            "mean_billed_pages": round(arena_s["mean_billed_pages"], 2),
            "mean_pages": round(arena_s["mean_pages"], 2),
            "stall_p95_ms": round(stall_p95_ms(res_s), 2),
            "shared_hits": hits,
        }
        cb.shutdown()
        engine.arena.check_consistency()
        assert engine.arena.used_pages() == 0, "requests leaked arena pages"
    finally:
        platform.shutdown()

    pages_ratio = unshared["mean_billed_pages"] / max(shared["mean_billed_pages"], 1e-9)
    stall_ratio = unshared["stall_p95_ms"] / max(shared["stall_p95_ms"], 1e-9)
    out = {
        "mode": "shared-prefix", "clients": c, "requests": n,
        "unshared": unshared, "shared": shared,
        "pages_ratio": round(pages_ratio, 2), "stall_ratio": round(stall_ratio, 2),
    }
    print(f"[serve] shared-prefix: billed pages/request "
          f"{unshared['mean_billed_pages']:.2f} -> {shared['mean_billed_pages']:.2f} "
          f"({pages_ratio:.2f}x lower; {hits}/{n} prefix hits)")
    print(f"[serve] joiner stall p95: {unshared['stall_p95_ms']:8.2f} ms serialized/unshared"
          f" -> {shared['stall_p95_ms']:8.2f} ms chunked/shared ({stall_ratio:.2f}x)")
    assert hits >= n - 1, f"shared prompts must hit the prefix cache ({hits}/{n})"
    assert pages_ratio >= 2.0, (
        f"prefix sharing must cut billed pages/request >= 2x "
        f"(got {pages_ratio:.2f}x)"
    )
    # the stall floor is loose in smoke (shared 2-core boxes): a cache hit
    # skips the whole prompt, so the real effect is several-fold
    stall_floor = 1.2 if smoke else 1.5
    assert stall_ratio >= stall_floor, (
        f"cache hits must shrink the joiner stall tail "
        f"(got {stall_ratio:.2f}x, floor {stall_floor}x)"
    )
    return out


def run_serve_smoke(args) -> int:
    """CI gate for the paged serve path; one retry (same policy as the other
    smokes on shared 2-core CI boxes)."""
    try:
        run_serve(args, smoke=True)
        return 0
    except AssertionError:
        print("[serve-smoke] attempt 1 flaked; retrying once")
        try:
            run_serve(args, smoke=True)
            return 0
        except AssertionError as exc:
            print(f"[serve-smoke] FAIL: {exc}")
            return 1


def run_smoke(args) -> int:
    """CI gate: a few seconds of closed-loop traffic on the tiny model. Fails
    (exit 1) when coalescing stops happening or throughput collapses to
    zero — scheduler regressions then fail the workflow, not just tests."""
    args.concurrency = min(args.concurrency, 4)
    args.steps, args.warmup_steps = 10, 3
    args.prompt_len, args.max_len = 4, 48
    res = run_closed_loop(args, "fused-batched")
    sched = res["scheduler"] or {}
    print(f"[smoke] {res['throughput_rps']:.1f} req/s, p95 {res['p95_ms']:.1f} ms, "
          f"mean batch {sched.get('mean_batch', 0):.2f} over {sched.get('batches', 0)} batches")
    ok = res["throughput_rps"] > 0 and sched.get("mean_batch", 0.0) > 1.05
    if not ok:
        print("[smoke] FAIL: scheduler no longer coalesces concurrent traffic")
    # tracing-overhead gate: the recorder is always on in production
    # configs, so its cost on the SAME closed-loop traffic must stay under
    # 3% throughput. One retry: on a shared 2-core box run-to-run noise
    # alone can exceed the margin; a real regression fails both attempts.
    off = run_closed_loop(args, "fused-batched", tracing=False)
    ratio = res["throughput_rps"] / max(off["throughput_rps"], 1e-9)
    if ratio < 0.97:
        print(f"[smoke] tracing overhead attempt 1 flaked (on/off ratio {ratio:.3f}); retrying once")
        on2 = run_closed_loop(args, "fused-batched")
        off2 = run_closed_loop(args, "fused-batched", tracing=False)
        ratio = on2["throughput_rps"] / max(off2["throughput_rps"], 1e-9)
    print(f"[smoke] tracing overhead: on/off throughput ratio {ratio:.3f}")
    if ratio < 0.97:
        print("[smoke] FAIL: tracing costs more than 3% throughput")
        ok = False
    # churn gate: merge -> saturate -> split under load, no dropped/hung
    # futures. One retry, same policy as the slow-marked timing tests: on a
    # 2-core shared box the saturation trigger can flake (~10%) on probe
    # noise; a real regression fails both attempts.
    try:
        run_churn(args, smoke=True)
    except AssertionError:
        print("[smoke] churn attempt 1 flaked; retrying once")
        try:
            run_churn(args, smoke=True)
        except AssertionError as exc:
            print(f"[smoke] FAIL (churn): {exc}")
            ok = False
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--backend", default="tinyjax", choices=sorted(BACKENDS))
    ap.add_argument("--concurrency", type=int, default=8, help="closed-loop clients / open-loop streams")
    ap.add_argument("--steps", type=int, default=48, help="timed decode steps per closed-loop client")
    ap.add_argument("--warmup-steps", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-batch", type=int, default=0, help="0 = match --concurrency")
    ap.add_argument("--max-delay-ms", type=float, default=4.0, help="micro-batch window")
    ap.add_argument("--rate", type=float, default=0.0, help=">0 switches to open loop at this req/s")
    ap.add_argument("--duration", type=float, default=5.0, help="open-loop run time (s)")
    ap.add_argument("--pattern", default="uniform", choices=("uniform", "bursty", "trickle"),
                    help="open-loop arrival pattern")
    ap.add_argument("--burst", type=int, default=8, help="bursty: arrivals per burst")
    ap.add_argument("--intra-gap-ms", type=float, default=1.0, help="bursty: spacing inside a burst")
    ap.add_argument("--trickle-rate", type=float, default=15.0,
                    help="--adaptive: req/s of the trickle scenario (gap must exceed any window)")
    ap.add_argument("--adaptive", action="store_true",
                    help="run the static-vs-adaptive window comparison on bursty + trickle arrivals")
    ap.add_argument("--smoke", action="store_true", help="tiny CI sanity run (exit 1 on regression)")
    ap.add_argument("--churn", action="store_true",
                    help="fission demo: merge -> saturate -> split under load (orchestrated)")
    ap.add_argument("--slo", action="store_true",
                    help="multi-class SLO demo: strict/standard/best-effort under mixed "
                         "load vs a FIFO baseline (with --smoke: tiny CI gate)")
    ap.add_argument("--serve", action="store_true",
                    help="paged continuous-batching serve demo vs the per-client-pytree "
                         "baseline (with --smoke: tiny CI gate)")
    ap.add_argument("--replicas", action="store_true",
                    help="replicated-data-plane demo: rho-driven autoscaling vs the "
                         "single-instance baseline on hot-skewed load "
                         "(with --smoke: tiny CI gate)")
    ap.add_argument("--coldstart", action="store_true",
                    help="warm-provisioning demo: merge/split churn from the executable "
                         "index + scale-to-zero resurrect vs cold build "
                         "(with --smoke: tiny CI gate)")
    ap.add_argument("--page-size", type=int, default=16, help="KV arena page size (tokens)")
    ap.add_argument("--modes", nargs="*", default=["fused-serial", "fused-batched"], choices=MODES)
    ap.add_argument("--json", action="store_true", help="emit machine-readable results")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Chrome/perfetto trace_event JSON of every "
                         "platform's request + control-plane spans to PATH at exit")
    args = ap.parse_args()

    if not args.coldstart:
        # REPRO_COMPILE_CACHE=<dir>: persistent XLA cache across runs. The
        # coldstart scenario opts out — its cold measurements must really
        # be cold, even when CI restored a populated cache directory.
        from repro.launch.compile_cache import maybe_enable_from_env
        maybe_enable_from_env()

    if args.trace:
        # pin every tracer created from here on: scenarios drop their
        # platforms, but the spans must survive until the export below
        from repro.obs import retain_tracers
        retain_tracers()
    try:
        _dispatch(args)
    finally:
        if args.trace:
            from repro.obs import export_all_chrome
            export_all_chrome(args.trace)
            print(f"[trace] wrote {args.trace}")


def _dispatch(args):
    if args.coldstart:
        if args.smoke:
            sys.exit(run_coldstart_smoke(args))
        out = run_coldstart(args)
        if args.json:
            print(json.dumps(out, indent=2))
        return
    if args.replicas:
        if args.smoke:
            sys.exit(run_replicas_smoke(args))
        out = run_replicas(args)
        if args.json:
            print(json.dumps(out, indent=2))
        return
    if args.serve:
        if args.smoke:
            sys.exit(run_serve_smoke(args))
        out = run_serve(args)
        if args.json:
            print(json.dumps(out, indent=2))
        return
    if args.slo:
        if args.smoke:
            sys.exit(run_slo_smoke(args))
        out = run_slo(args)
        if args.json:
            out.pop("slo", None)
            out.pop("fifo", None)
            print(json.dumps(out, indent=2))
        return
    if args.smoke:
        sys.exit(run_smoke(args))
    if args.churn:
        out = run_churn(args)
        if args.json:
            print(json.dumps(out, indent=2))
        return
    if args.adaptive:
        if args.rate <= 0:
            # bursts of --burst whose span outlives the static window: the
            # static window fragments each burst into several executions,
            # the adaptive one grows to pack it whole — and because each
            # burst drains before the next, the adaptive wait is bounded by
            # the burst span, never by queueing behind a knife-edge load
            args.rate = 160.0
        out = run_adaptive_compare(args)
        if args.json:
            for r in out.values():
                if isinstance(r, dict):
                    r.pop("scheduler", None)
            print(json.dumps(out, indent=2))
        return

    results = []
    for mode in args.modes:
        if args.rate > 0:
            if mode.endswith("serial"):
                # open loop submits without waiting — that IS the scheduled
                # path; a "serial" open-loop row would silently measure the
                # same thing under a different label
                print(f"[{mode:>16}] skipped: open loop (--rate) only supports *-batched modes")
                continue
            res = run_open_loop(args, mode)
        else:
            res = run_closed_loop(args, mode)
        results.append(res)
        if not args.json:
            sched = res.pop("scheduler", None)
            print(f"[{res['mode']:>16}] {res['throughput_rps']:8.1f} req/s   "
                  f"p50 {res['p50_ms']:7.1f} ms   p95 {res['p95_ms']:7.1f} ms   "
                  f"p99 {res['p99_ms']:7.1f} ms   ({res['requests']} reqs in {res['elapsed_s']}s)")
            if sched:
                print(f"{'':18}mean batch {sched['mean_batch']:.2f}, max {sched['max_batch_seen']}, "
                      f"{sched['batches']} batches")

    by_mode = {r["mode"]: r for r in results}
    if "fused-serial" in by_mode and "fused-batched" in by_mode:
        speedup = by_mode["fused-batched"]["throughput_rps"] / max(by_mode["fused-serial"]["throughput_rps"], 1e-9)
        if args.json:
            for r in results:
                r.pop("scheduler", None)
            print(json.dumps({"results": results, "batched_vs_serial_speedup": round(speedup, 2)}, indent=2))
        else:
            print(f"\nbatched vs serial (fused chain): {speedup:.2f}x throughput")
    elif args.json:
        print(json.dumps({"results": results}, indent=2))


if __name__ == "__main__":
    main()
