"""Kernel micro-benchmarks: jnp oracle wall-time on this host (CPU) as the
throughput reference + interpret-mode validation deltas. (TPU wall-times are
not measurable here; the dry-run roofline covers projected TPU perf.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[dict]:
    rng = jax.random.PRNGKey(0)
    rows = []

    b, t, h, kv, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, t, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, hd), jnp.float32)
    us = _time(jax.jit(lambda a, b2, c: ref.mha_ref(a, b2, c, causal=True)), q, k, v)
    rows.append({"name": "mha_ref_512x8h", "us_per_call": round(us, 1)})

    s = 2048
    kd = jax.random.normal(ks[1], (2, s, kv, hd), jnp.float32)
    vd = jax.random.normal(ks[2], (2, s, kv, hd), jnp.float32)
    qd = jax.random.normal(ks[0], (2, h, hd), jnp.float32)
    cur = jnp.array([s, s // 2])
    us = _time(jax.jit(lambda a, b2, c, d: ref.decode_attn_ref(a, b2, c, d)), qd, kd, vd, cur)
    rows.append({"name": "decode_attn_ref_2k", "us_per_call": round(us, 1)})

    from repro.models.ssm import ssd_chunked

    x = jax.random.normal(ks[0], (1, 512, 8, 32), jnp.float32)
    bm = jax.random.normal(ks[1], (1, 512, 1, 16), jnp.float32) * 0.5
    cm = jax.random.normal(ks[2], (1, 512, 1, 16), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[0], (1, 512, 8), jnp.float32))
    al = jnp.zeros((8,))
    dk = jnp.ones((8,))
    us = _time(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0]), x, bm, cm, dt, al, dk)
    rows.append({"name": "ssd_chunked_512", "us_per_call": round(us, 1)})

    xe = jax.random.normal(ks[0], (8, 128, 128), jnp.float32)
    w = jax.random.normal(ks[1], (8, 128, 256), jnp.float32) * 0.05
    us = _time(jax.jit(ref.gmm_ref), xe, w)
    rows.append({"name": "moe_gmm_ref_8x128", "us_per_call": round(us, 1)})
    return rows
