"""The paper's two benchmark applications, as Provuse function graphs.

TREE (Fusionize++ fig. 4): A synchronously invokes B, which calls D and E;
A also triggers an asynchronous branch via C to F and G. The async path
dominates the workload (heavier payloads), so fusion of the sync chain must
win despite most compute being elsewhere.

IOT (Fusionize++ fig. 3): AnalyzeSensor entry combines sequential
preprocessing with parallel analysis of temperature, air quality and
traffic (synchronous), then stores results asynchronously.

Payloads are real JAX compute (matmul stacks) sized like the paper's
functions — light sensor analytics, a few hundred us each on this host —
so the invocation boundary carries a share of end-to-end latency
comparable to the paper's network hop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FunctionSpec

DIM = 128


def _w(seed: int, scale: float = 0.05, dim: int = DIM):
    return jax.random.normal(jax.random.PRNGKey(seed), (dim, dim)) * scale


def _work(x: jax.Array, w: jax.Array, n: int = 1) -> jax.Array:
    for _ in range(n):
        x = jnp.tanh(x @ w)
    return x


def deploy_tree(platform) -> str:
    """Returns the entry function name."""

    def f_d(ctx, p, x):
        return _work(x, p)

    def f_e(ctx, p, x):
        return _work(x, p)

    def f_b(ctx, p, x):
        h = _work(x, p)
        d = ctx.call("tree/D", h)
        e = ctx.call("tree/E", h)
        return d + e

    def f_g(ctx, p, x):
        return _work(x, p, n=6).sum()

    def f_f(ctx, p, x):
        h = _work(x, p, n=3)
        ctx.call_async("tree/G", h)
        return h.sum()

    def f_c(ctx, p, x):
        h = _work(x, p, n=3)  # async path dominates (fig. 4 caption)
        ctx.call_async("tree/F", h)
        return h.sum()

    def f_a(ctx, p, x):
        h = _work(x, p)
        ctx.call_async("tree/C", h)
        return ctx.call("tree/B", h)

    platform.deploy(FunctionSpec("tree/A", f_a, _w(1), trust_domain="tree"))
    platform.deploy(FunctionSpec("tree/B", f_b, _w(2), trust_domain="tree"))
    platform.deploy(FunctionSpec("tree/C", f_c, _w(3), trust_domain="tree"))
    platform.deploy(FunctionSpec("tree/D", f_d, _w(4), trust_domain="tree"))
    platform.deploy(FunctionSpec("tree/E", f_e, _w(5), trust_domain="tree"))
    platform.deploy(FunctionSpec("tree/F", f_f, _w(6), trust_domain="tree"))
    platform.deploy(FunctionSpec("tree/G", f_g, _w(7), trust_domain="tree"))
    return "tree/A"


def deploy_iot(platform) -> str:
    def f_temp(ctx, p, x):
        return _work(x, p, n=2).mean(axis=1)

    def f_airq(ctx, p, x):
        return jnp.sqrt(jnp.maximum(_work(x, p, n=2), 0)).mean(axis=1)

    def f_traffic(ctx, p, x):
        return jax.nn.softmax(_work(x, p, n=2), axis=1).max(axis=1)

    def f_store(ctx, p, x):
        return (x * x).sum()

    def f_analyze(ctx, p, x):
        h = _work(x, p)  # sequential preprocessing step
        t = ctx.call("iot/temperature", h)
        a = ctx.call("iot/airquality", h)
        r = ctx.call("iot/traffic", h)
        result = jnp.stack([t, a, r], axis=1)
        ctx.call_async("iot/store", result)
        return result

    platform.deploy(FunctionSpec("iot/analyze", f_analyze, _w(11), trust_domain="iot"))
    platform.deploy(FunctionSpec("iot/temperature", f_temp, _w(12), trust_domain="iot"))
    platform.deploy(FunctionSpec("iot/airquality", f_airq, _w(13), trust_domain="iot"))
    platform.deploy(FunctionSpec("iot/traffic", f_traffic, _w(14), trust_domain="iot"))
    platform.deploy(FunctionSpec("iot/store", f_store, None, trust_domain="iot"))
    return "iot/analyze"


APPS = {"TREE": deploy_tree, "IOT": deploy_iot}


def make_request(seed: int = 0):
    return jax.random.normal(jax.random.PRNGKey(seed % 97), (8, DIM)) * 0.5
