"""Roofline table from the dry-run JSONL (see launch/dryrun.py + DESIGN.md).

Per (arch x shape x mesh): the three terms in seconds, the dominant one,
HBM fit, and MODEL_FLOPS/HLO_FLOPS. Also emits the markdown table used in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import os

HW_NOTE = "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI per chip (TPU v5e)"


def load(path: str = "results/dryrun.jsonl") -> list[dict]:
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
    # newest record per cell wins
    dedup: dict[tuple, dict] = {}
    for r in rows:
        dedup[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(dedup.values())


def table(rows: list[dict], mesh: str = "pod16x16") -> list[dict]:
    out = []
    for r in sorted(rows, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            out.append({"arch": r.get("arch"), "shape": r.get("shape"), "status": r.get("status"), "reason": r.get("reason", "")})
            continue
        rf = r["roofline"]
        out.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "status": "ok",
                "compute_s": rf["compute_s"],
                "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "dominant": rf["dominant"].replace("_s", ""),
                "bound_s": rf["bound_s"],
                "hbm_gb": r["hbm_per_device_gb"],
                "fits": r["fits_16gb"],
                "useful_ratio": round(r["useful_flops_ratio"], 3),
                "roofline_frac": round(rf["compute_s"] / rf["bound_s"], 4) if rf["bound_s"] else None,
            }
        )
    return out


def markdown(rows: list[dict], mesh: str = "pod16x16") -> str:
    t = table(rows, mesh)
    lines = [
        f"Hardware: {HW_NOTE}; mesh {mesh}.",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | HBM GB | fits | 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in t:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']}: {r.get('reason','')[:60]} | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | {r['memory_s']:.4g} | "
            f"{r['collective_s']:.4g} | {r['dominant']} | {r['hbm_gb']} | {'Y' if r['fits'] else 'N'} | "
            f"{r['useful_ratio']} | {r['roofline_frac']} |"
        )
    return "\n".join(lines)


def summary(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    doms: dict[str, int] = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    return {
        "cells_ok": len(ok),
        "cells_skipped": sum(1 for r in rows if r.get("status") == "skipped"),
        "cells_failed": sum(1 for r in rows if r.get("status") in ("error", "timeout")),
        "fits_16gb": sum(1 for r in ok if r.get("fits_16gb")),
        "dominant_terms": doms,
    }
