"""§Perf hillclimbing harness: lower one (arch x shape) cell under a named
variant, extract the three roofline terms, and diff against baseline.

Each experiment = hypothesis -> change -> re-lower -> re-analyse (no real
TPU: the "profile" is the loop-aware HLO analysis, per the assignment).

  PYTHONPATH=src python -m benchmarks.perf_experiments --arch qwen3-moe-30b-a3b \
      --shape train_4k --variant moe_ep_local
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time


def lower_cell(cfg, shape_name: str, *, rules_override=None):
    import jax

    from repro.configs import get_shape
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import build_model
    from repro.models.params import param_structs
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.sharding.specs import decode_rules, infer_rules, train_rules
    from repro.training.train_step import make_train_state_defs, make_train_step

    shape = get_shape(shape_name)
    mesh = make_production_mesh()
    if rules_override is not None:
        rules = rules_override(mesh, cfg, shape)
    elif shape.kind == "decode":
        rules = decode_rules(mesh, kv_heads=cfg.num_kv_heads or None, batch=shape.global_batch)
    elif shape.kind == "prefill":
        rules = infer_rules(mesh, kv_heads=cfg.num_kv_heads or None)
    else:
        rules = train_rules(mesh)
    model = build_model(cfg, rules)
    t0 = time.perf_counter()
    with mesh:
        if shape.kind == "train":
            ss = param_structs(make_train_state_defs(model), mesh, rules)
            bs = param_structs(model.input_defs(shape), mesh, rules)
            step = make_train_step(model, AdamWConfig(), cosine_schedule(3e-4, 100, 10000))
            compiled = jax.jit(step, donate_argnums=0).lower(ss, bs).compile()
        elif shape.kind == "prefill":
            ps = param_structs(model.param_defs, mesh, rules)
            ins = param_structs(model.input_defs(shape), mesh, rules)
            compiled = jax.jit(model.prefill_fn).lower(ps, ins).compile()
        else:
            ps = param_structs(model.param_defs, mesh, rules)
            ins = param_structs(model.input_defs(shape), mesh, rules)
            cs = param_structs(model.cache_defs(shape), mesh, rules)
            compiled = jax.jit(model.decode_fn, donate_argnums=2).lower(ps, ins, cs).compile()
    s = analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    footprint = ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes
    HW = {"c": 197e12, "m": 819e9, "i": 50e9}
    terms = {
        "compute_s": s.flops / HW["c"],
        "memory_s": s.bytes / HW["m"],
        "collective_s": s.collective_bytes / HW["i"],
    }
    return {
        "terms": {k: round(v, 6) for k, v in terms.items()},
        "dominant": max(terms, key=terms.get),
        "bound_s": max(terms.values()),
        "hbm_gb": round(footprint / 2**30, 3),
        "collective_detail": {k: (v["count"], round(v["bytes"] / 1e9, 3)) for k, v in s.collective_detail.items()},
        "top_collectives": [
            {
                "op": r["op"],
                "gb": round(r["total_bytes"] / 1e9, 2),
                "per_op_mb": round(r["per_op_bytes"] / 1e6, 2),
                "trips": r["trips"],
                "line": r["line"][:120],
            }
            for r in s.top_collectives[:8]
        ],
        "compile_s": round(time.perf_counter() - t0, 1),
    }


# ---------------------------------------------------------------- variants

def variant_baseline(cfg):
    return cfg


def variant_moe_ep_local(cfg):
    """EP-local dispatch/combine inside shard_map (psum_scatter combine)."""
    return dataclasses.replace(cfg, moe_impl="dropping_ep")


def variant_no_remat(cfg):
    return dataclasses.replace(cfg, remat=False)


def variant_more_microbatches(cfg):
    return dataclasses.replace(cfg, microbatches=max(2, cfg.microbatches * 2))


def variant_kv_fp8(cfg):
    """fp8 (e4m3) KV cache: halves decode cache reads + residency."""
    return dataclasses.replace(cfg, kv_cache_dtype="float8_e4m3fn")


VARIANTS = {
    "baseline": variant_baseline,
    "moe_ep_local": variant_moe_ep_local,
    "no_remat": variant_no_remat,
    "more_microbatches": variant_more_microbatches,
    "kv_fp8": variant_kv_fp8,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    args = ap.parse_args()
    from repro.configs import get_arch

    cfg = VARIANTS[args.variant](get_arch(args.arch))
    out = lower_cell(cfg, args.shape)
    out.update(arch=args.arch, shape=args.shape, variant=args.variant)
    print(json.dumps(out, indent=2))
    os.makedirs("results", exist_ok=True)
    with open("results/perf_experiments.jsonl", "a") as f:
        f.write(json.dumps(out) + "\n")


if __name__ == "__main__":
    main()
