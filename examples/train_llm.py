"""Train a ~100M-param llama-family model for a few hundred steps on the
learnable synthetic stream, with checkpointing and fault tolerance on.

Default runs a CPU-sized config quickly; pass --full-100m for the real 100M
(slow on this 1-core host, same code path).

  PYTHONPATH=src python examples/train_llm.py --steps 200
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.checkpointing import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.models.params import param_count
from repro.optim import AdamWConfig, cosine_schedule
from repro.training import TrainLoop
from repro.training.train_step import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-100m", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_llm")
args = ap.parse_args()

if args.full_100m:
    cfg = ModelConfig(
        name="llama-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_head=64, d_ff=2048, vocab_size=32000,
        act="silu", norm="rmsnorm", remat=False,
    )
    shape = ShapeConfig("train", 512, 8, "train")
else:
    cfg = ModelConfig(
        name="llama-mini", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_head=64, d_ff=688, vocab_size=4096,
        act="silu", norm="rmsnorm", remat=False,
    )
    shape = ShapeConfig("train", 128, 8, "train")

model = build_model(cfg)
print(f"model: {cfg.name}  params={param_count(model.param_defs)/1e6:.1f}M")
step_fn = make_train_step(model, AdamWConfig(lr=3e-3), cosine_schedule(3e-3, 20, args.steps))
state = init_train_state(model, jax.random.PRNGKey(0))
loop = TrainLoop(
    step_fn,
    lambda start: SyntheticTokenPipeline(cfg, shape, seed=0, mode="affine", start_batch=start),
    CheckpointManager(args.ckpt_dir, retain=2, async_save=True),
    ckpt_every=50,
)
state, history = loop.run(state, args.steps)
for h in history[:: max(1, args.steps // 10)]:
    print(f"step {h['step']:4d}  loss {h['loss']:8.4f}  {h['seconds']*1e3:6.0f} ms")
print(f"final loss: {history[-1]['loss']:.4f} (start {history[0]['loss']:.4f})")
print(f"stragglers flagged: {len(loop.straggler_events)}; checkpoints: {loop.manager.all_steps()}")
