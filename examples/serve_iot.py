"""End-to-end serving driver (the paper's IOT workload): deploy the
5-function IoT analytics app, serve a constant 5 req/s stream, and watch
median latency drop as the platform fuses the synchronous group at runtime —
the Fig. 5 experiment in miniature.

  PYTHONPATH=src python examples/serve_iot.py [--requests 100] [--backend orchestrated]
"""
import argparse
import sys
import time

sys.path.insert(0, ".")

import numpy as np

from benchmarks.apps import deploy_iot, make_request
from repro.core import FusionPolicy, OrchestratedBackend, TinyJaxBackend

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=100)
ap.add_argument("--rate", type=float, default=5.0)
ap.add_argument("--backend", default="tinyjax", choices=["tinyjax", "orchestrated"])
ap.add_argument("--no-fusion", action="store_true")
args = ap.parse_args()

Backend = TinyJaxBackend if args.backend == "tinyjax" else OrchestratedBackend
platform = Backend(FusionPolicy(min_observations=3, merge_cost_s=0.0, enabled=not args.no_fusion))
entry = deploy_iot(platform)

for i in range(3):  # cold-start warmup
    platform.invoke(entry, make_request(i))

period = 1.0 / args.rate
t0 = time.perf_counter()
lat = []
merge_seen = 0
for i in range(args.requests):
    target = t0 + i * period
    if time.perf_counter() < target:
        time.sleep(target - time.perf_counter())
    s = time.perf_counter()
    platform.invoke(entry, make_request(i))
    lat.append((time.perf_counter() - s) * 1e3)
    merges = [m for m in platform.merger.merge_log if m.healthy]
    if len(merges) > merge_seen:
        merge_seen = len(merges)
        print(f"  >>> merge #{merge_seen} completed at t={time.perf_counter()-t0:.1f}s: {merges[-1].members}")
    if i % 20 == 19:
        print(f"t={time.perf_counter()-t0:5.1f}s  requests={i+1:4d}  median(last 20)={np.median(lat[-20:]):7.2f} ms")

half = len(lat) // 2
print(f"\nfirst-half median: {np.median(lat[:half]):.2f} ms")
print(f"second-half median: {np.median(lat[half:]):.2f} ms")
print(f"reduction: {100*(1-np.median(lat[half:])/np.median(lat[:half])):.1f}% (paper IOT: 28.9%)")
print(f"RAM: {platform.ram_bytes()/1e6:.1f} MB; billing: {platform.meter.summary()['total_gb_s']:.4f} GB-s")
platform.shutdown()
