"""Quickstart: deploy three functions, send traffic, watch Provuse fuse them.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import FunctionSpec, FusionPolicy, TinyJaxBackend

# --- user code: three independent functions; preprocess calls the others ---
w_embed = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.05
w_score = jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 0.05


def normalize(ctx, params, x):
    return (x - x.mean(axis=-1, keepdims=True)) / (x.std(axis=-1, keepdims=True) + 1e-6)


def score(ctx, params, x):
    return jnp.tanh(x @ params).sum(axis=-1)


def preprocess(ctx, params, x):
    h = jnp.tanh(x @ params)
    h = ctx.call("normalize", h)   # synchronous -> fusion candidate
    return ctx.call("score", h)    # synchronous -> fusion candidate


# --- platform side: nothing special, just deploy ---
platform = TinyJaxBackend(FusionPolicy(min_observations=3, merge_cost_s=0.0))
platform.deploy(FunctionSpec("preprocess", preprocess, w_embed))
platform.deploy(FunctionSpec("normalize", normalize, None))
platform.deploy(FunctionSpec("score", score, w_score))

x = jnp.ones((8, 128))
for i in range(10):
    t0 = time.perf_counter()
    out = platform.invoke("preprocess", x)
    dt = (time.perf_counter() - t0) * 1e3
    insts = len(platform.registry.live_instances())
    print(f"request {i:2d}: {dt:8.2f} ms   live instances: {insts}")

print("\nmerge log:")
for m in platform.merger.merge_log:
    print(f"  {'OK ' if m.healthy else 'ABORT'} {m.members} (build {m.build_s:.2f}s, freed {m.freed_bytes} B)")
print("\nedges observed:", platform.handler.stats())
platform.shutdown()
