"""Dry-run integration: one real cell compiles on the production mesh in a
subprocess and reports coherent roofline terms. (The full 40-cell x 2-mesh
grid runs via `python -m repro.launch.dryrun --all --both-meshes`; its
results are recorded in EXPERIMENTS.md.)"""
import json
import subprocess
import sys

import pytest


def run_cell(arch, shape, extra=()):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape, *extra],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout)


@pytest.mark.slow
def test_llama_decode_cell_production_mesh():
    r = run_cell("llama3.2-1b", "decode_32k")
    assert r["status"] == "ok"
    assert r["n_chips"] == 256
    assert r["fits_16gb"], f"HBM {r['hbm_per_device_gb']} GB over budget"
    rf = r["roofline"]
    assert rf["bound_s"] > 0
    assert rf["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert r["flops_per_device"] > 0
    assert 0 < r["useful_flops_ratio"] < 4


@pytest.mark.slow
def test_multi_pod_mesh_cell():
    r = run_cell("llama3.2-1b", "decode_32k", ("--multi-pod",))
    assert r["status"] == "ok"
    assert r["n_chips"] == 512


def test_long_500k_skips_full_attention_archs():
    from repro.configs import get_arch, shape_skip_reason

    assert shape_skip_reason(get_arch("llama3.2-1b"), "long_500k")
    assert shape_skip_reason(get_arch("qwen3-moe-30b-a3b"), "long_500k")
    assert shape_skip_reason(get_arch("mamba2-370m"), "long_500k") is None
    assert shape_skip_reason(get_arch("zamba2-7b"), "long_500k") is None
