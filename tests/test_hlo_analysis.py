"""Validate the loop-aware HLO cost analyzer against known-FLOPs programs.

These tests compile tiny programs in a SUBPROCESS with a forced multi-device
host platform (the test process itself must keep the default 1-device view).
"""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import sys
    sys.path.insert(0, "src")
    from repro.launch.hlo_analysis import analyze

    kw = {}
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5; Auto is the default before
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    mesh = jax.make_mesh((4, 2), ("data", "model"), devices=jax.devices(), **kw)
    L, B, D = 12, 64, 128

    def f(x, ws):
        def body(c, w):
            h = jnp.tanh(c @ w)
            h = jax.lax.with_sharding_constraint(h, P("data", "model"))
            return h, None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32, sharding=NamedSharding(mesh, P("data", None)))
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32, sharding=NamedSharding(mesh, P(None, None, "model")))
    with mesh:
        compiled = jax.jit(f).lower(xs, ws).compile()
    s = analyze(compiled.as_text())
    raw = compiled.cost_analysis()
    if isinstance(raw, (list, tuple)):  # jax < 0.5 wraps it in a list
        raw = raw[0]
    print(json.dumps({
        "flops": s.flops,
        "bytes": s.bytes,
        "collective_bytes": s.collective_bytes,
        "while_trips": s.while_trips,
        "raw_flops": raw["flops"],
    }))
    """
)


@pytest.fixture(scope="module")
def analysis():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_while_trip_count_detected(analysis):
    assert 12 in analysis["while_trips"].values()


def test_loop_scaled_flops_match_analytic(analysis):
    # per-device matmul flops: L * 2*B*D*D / (4 dp * 2 tp shards)
    expect = 12 * 2 * 64 * 128 * 128 / 8
    assert analysis["flops"] == pytest.approx(expect, rel=0.05)
    # and the raw XLA count must be ~L x smaller (the bug we correct)
    assert analysis["raw_flops"] < analysis["flops"] / 6


def test_collectives_scaled_by_trips(analysis):
    # one all-gather per layer inside the loop -> nonzero collective traffic
    assert analysis["collective_bytes"] > 0


def test_parser_robust_to_garbage():
    from repro.launch.hlo_analysis import analyze

    s = analyze("HloModule junk\n\nnot an hlo line at all\n")
    assert s.flops == 0.0
