"""Request scheduler: coalescing mechanics (no platform), then batched
dispatch through both backends — correctness, billing, stats."""
import threading
import time
from concurrent.futures import wait

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FunctionSpec, FusionPolicy, OrchestratedBackend, TinyJaxBackend
from repro.scheduler import RequestScheduler, percentiles_ms
from repro.scheduler.batching import next_batch_bucket

BACKENDS = [TinyJaxBackend, OrchestratedBackend]


# --------------------------------------------------------------- pure units


def test_percentiles_ms_nearest_rank():
    samples = [i / 1e3 for i in range(1, 101)]  # 1..100 ms
    p = percentiles_ms(samples)
    assert p["p50_ms"] == pytest.approx(50.0)
    assert p["p95_ms"] == pytest.approx(95.0)
    assert p["p99_ms"] == pytest.approx(99.0)
    assert percentiles_ms([]) == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}


def test_percentiles_ms_ceil_rank_pinned():
    """Explicit ceil-based nearest rank: rank = ceil(p/100 * n), 1-indexed.
    Python's round() is half-even and landed one rank low on exact halves
    (p50 of 5 samples used to report the 2nd sample, not the median)."""
    five = [i / 1e3 for i in (1, 2, 3, 4, 5)]
    p = percentiles_ms(five)
    assert p["p50_ms"] == pytest.approx(3.0)  # true median, was 2.0
    assert p["p95_ms"] == pytest.approx(5.0)
    ten = [i / 1e3 for i in range(1, 11)]
    p = percentiles_ms(ten)
    assert p["p50_ms"] == pytest.approx(5.0)  # ceil(0.5*10) = rank 5
    assert p["p95_ms"] == pytest.approx(10.0)  # ceil(9.5) = rank 10
    assert p["p99_ms"] == pytest.approx(10.0)
    assert percentiles_ms([0.004], points=(50,))["p50_ms"] == pytest.approx(4.0)


def test_next_batch_bucket_pow2_capped():
    assert [next_batch_bucket(k, 8) for k in (1, 2, 3, 5, 8, 9, 30)] == [1, 2, 4, 8, 8, 8, 8]
    assert [next_batch_bucket(k) for k in (1, 3, 5, 9)] == [1, 4, 8, 16]  # uncapped


def test_next_batch_bucket_non_pow2_cap_never_leaks_odd_bucket():
    """A non-power-of-two max_batch must clamp to the largest power of two
    BELOW it — bucket 6 would be a one-off compile nothing else reuses."""
    assert [next_batch_bucket(k, 6) for k in (1, 2, 3, 4, 5, 6, 9)] == [1, 2, 4, 4, 4, 4, 4]
    assert [next_batch_bucket(k, 12) for k in (5, 9, 12)] == [8, 8, 8]
    assert next_batch_bucket(3, 1) == 1
    for cap in range(1, 33):
        for k in range(1, 40):
            b = next_batch_bucket(k, cap)
            assert b & (b - 1) == 0, f"bucket {b} (k={k}, cap={cap}) not a power of two"
            assert b <= cap


def test_stack_then_split_roundtrips_requests():
    from repro.scheduler.batching import split_results, stack_requests

    reqs = [({"x": jnp.full((2, 3), float(i))}, jnp.int32(i)) for i in range(3)]
    stacked = stack_requests(reqs)
    assert stacked[0]["x"].shape == (3, 2, 3)
    back = split_results(stacked, 3)
    for i, (tree, scalar) in enumerate(back):
        np.testing.assert_array_equal(np.asarray(tree["x"]), np.full((2, 3), float(i)))
        assert int(scalar) == i


# ------------------------------------------------------- coalescer (no jax)


def make_scheduler(dispatch, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay_ms", 50.0)
    return RequestScheduler(dispatch, **kw)


def test_coalescer_groups_requests_within_window():
    batches = []

    def dispatch(name, args_list):
        batches.append(len(args_list))
        time.sleep(0.02)  # hold the dispatcher so later submits coalesce
        return [a[0] * 10 for a in args_list]

    sched = make_scheduler(dispatch)
    try:
        futs = [sched.submit("f", (i,)) for i in range(10)]
        done, not_done = wait(futs, timeout=10)
        assert not not_done
        assert [f.result() for f in futs] == [i * 10 for i in range(10)]
        assert sum(batches) == 10
        assert max(batches) > 1, "concurrent submits must coalesce"
        assert all(b <= 4 for b in batches)
        st = sched.stats()
        assert st["requests"] == 10 and st["throughput_rps"] > 0
    finally:
        sched.shutdown()


def test_incompatible_shapes_use_separate_queues():
    seen = []

    def dispatch(name, args_list):
        shapes = {np.asarray(a[0]).shape for a in args_list}
        seen.append(shapes)
        return [a[0] for a in args_list]

    sched = make_scheduler(dispatch)
    try:
        futs = [sched.submit("f", (np.zeros(s),)) for s in (2, 3, 2, 3, 2)]
        wait(futs, timeout=10)
        assert sched.stats()["queues"] == 2
        for shapes in seen:
            assert len(shapes) == 1, "a batch must never mix request shapes"
    finally:
        sched.shutdown()


def test_dispatch_exception_reaches_every_future():
    def dispatch(name, args_list):
        raise ValueError("boom")

    sched = make_scheduler(dispatch)
    try:
        futs = [sched.submit("f", (i,)) for i in range(3)]
        wait(futs, timeout=10)
        for f in futs:
            with pytest.raises(ValueError, match="boom"):
                f.result()
    finally:
        sched.shutdown()


def test_raising_metrics_callback_cannot_hang_futures():
    """Regression (PR 2): `_run_batch` used to invoke on_batch_done BEFORE
    resolving futures and outside the try — one raising metrics sink (e.g. a
    billing meter) stranded every client in the batch on an unresolved
    future forever. Futures resolve first; metrics failures are swallowed."""
    def bad_sink(name, lat_s, k):
        raise RuntimeError("billing meter exploded")

    def dispatch(name, args_list):
        time.sleep(0.02)  # hold the dispatcher so submits coalesce
        return [a[0] * 10 for a in args_list]

    sched = make_scheduler(dispatch, on_request_done=bad_sink)
    try:
        futs = [sched.submit("f", (i,)) for i in range(6)]
        done, not_done = wait(futs, timeout=5)
        assert not not_done, "a raising metrics callback must not hang client futures"
        assert [f.result() for f in futs] == [i * 10 for i in range(6)]
        # the dispatcher thread survived and keeps serving the key
        assert sched.submit("f", (7,)).result(timeout=5) == 70
    finally:
        sched.shutdown()


def test_raising_on_batch_done_resolves_futures_and_keeps_dispatcher():
    """Same invariant one layer down, with the batch-level callback itself
    raising (the scheduler's _record_batch is only one possible sink)."""
    from repro.scheduler import AdmissionQueue, PendingRequest
    from concurrent.futures import Future

    def boom(name, batch, t_done):
        raise ValueError("metrics sink down")

    q = AdmissionQueue("f", lambda name, args_list: [a[0] for a in args_list],
                       max_batch=4, max_delay_s=0.02, on_batch_done=boom)
    try:
        reqs = [PendingRequest((i,), Future(), time.perf_counter()) for i in range(3)]
        for r in reqs:
            q.put(r)
        done, not_done = wait([r.future for r in reqs], timeout=5)
        assert not not_done
        assert [r.future.result() for r in reqs] == [0, 1, 2]
        assert q.thread.is_alive()
    finally:
        q.stop()
        q.thread.join(timeout=5)


def test_result_count_mismatch_is_an_error():
    sched = make_scheduler(lambda name, args_list: [0])  # always one result
    try:
        futs = [sched.submit("f", (1,)), sched.submit("f", (2,))]
        wait(futs, timeout=10)
        errs = [f for f in futs if f.exception() is not None]
        assert errs, "short result lists must fail loudly, not drop requests"
    finally:
        sched.shutdown()


def test_shutdown_stops_dispatchers_and_rejects_submits():
    sched = make_scheduler(lambda name, args_list: [a[0] for a in args_list])
    fut = sched.submit("f", (1,))
    assert fut.result(timeout=10) == 1
    sched.shutdown()
    assert all(not q.thread.is_alive() for q in sched._queues.values())
    with pytest.raises(RuntimeError):
        sched.submit("f", (2,))


def test_idle_dispatcher_retires_then_fresh_queue_serves():
    """Virtual clock: the 60s idle timeout elapses in simulated time — the
    retirement path costs zero wall-clock waiting."""
    from repro.scheduler import VirtualClock

    clock = VirtualClock()
    sched = make_scheduler(
        lambda name, args_list: [a[0] for a in args_list],
        idle_timeout_s=60.0, max_delay_ms=0.0, clock=clock,
    )
    try:
        assert sched.submit("f", (1,)).result(timeout=10) == 1
        q = next(iter(sched._queues.values()))
        clock.wait_for_waiters(1)
        clock.advance(61.0)  # virtual idle timeout expires
        q.thread.join(timeout=10)
        assert not q.thread.is_alive()
        assert sched.stats()["queues"] == 0
        # the key still serves: a fresh queue spins up transparently
        assert sched.submit("f", (2,)).result(timeout=10) == 2
        clock.assert_elapsed_real_below(10.0)
    finally:
        sched.shutdown()


# ----------------------------------------------------- platform integration


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_batched_matches_serial_on_leaf(backend_cls):
    p = backend_cls(FusionPolicy(enabled=False), max_batch=4, max_delay_ms=10.0)
    try:
        w = jnp.asarray(np.random.RandomState(0).randn(16, 16).astype(np.float32) * 0.1)
        p.deploy(FunctionSpec("leaf", lambda ctx, params, x: jnp.tanh(x @ params), w))
        xs = [jnp.full((3, 16), float(i) / 7) for i in range(11)]  # odd count: pads a bucket
        ref = [p.invoke("leaf", x) for x in xs]
        futs = [p.invoke_async("leaf", x) for x in xs]
        done, not_done = wait(futs, timeout=60)
        assert not not_done
        for f, r in zip(futs, ref):
            np.testing.assert_allclose(np.asarray(f.result()), np.asarray(r), rtol=1e-5, atol=1e-6)
        assert p.scheduler.stats()["max_batch_seen"] > 1
    finally:
        p.shutdown()


def test_non_pow2_max_batch_clamps_and_chunks_pow2():
    """A non-power-of-two max_batch must never mint a bucket-6 program (a
    one-off compile nothing reuses). Two layers enforce it: the scheduler
    clamps max_batch to the largest power of two below it (batches of 6
    never form), and execute_batch — for direct callers — splits oversized
    batches into power-of-two chunks."""
    p = TinyJaxBackend(FusionPolicy(enabled=False), max_batch=6, max_delay_ms=60.0)
    try:
        assert p.scheduler.max_batch == 4  # clamped at construction
        w = jnp.asarray(np.random.RandomState(2).randn(8, 8).astype(np.float32) * 0.1)
        p.deploy(FunctionSpec("leaf", lambda ctx, params, x: jnp.tanh(x @ params), w))
        xs = [jnp.full((2, 8), float(i) / 5) for i in range(6)]
        ref = [p.invoke("leaf", x) for x in xs]
        futs = [p.invoke_async("leaf", x) for x in xs]
        done, not_done = wait(futs, timeout=60)
        assert not not_done
        for f, r in zip(futs, ref):
            np.testing.assert_allclose(np.asarray(f.result()), np.asarray(r), rtol=1e-5, atol=1e-6)
        # the chunk fallback: a direct 6-request execute_batch runs as 4+2
        inst = p.registry.resolve("leaf")
        out = inst.execute_batch("leaf", [(x,) for x in xs], max_bucket=6)
        for got, r in zip(out, ref):
            np.testing.assert_allclose(np.asarray(got), np.asarray(r), rtol=1e-5, atol=1e-6)
        buckets = [key[3] for key in inst._compiled if len(key) == 4 and key[0] == "__batch__"]
        assert buckets, "batched buckets must have compiled"
        for b in buckets:
            assert b & (b - 1) == 0, f"non-power-of-two bucket {b} compiled"
    finally:
        p.shutdown()


def test_batched_billing_one_record_per_request_and_split_gbs():
    p = TinyJaxBackend(FusionPolicy(enabled=False), max_batch=8, max_delay_ms=10.0)
    try:
        w = jnp.eye(8)
        p.deploy(FunctionSpec("leaf", lambda ctx, params, x: x @ params, w))
        p.invoke("leaf", jnp.ones((2, 8)))  # warm the unbatched compile
        p.meter.reset()
        futs = [p.invoke_async("leaf", jnp.ones((2, 8)) * i) for i in range(8)]
        wait(futs, timeout=60)
        recs = [r for r in p.meter.records if r.function == "leaf"]
        assert len(recs) == 8, "one billing record per client request"
        batched = [r for r in recs if r.batch_size > 1]
        assert batched, "micro-batching must have grouped some requests"
        # co-batched records split the instance-hold cost: summing the batch
        # reproduces duration * resident_bytes once, not k times
        by_batch = {}
        for r in batched:
            by_batch.setdefault((r.t_start, r.t_end), []).append(r)
        for (t0, t1), group in by_batch.items():
            assert len(group) == group[0].batch_size
            total = sum(r.gb_seconds for r in group)
            assert total == pytest.approx((t1 - t0) * group[0].resident_bytes / 1e9, rel=1e-6)
    finally:
        p.shutdown()


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_invoke_async_works_on_boundary_entries(backend_cls):
    """Pre-fusion chain entries can't compile as one program; the batch path
    must fall back to per-request execution, never fail."""
    p = backend_cls(FusionPolicy(enabled=False), max_batch=4, max_delay_ms=10.0)
    try:
        w = jnp.eye(8) * 0.5
        p.deploy(FunctionSpec("A", lambda ctx, params, x: ctx.call("B", x @ params), w))
        p.deploy(FunctionSpec("B", lambda ctx, params, x: jnp.tanh(x @ params), w))
        xs = [jnp.full((2, 8), float(i)) for i in range(6)]
        ref = [p.invoke("A", x) for x in xs]
        futs = [p.invoke_async("A", x) for x in xs]
        wait(futs, timeout=60)
        for f, r in zip(futs, ref):
            np.testing.assert_allclose(np.asarray(f.result()), np.asarray(r), rtol=1e-5, atol=1e-6)
    finally:
        p.shutdown()


def test_async_effects_never_replayed_by_batch_padding():
    """Bucket padding duplicates the last request's args; a fire-and-forget
    ctx.call_async in the entry would fire once per padded vmap lane. Such
    effectful entries must fall back to per-request execution."""
    p = TinyJaxBackend(FusionPolicy(enabled=False), max_batch=8, max_delay_ms=20.0)
    try:
        p.deploy(FunctionSpec("D", lambda ctx, params, x: (x * x).sum(), None))

        def fn_a(ctx, params, x):
            ctx.call_async("D", x)
            return x + 1

        p.deploy(FunctionSpec("A", fn_a, None))
        # 3 concurrent requests pad to a 4-bucket: lanes 4 would replay req 3
        futs = [p.invoke_async("A", jnp.full((2,), float(i))) for i in range(3)]
        wait(futs, timeout=60)
        for i, f in enumerate(futs):
            np.testing.assert_allclose(np.asarray(f.result()), np.full((2,), i + 1.0))
        # bounded poll (not a fixed sleep) for the fire-and-forget D
        # invocations to drain through the async pool: typically a few ms
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            if sum(1 for r in p.meter.records if r.function == "D") >= 3:
                break
            time.sleep(0.005)
        time.sleep(0.05)  # a short grace: a 4th (replayed) call must NOT appear
        d_calls = sum(1 for r in p.meter.records if r.function == "D")
        assert d_calls == 3, f"padded lanes must not replay side effects (D ran {d_calls}x)"
    finally:
        p.shutdown()


def test_stats_report_latency_percentiles_and_throughput():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        p.deploy(FunctionSpec("f", lambda ctx, params, x: x + 1, None))
        for i in range(5):
            p.invoke("f", jnp.float32(i))
        wait([p.invoke_async("f", jnp.float32(9))], timeout=30)
        st = p.stats()
        for key in ("p50_ms", "p95_ms", "p99_ms", "throughput_rps"):
            assert key in st["latency"], st["latency"]
            assert key in st["scheduler"] or key == "throughput_rps", st["scheduler"]
        assert st["latency"]["requests"] == 6  # serial + scheduled both counted
        assert st["latency"]["p99_ms"] >= st["latency"]["p50_ms"] > 0
        assert st["scheduler"]["requests"] == 1
    finally:
        p.shutdown()


def test_shutdown_is_idempotent_and_stops_scheduler():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    p.deploy(FunctionSpec("f", lambda ctx, params, x: x, None))
    wait([p.invoke_async("f", jnp.float32(1))], timeout=30)
    p.shutdown()
    p.shutdown()
    with pytest.raises(RuntimeError):
        p.invoke_async("f", jnp.float32(2))


def test_batched_execution_coalesces_under_contention():
    """Closed-loop clients must actually ride in shared batches (the
    throughput mechanism), not just trickle through one by one."""
    p = TinyJaxBackend(FusionPolicy(enabled=False), max_batch=4, max_delay_ms=25.0)
    try:
        w = jnp.asarray(np.random.RandomState(1).randn(12, 12).astype(np.float32) * 0.1)
        p.deploy(FunctionSpec("leaf", lambda ctx, params, x: jnp.tanh(x @ params), w))
        wait([p.invoke_async("leaf", jnp.ones((2, 12)))], timeout=60)  # compile bucket 1

        stop = time.perf_counter() + 0.6
        def client():
            while time.perf_counter() < stop:
                p.invoke_async("leaf", jnp.ones((2, 12))).result(timeout=30)

        threads = [threading.Thread(target=client) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert p.scheduler.stats()["mean_batch"] > 1.2
    finally:
        p.shutdown()
