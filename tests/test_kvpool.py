"""KVArena allocator invariants (seeded fuzz) + page data round-trips."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kvpool import ArenaFull, KVArena


def make_arena(num_pages=16, page=8, stages=None):
    return KVArena(
        stages or {"g0": 2, "g1": 2},
        num_pages=num_pages,
        page_size=page,
        kv_heads=2,
        head_dim=4,
        dtype=jnp.float32,
    )


def test_alloc_extend_free_roundtrip():
    a = make_arena()
    pages = a.alloc("s1", 10)  # 2 pages of 8
    assert len(pages) == 2 and a.pages_held("s1") == 2
    assert a.RESERVED_PAGE not in pages
    added = a.extend("s1", 17)  # crosses into a 3rd page
    assert len(added) == 1 and a.pages_held("s1") == 3
    assert a.extend("s1", 18) == []  # same page
    row = a.block_row("s1", 5)
    assert list(row[:3]) == pages + added and list(row[3:]) == [0, 0]
    assert a.peak_pages("s1") == 3
    assert a.free("s1") == 3
    assert a.free("s1") == 0  # idempotent
    a.check_consistency()


def test_arena_full_allocates_nothing():
    a = make_arena(num_pages=4)  # 3 usable
    a.alloc("s1", 16)  # 2 pages
    with pytest.raises(ArenaFull):
        a.alloc("s2", 17)  # needs 3
    assert a.pages_held("s2") == 0
    a.check_consistency()
    a.alloc("s2", 8)  # 1 page still fits
    a.check_consistency()


def test_double_alloc_and_shrink_rejected():
    a = make_arena()
    a.alloc("s1", 8)
    with pytest.raises(ValueError):
        a.alloc("s1", 8)
    with pytest.raises(ValueError):
        a.extend("s1", 4)
    with pytest.raises(KeyError):
        a.extend("ghost", 9)


def test_alloc_free_fuzz_no_double_use_no_leak():
    """Seeded random alloc/extend/free storm; after every op the arena must
    satisfy: every page in exactly one place, rows cover lengths, page 0
    never handed out. After all clients exit, zero pages leak."""
    rng = random.Random(1234)
    a = make_arena(num_pages=24, page=4)
    live: dict[int, int] = {}  # seq -> len
    next_id = 0
    for _ in range(600):
        op = rng.random()
        if op < 0.4 and len(live) < 10:
            length = rng.randint(1, 40)
            sid = next_id
            next_id += 1
            try:
                a.alloc(sid, length)
                live[sid] = length
            except ArenaFull:
                assert a.free_pages() < a.pages_for(length)
        elif op < 0.75 and live:
            sid = rng.choice(list(live))
            new_len = live[sid] + rng.randint(1, 12)
            try:
                a.extend(sid, new_len)
                live[sid] = new_len
            except ArenaFull:
                pass
        elif live:
            sid = rng.choice(list(live))
            freed = a.free(sid)
            assert freed == a.pages_for(live.pop(sid))
        a.check_consistency()
    for sid in list(live):
        a.free(sid)
    a.check_consistency()
    assert a.used_pages() == 0
    assert a.free_pages() == a.num_pages - 1  # page 0 reserved, all else free


def test_write_prefill_gather_roundtrip():
    """Scattered prefill pages gather back to the dense source (valid
    region) through the block table."""
    a = make_arena(num_pages=12, page=8, stages={"g0": 3})
    length = 19  # 3 pages, last partially valid
    a.alloc("s", length)
    src = jax.random.normal(jax.random.PRNGKey(0), (3, 1, 24, 2, 4), jnp.float32)
    a.write_prefill("s", {"g0": {"k": src, "v": src * 2.0}}, length)
    got = a.gather("s", "g0")
    np.testing.assert_array_equal(np.asarray(got["k"][:, :24]), np.asarray(src[:, 0]))
    np.testing.assert_array_equal(np.asarray(got["v"][:, :24]), np.asarray(src[:, 0] * 2.0))
    # a second tenant reusing freed pages sees only its own data
    a.free("s")
    a.alloc("t", 8)
    src2 = jnp.ones((3, 1, 8, 2, 4), jnp.float32) * 7.0
    a.write_prefill("t", {"g0": {"k": src2, "v": src2}}, 8)
    got2 = a.gather("t", "g0")
    np.testing.assert_array_equal(np.asarray(got2["k"][:, :8]), np.asarray(src2[:, 0]))


def test_page_bytes_covers_all_stages():
    a = make_arena(stages={"g0": 3, "g1": 5})
    # 2 (k+v) x page 8 x kv 2 x hd 4 x f32(4B) x 8 layers
    assert a.page_bytes == 2 * 8 * 2 * 4 * 4 * 8
