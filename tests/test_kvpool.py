"""KVArena allocator invariants (seeded fuzz) + page data round-trips +
concurrency regressions + shared-prefix refcounting/CoW."""
import random
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import InstrumentedLock, LockGraph
from repro.serving.kvpool import ArenaFull, KVArena


def make_arena(num_pages=16, page=8, stages=None):
    return KVArena(
        stages or {"g0": 2, "g1": 2},
        num_pages=num_pages,
        page_size=page,
        kv_heads=2,
        head_dim=4,
        dtype=jnp.float32,
    )


def test_alloc_extend_free_roundtrip():
    a = make_arena()
    pages = a.alloc("s1", 10)  # 2 pages of 8
    assert len(pages) == 2 and a.pages_held("s1") == 2
    assert a.RESERVED_PAGE not in pages
    added = a.extend("s1", 17)  # crosses into a 3rd page
    assert len(added) == 1 and a.pages_held("s1") == 3
    assert a.extend("s1", 18) == []  # same page
    row = a.block_row("s1", 5)
    assert list(row[:3]) == pages + added and list(row[3:]) == [0, 0]
    assert a.peak_pages("s1") == 3
    assert a.free("s1") == 3
    assert a.free("s1") == 0  # idempotent
    a.check_consistency()


def test_arena_full_allocates_nothing():
    a = make_arena(num_pages=4)  # 3 usable
    a.alloc("s1", 16)  # 2 pages
    with pytest.raises(ArenaFull):
        a.alloc("s2", 17)  # needs 3
    assert a.pages_held("s2") == 0
    a.check_consistency()
    a.alloc("s2", 8)  # 1 page still fits
    a.check_consistency()


def test_double_alloc_and_shrink_rejected():
    a = make_arena()
    a.alloc("s1", 8)
    with pytest.raises(ValueError):
        a.alloc("s1", 8)
    with pytest.raises(ValueError):
        a.extend("s1", 4)
    with pytest.raises(KeyError):
        a.extend("ghost", 9)


def test_alloc_free_fuzz_no_double_use_no_leak():
    """Seeded random alloc/extend/free storm; after every op the arena must
    satisfy: every page in exactly one place, rows cover lengths, page 0
    never handed out. After all clients exit, zero pages leak."""
    rng = random.Random(1234)
    a = make_arena(num_pages=24, page=4)
    live: dict[int, int] = {}  # seq -> len
    next_id = 0
    for _ in range(600):
        op = rng.random()
        if op < 0.4 and len(live) < 10:
            length = rng.randint(1, 40)
            sid = next_id
            next_id += 1
            try:
                a.alloc(sid, length)
                live[sid] = length
            except ArenaFull:
                assert a.free_pages() < a.pages_for(length)
        elif op < 0.75 and live:
            sid = rng.choice(list(live))
            new_len = live[sid] + rng.randint(1, 12)
            try:
                a.extend(sid, new_len)
                live[sid] = new_len
            except ArenaFull:
                pass
        elif live:
            sid = rng.choice(list(live))
            freed = a.free(sid)
            assert freed == a.pages_for(live.pop(sid))
        a.check_consistency()
    for sid in list(live):
        a.free(sid)
    a.check_consistency()
    assert a.used_pages() == 0
    assert a.free_pages() == a.num_pages - 1  # page 0 reserved, all else free


def test_write_prefill_gather_roundtrip():
    """Scattered prefill pages gather back to the dense source (valid
    region) through the block table."""
    a = make_arena(num_pages=12, page=8, stages={"g0": 3})
    length = 19  # 3 pages, last partially valid
    a.alloc("s", length)
    src = jax.random.normal(jax.random.PRNGKey(0), (3, 1, 24, 2, 4), jnp.float32)
    a.write_prefill("s", {"g0": {"k": src, "v": src * 2.0}}, length)
    got = a.gather("s", "g0")
    np.testing.assert_array_equal(np.asarray(got["k"][:, :24]), np.asarray(src[:, 0]))
    np.testing.assert_array_equal(np.asarray(got["v"][:, :24]), np.asarray(src[:, 0] * 2.0))
    # a second tenant reusing freed pages sees only its own data
    a.free("s")
    a.alloc("t", 8)
    src2 = jnp.ones((3, 1, 8, 2, 4), jnp.float32) * 7.0
    a.write_prefill("t", {"g0": {"k": src2, "v": src2}}, 8)
    got2 = a.gather("t", "g0")
    np.testing.assert_array_equal(np.asarray(got2["k"][:, :8]), np.asarray(src2[:, 0]))


def test_page_bytes_covers_all_stages():
    a = make_arena(stages={"g0": 3, "g1": 5})
    # 2 (k+v) x page 8 x kv 2 x hd 4 x f32(4B) x 8 layers
    assert a.page_bytes == 2 * 8 * 2 * 4 * 4 * 8


# ------------------------------------------------- concurrency regressions


class _BarrierDict(dict):
    """Stage-data dict whose reads rendezvous two threads: if both writers
    reach the read concurrently (the pre-fix unlocked RMW), both rebase on
    the same old array and one loses its pages. The fixed code serializes
    under the data lock, so the second thread never reaches the barrier and
    the wait times out harmlessly."""

    def __init__(self, *args, barrier):
        super().__init__(*args)
        self._barrier = barrier

    def __getitem__(self, key):
        try:
            self._barrier.wait(timeout=0.3)
        except threading.BrokenBarrierError:
            pass
        return super().__getitem__(key)


def test_write_prefill_concurrent_rmw_keeps_both_sequences():
    """Regression (unlocked device-array RMW): two concurrent prefills into
    the same stage must BOTH land — pre-fix, each rebased on the stale
    array and silently dropped the other's pages."""
    a = make_arena(num_pages=12, page=8, stages={"g0": 2})
    a.alloc("s1", 8)
    a.alloc("s2", 8)
    barrier = threading.Barrier(2)
    a.data["g0"] = _BarrierDict(a.data["g0"], barrier=barrier)
    src1 = jnp.ones((2, 1, 8, 2, 4), jnp.float32) * 3.0
    src2 = jnp.ones((2, 1, 8, 2, 4), jnp.float32) * 5.0
    errs = []

    def write(sid, src):
        try:
            a.write_prefill(sid, {"g0": {"k": src, "v": src}}, 8)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    t1 = threading.Thread(target=write, args=("s1", src1))
    t2 = threading.Thread(target=write, args=("s2", src2))
    t1.start(); t2.start(); t1.join(); t2.join()
    a.data["g0"] = dict(a.data["g0"])  # plain dict again for gather
    assert not errs
    np.testing.assert_array_equal(np.asarray(a.gather("s1", "g0")["k"]), np.asarray(src1[:, 0]))
    np.testing.assert_array_equal(np.asarray(a.gather("s2", "g0")["k"]), np.asarray(src2[:, 0]))


class _RacingExtendArena(KVArena):
    """Simulates a concurrent extend landing between a seq_len read and the
    page-list read: pre-fix, gather derived its default width from seq_len
    and then re-read the pages under a SECOND lock acquisition, so the
    interleaved extend made block_row raise a spurious ValueError."""

    def seq_len(self, seq_id):
        n = super().seq_len(seq_id)
        if n and seq_id in self._held:
            super().extend(seq_id, n + self.page_size)
        return n


def test_gather_width_snapshot_atomic_with_extend():
    a = _RacingExtendArena(
        {"g0": 2}, num_pages=16, page_size=8, kv_heads=2, head_dim=4, dtype=jnp.float32
    )
    a.alloc("s", 19)  # 3 pages
    got = a.gather("s", "g0")  # must not raise, must cover the 3-page snapshot
    assert got["k"].shape[1] == 3 * 8
    a.check_consistency()


def test_write_prefill_unknown_stage_raises_before_writing():
    """Regression (silent `continue` on unknown stages): a misspelled stage
    key must raise, and no stage may be partially written first."""
    a = make_arena(num_pages=12, page=8, stages={"g0": 2})
    a.alloc("s", 8)
    src = jnp.ones((2, 1, 8, 2, 4), jnp.float32)
    with pytest.raises(KeyError, match="gX"):
        a.write_prefill("s", {"g0": {"k": src, "v": src}, "gX": {"k": src, "v": src}}, 8)
    # validation happens before ANY write: g0 stayed zero
    assert not np.asarray(a.gather("s", "g0")["k"]).any()


# ------------------------------------------------- shared-prefix page cache


def _toks(*vals):
    return np.asarray(vals, np.int64)


def test_alloc_prefill_shares_committed_prefix_and_amortizes():
    a = make_arena(num_pages=16, page=4, stages={"g0": 2})
    p1, cached = a.alloc_prefill("a", _toks(*range(1, 11)))  # 10 toks: 2 full + tail
    assert cached == 0 and len(p1) == 3
    # pre-commit: the index is not live yet (pages not written)
    _, cached_pre = a.alloc_prefill("pre", _toks(*range(1, 11)))
    assert cached_pre == 0
    a.free("pre")
    a.commit_prefill("a")
    # same first 8 tokens, different tail: the 2 FULL pages are shared
    p2, cached2 = a.alloc_prefill("b", _toks(1, 2, 3, 4, 5, 6, 7, 8, 99, 98))
    a.commit_prefill("b")
    assert cached2 == 8 and p2[:2] == p1[:2] and p2[2] != p1[2]
    assert a.shared_pages("b") == 2
    # shared pages split their bill: 2 pages at refcount 2 + 1 private
    assert a.amortized_pages("b") == pytest.approx(2 * 0.5 + 1.0)
    # an exact repeat prompt is a WHOLE-prompt hit (partial tail included)
    p3, cached3 = a.alloc_prefill("c", _toks(*range(1, 11)))
    assert cached3 == 10 and p3 == p1
    a.check_consistency()
    for s in ("a", "b", "c"):
        a.free(s)
    a.check_consistency()
    assert a.used_pages() == 0


def test_prefix_cache_survives_free_and_resurrects():
    """Freed pages keep their index entries (free-but-cached) until reused:
    a sequential repeat request still hits, pulling pages back off the free
    list."""
    a = make_arena(num_pages=16, page=4, stages={"g0": 2})
    prompt = _toks(*range(20, 30))
    pages, _ = a.alloc_prefill("x", prompt)
    a.commit_prefill("x")
    a.free("x")
    assert a.used_pages() == 0
    p2, cached = a.alloc_prefill("y", prompt)
    assert cached == 10 and p2 == pages and a.shared_hits == 1
    a.check_consistency()
    a.free("y")
    # allocation pressure reuses cached-free pages and purges their keys
    big = [a.alloc(("fill", i), 4 * 5) for i in range(3)]  # 3 x 5 pages = all 15
    assert sum(len(p) for p in big) == 15
    a.check_consistency()
    assert a.stats()["prefix_index"] == 0  # every cached page was evicted
    for i in range(3):
        a.free(("fill", i))
    _, cached3 = a.alloc_prefill("z", prompt)
    assert cached3 == 0  # cache was evicted, no stale hit
    a.check_consistency()


def test_make_private_copies_page_data_and_reroutes_row():
    a = make_arena(num_pages=16, page=4, stages={"g0": 2})
    prompt = _toks(7, 7, 7, 7, 8, 8)  # 1 full page + tail
    a.alloc_prefill("a", prompt)
    src = jnp.arange(2 * 8 * 2 * 4, dtype=jnp.float32).reshape(2, 1, 8, 2, 4)
    a.write_prefill("a", {"g0": {"k": src, "v": src}}, 6)
    a.commit_prefill("a")
    _, cached = a.alloc_prefill("b", prompt)  # whole hit: shares the tail page
    assert cached == 6
    row_before = list(a.block_row("b", 2))
    assert a.make_private("b", 5) is True  # tail page (pos 5 -> page idx 1)
    row_after = list(a.block_row("b", 2))
    assert row_before[0] == row_after[0] and row_before[1] != row_after[1]
    assert a.make_private("b", 5) is False  # already private: no-op
    # the copy carried the data: b's gathered view still matches the source
    np.testing.assert_array_equal(np.asarray(a.gather("b", "g0")["k"]), np.asarray(src[:, 0]))
    assert a.cow_copies == 1
    a.check_consistency()
    a.free("a")
    a.free("b")
    a.check_consistency()


def test_concurrent_sharing_fuzz_consistent():
    """Three threads storm the arena with the full op mix — content-aware
    alloc (shared prompt pool), write_prefill, extend, gather, make_private,
    free — and the refcount/free-list/index invariants must hold after
    every round."""
    a = make_arena(num_pages=32, page=4, stages={"g0": 2, "g1": 2})
    # provlint runtime net: record the observed acquisition order of the
    # arena's two locks; any nesting inversion across the op mix is an
    # ABBA cycle and fails the round
    lock_graph = LockGraph()
    a._lock = InstrumentedLock(lock_graph, inner=a._lock, name="KVArena._lock")
    a._data_lock = InstrumentedLock(lock_graph, inner=a._data_lock,
                                    name="KVArena._data_lock")
    prompts = [_toks(*range(s, s + n)) for s, n in
               [(0, 9), (0, 12), (100, 6), (100, 17), (200, 4)]]

    errors: list[BaseException] = []

    def worker(tid: int):
        rng = random.Random(1000 + tid)
        live: dict[tuple, int] = {}  # only THIS thread touches its seq ids
        try:
            for i in range(40):
                op = rng.random()
                if op < 0.35 and len(live) < 4:
                    sid = (tid, i)
                    prompt = rng.choice(prompts)
                    try:
                        a.alloc_prefill(sid, prompt)
                    except ArenaFull:
                        continue
                    length = len(prompt)
                    span = a.pages_for(length) * a.page_size
                    src = jnp.full((2, 1, span, 2, 4), float(tid + 1), jnp.float32)
                    a.write_prefill(sid, {"g0": {"k": src, "v": src}}, length)
                    a.commit_prefill(sid)
                    live[sid] = length
                elif op < 0.55 and live:
                    sid = rng.choice(list(live))
                    new_len = live[sid] + rng.randint(1, 6)
                    try:
                        a.extend(sid, new_len)
                        live[sid] = new_len
                    except ArenaFull:
                        pass
                elif op < 0.7 and live:
                    sid = rng.choice(list(live))
                    got = a.gather(sid, rng.choice(["g0", "g1"]))
                    assert got["k"].ndim == 4
                elif op < 0.85 and live:
                    sid = rng.choice(list(live))
                    try:
                        a.make_private(sid, live[sid] - 1)
                    except ArenaFull:
                        pass
                elif live:
                    sid = rng.choice(list(live))
                    live.pop(sid)
                    a.free(sid)
        except BaseException as e:  # noqa: BLE001 — surface in the main thread
            errors.append(e)
        finally:
            for sid in live:
                a.free(sid)

    for _ in range(3):  # rounds: storm, join, audit
        threads = [threading.Thread(target=worker, args=(t,)) for t in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        a.check_consistency()
        lock_graph.assert_acyclic()
    assert "KVArena._lock" in lock_graph.edges(), "instrumentation never fired"
    assert a.used_pages() == 0
    assert a.free_pages() == a.num_pages - 1
