"""Serving engine on the Provuse platform: chain correctness under fusion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced_config
from repro.core import FusionPolicy, TinyJaxBackend
from repro.models.model import build_model
from repro.models.params import init_params
from repro.serving.engine import ServingEngine


def direct_generate(model, params, tokens, steps, max_len):
    """Reference: generate WITHOUT the platform (plain model calls)."""
    from repro.configs.base import ShapeConfig

    logits, cache = jax.jit(model.prefill_fn)(params, {"tokens": tokens})
    t = tokens.shape[1]
    # pad cache seq dim to max_len
    def grow(x):
        if x.ndim >= 3 and x.shape[-3] == t:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, max_len - t)
            return jnp.pad(x, pad)
        return x

    cache = jax.tree.map(grow, cache)
    cur = jnp.full((tokens.shape[0],), t, jnp.int32)
    out = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
    dec = jax.jit(model.decode_fn)
    for _ in range(steps - 1):
        logits, cache = dec(params, {"tokens": out[-1], "cur_len": cur}, cache)
        cur = cur + 1
        out.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    return jnp.concatenate(out, axis=1)


def test_chain_generation_matches_direct_model():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(min_observations=2, merge_cost_s=0.0))
    try:
        engine = ServingEngine(model, platform, max_len=48)
        tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab_size, jnp.int32)
        got, _ = engine.generate({"tokens": tokens}, steps=10)
        expect = direct_generate(model, engine.params, tokens, 10, 48)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
        # fusion actually happened during generation
        assert any(m.healthy for m in platform.merger.merge_log)
    finally:
        platform.shutdown()


def test_chain_fuses_to_single_instance_and_latency_drops():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(min_observations=2, merge_cost_s=0.0))
    try:
        engine = ServingEngine(model, platform, max_len=48)
        tokens = jnp.ones((1, 8), jnp.int32)
        _, lat = engine.generate({"tokens": tokens}, steps=16)
        live = platform.registry.live_instances()
        assert len(live) == 1, f"chain should fully fuse, got {live}"
        assert np.median(lat[-3:]) < np.median(lat[:3])
    finally:
        platform.shutdown()


def test_paged_generate_bit_identical():
    """generate() outputs are bit-identical pre/post KV paging: the paged
    decode gathers pages to the same width the dense cache has, and masked
    positions contribute exact zeros — same program, same values."""
    cfg = reduced_config(get_arch("llama3.2-1b"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(min_observations=2, merge_cost_s=0.0))
    try:
        engine = ServingEngine(model, platform, max_len=48, kv_pages=32, kv_page_size=16)
        tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 11), 0, cfg.vocab_size, jnp.int32)
        dense, _ = engine.generate({"tokens": tokens}, steps=12)
        paged, _ = engine.generate_paged({"tokens": tokens}, steps=12)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))
        # decode crossed a page boundary (11 + 11 tokens > page 16)
        assert engine.arena.used_pages() == 0  # pages freed on exit
        engine.arena.check_consistency()
        # a second paged run after the arena was recycled still matches
        paged2, _ = engine.generate_paged({"tokens": tokens}, steps=12)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged2))
    finally:
        platform.shutdown()


def test_paging_unsupported_for_ssm():
    cfg = reduced_config(get_arch("mamba2-370m"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        engine = ServingEngine(model, platform, max_len=32)
        assert not engine.paging_supported
        with pytest.raises(ValueError):
            engine.enable_paging(8)
    finally:
        platform.shutdown()


def test_encdec_two_function_app():
    cfg = reduced_config(get_arch("seamless-m4t-medium"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        engine = ServingEngine(model, platform, max_len=32)
        inputs = {
            "src_embeds": (jax.random.normal(jax.random.PRNGKey(0), (2, 8, cfg.d_model)) * 0.02).astype(jnp.bfloat16),
            "tokens": jnp.zeros((2, 1), jnp.int32),
        }
        toks, _ = engine.generate(inputs, steps=6)
        assert toks.shape == (2, 6)
        assert jnp.all((toks >= 0) & (toks < cfg.vocab_size))
        merged = [m for m in platform.merger.merge_log if m.healthy]
        assert merged and len(merged[0].members) == 2  # encoder + decoder fused
    finally:
        platform.shutdown()


def test_hybrid_monolithic_chain():
    cfg = reduced_config(get_arch("zamba2-7b"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(min_observations=2, merge_cost_s=0.0))
    try:
        engine = ServingEngine(model, platform, max_len=32)
        tokens = jnp.ones((1, 8), jnp.int32)
        toks, _ = engine.generate({"tokens": tokens}, steps=5)
        assert toks.shape == (1, 5)
    finally:
        platform.shutdown()
