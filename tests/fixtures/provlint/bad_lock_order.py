"""Known-bad: two methods nest the same pair of locks in opposite orders —
the classic ABBA deadlock, visible statically."""
import threading


class TwoLocks:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.x = 0

    def forward(self):
        with self._a:
            with self._b:  # line 14: a -> b
                self.x += 1

    def backward(self):
        with self._b:
            with self._a:  # line 19: b -> a — closes the cycle
                self.x -= 1
