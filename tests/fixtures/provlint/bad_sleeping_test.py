"""Known-bad: a tier-1 test burning real wall-clock without a slow mark."""
import time


def test_waits_for_worker():
    time.sleep(0.5)  # line 6: >= 0.25s and not @pytest.mark.slow
    assert True
