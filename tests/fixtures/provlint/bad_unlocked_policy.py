"""Known-bad: the PR 2 ``merge_cost_s`` shape — an EWMA read-modify-write of
a guarded field with the lock dropped."""
import threading


class Policy:
    GUARDED_FIELDS = {"merge_cost_s": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self.merge_cost_s = 2.0

    def feedback_merge_cost(self, seconds):
        self.merge_cost_s = 0.5 * self.merge_cost_s + 0.5 * seconds  # line 14

    def decide(self):
        with self._lock:
            return self.merge_cost_s  # correctly locked: no finding
