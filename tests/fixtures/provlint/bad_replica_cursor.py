"""Known-bad: the ISSUE 9 replica-cursor shape — a spread policy's
round-robin cursor read-modify-written outside the lock that concurrent
resolve threads race through (two resolves read the same cursor, pick the
same replica, and one increment is lost)."""
import threading


class BadReplicaCursor:
    GUARDED_FIELDS = {"_cursor": "_lock", "_replicas": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._cursor = {}
        self._replicas = {}

    def add(self, name, replica):
        with self._lock:
            self._replicas.setdefault(name, []).append(replica)

    def pick(self, name):
        with self._lock:
            replicas = list(self._replicas.get(name, ()))
        i = self._cursor.get(name, 0)  # line 23: cursor read without _lock
        self._cursor[name] = i + 1  # line 24: cursor RMW without _lock
        return replicas[i % len(replicas)] if replicas else None
