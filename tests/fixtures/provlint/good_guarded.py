"""Known-good: every guarded access locked, every nesting one order,
condition-alias and guarded-method contracts exercised. Zero findings."""
import threading

from repro.analysis.guards import guarded_by


class Disciplined:
    GUARDED_FIELDS = {"items": "_lock", "closed": "_lock"}
    GUARDED_WRITES = {"snapshot": "_data_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._data_lock = threading.Lock()
        self.items = []
        self.closed = False
        self.snapshot = ()

    def put(self, x):
        with self._cond:  # alias of _lock: counts as holding it
            self.items.append(x)
            self._count_locked()

    def publish(self):
        with self._lock:
            live = tuple(self.items)
        peek = self.snapshot  # unlocked READ of a write-guarded field: ok
        with self._data_lock:
            self.snapshot = live + tuple(peek[:0])

    def close(self):
        with self._lock:
            with self._data_lock:  # consistent _lock -> _data_lock order
                self.closed = True
                self.snapshot = ()

    @guarded_by("_lock")
    def _count_locked(self):
        return len(self.items)
