"""Known-good test module: tiny sleeps, slow-marked big sleep, waived sleep.
Zero findings."""
import time

import pytest


def test_tiny_sleep_is_fine():
    time.sleep(0.01)


@pytest.mark.slow
def test_marked_slow_may_sleep():
    time.sleep(1.0)


def test_waived_sleep():
    time.sleep(0.5)  # provlint: ok — scenario needs the real drain
