"""Known-bad: the PR 6 ``write_prefill`` shape — a functional RMW swap of a
write-guarded device array through a local alias, outside its lock."""
import threading


class Arena:
    GUARDED_FIELDS = {"_held": "_lock"}
    GUARDED_WRITES = {"data": "_data_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._data_lock = threading.Lock()
        self.data = {}
        self._held = {}

    def write_prefill(self, stage, kv, ids, rows):
        with self._lock:
            held = list(self._held)
        dst = self.data[stage]
        dst[kv] = dst[kv].at[:, ids].set(rows)  # line 20: RMW without _data_lock
        return held

    def gather(self, seq_id):
        return self._held.get(seq_id)  # line 24: read without _lock
