"""Known-bad: src-style module calling raw time primitives instead of the
injectable Clock."""
import time


def poll_until_ready(check):
    deadline = time.monotonic() + 5.0  # line 7
    while time.monotonic() < deadline:  # line 8
        if check():
            return True
        time.sleep(0.01)  # line 11
    return False
