"""Benchmark-backed acceptance checks for the adaptive batching window.

Runs the same code paths as `benchmarks/load_bench.py --adaptive` (bursty and
trickle open-loop scenarios, static vs adaptive window) and asserts the
headline claims: on bursts, adaptive occupancy beats the static window at
equal-or-better p95; on a serial trickle, the adaptive window decays so the
static window's per-request queueing tax disappears. Marked slow — four full
engine builds + compiles; run with `-m slow`.
"""
import argparse
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))

import load_bench  # noqa: E402


def bench_args(**overrides) -> argparse.Namespace:
    base = dict(
        arch="llama3.2-1b", backend="tinyjax", concurrency=8, steps=48,
        warmup_steps=8, prompt_len=8, max_len=96, max_batch=0,
        max_delay_ms=4.0, rate=160.0, duration=2.5, pattern="bursty",
        burst=8, intra_gap_ms=1.0, trickle_rate=15.0, adaptive=False,
        smoke=False, slo=False, modes=["fused-batched"], json=False,
    )
    base.update(overrides)
    return argparse.Namespace(**base)


def _retry_once(check):
    """Timing assertions on a 2-core shared box get one retry: a transient
    scheduler hiccup must not fail the suite, a real regression still does."""
    try:
        check()
    except AssertionError:
        check()


@pytest.mark.slow
def test_adaptive_window_beats_static_on_bursty_and_trickle():
    def check():
        args = bench_args(max_delay_ms=4.0, duration=4.0)
        out = load_bench.run_adaptive_compare(args)
        s = out["summary"]
        # bursty: the grown window packs fuller batches at parity-or-better
        # p95 (1.25x headroom: the tail on a 2-core shared box jitters by
        # more than the effect of the window itself)
        assert s["bursty_occupancy_adaptive"] > s["bursty_occupancy_static"], s
        assert s["bursty_p95_adaptive_ms"] <= s["bursty_p95_static_ms"] * 1.25, s
        # all requests completed in every cell
        for cell in ("bursty/static", "bursty/adaptive", "trickle/static", "trickle/adaptive"):
            assert out[cell]["requests"] > 0

    _retry_once(check)


@pytest.mark.slow
def test_adaptive_trickle_sheds_the_static_window_tax():
    def check():
        # a deliberately heavy static window makes the tax unambiguous vs noise
        args = bench_args(max_delay_ms=25.0, duration=2.5, trickle_rate=12.0)
        out = load_bench.run_adaptive_compare(args)
        t_s, t_a = out["trickle/static"], out["trickle/adaptive"]
        # static: every lone request waits out the 25ms window; adaptive decays it
        assert t_a["p50_ms"] < t_s["p50_ms"] - 0.4 * args.max_delay_ms, (t_s, t_a)

    _retry_once(check)


@pytest.mark.slow
def test_smoke_mode_passes_on_healthy_scheduler():
    assert load_bench.run_smoke(bench_args()) == 0


@pytest.mark.slow
def test_slo_scenario_meets_strict_target_near_fifo_throughput():
    """The ISSUE 4 acceptance run: strict class meets its p95 target under
    mixed 3-class load; aggregate throughput within 15% of FIFO (run_slo
    asserts both internally; the smoke wrapper supplies the one retry)."""
    assert load_bench.run_slo_smoke(bench_args()) == 0  # smoke forces its own 2s duration


@pytest.mark.slow
def test_churn_phase_shift_recovers_throughput_after_fission():
    def check():
        out = load_bench.run_churn(bench_args(duration=5.0))
        assert out["failed"] == 0 and out["hung"] == 0
        assert out["split_epoch"] > out["merge_epoch"]
        assert "saturation" in out["split_reason"] or "p95" in out["split_reason"]
        assert out["recovery"] >= 1.3, out

    _retry_once(check)
