import os
import sys

# Smoke tests and benches must see the REAL device count (1 CPU device).
# Only launch/dryrun.py forces 512 host devices — and only in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# REPRO_COMPILE_CACHE=<dir> (set by CI with an actions/cache'd directory):
# persist XLA executables across test runs so repeat compiles restore
# instead of rebuild. A no-op when the variable is unset.
try:
    from repro.launch.compile_cache import maybe_enable_from_env

    maybe_enable_from_env()
except Exception:  # pragma: no cover - cache is an optimization, never a gate
    pass
