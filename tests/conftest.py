import os
import sys

# Smoke tests and benches must see the REAL device count (1 CPU device).
# Only launch/dryrun.py forces 512 host devices — and only in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
