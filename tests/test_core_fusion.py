"""End-to-end behaviour of the Provuse platform: observation -> policy ->
merge -> health check -> swap -> retire, on both backends."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FunctionSpec,
    FusionPolicy,
    OrchestratedBackend,
    TinyJaxBackend,
)

BACKENDS = [TinyJaxBackend, OrchestratedBackend]


def deploy_chain_app(platform):
    """A -> B -> C synchronously; A fires async D."""
    wa = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.05
    wb = jax.random.normal(jax.random.PRNGKey(1), (64, 64)) * 0.05
    wc = jax.random.normal(jax.random.PRNGKey(2), (64, 64)) * 0.05

    def fn_c(ctx, params, x):
        return jnp.tanh(x @ params)

    def fn_b(ctx, params, x):
        return ctx.call("C", jnp.tanh(x @ params))

    def fn_a(ctx, params, x):
        h = jnp.tanh(x @ params)
        ctx.call_async("D", h)
        return ctx.call("B", h)

    def fn_d(ctx, params, x):
        return (x * x).sum()

    platform.deploy(FunctionSpec("A", fn_a, wa))
    platform.deploy(FunctionSpec("B", fn_b, wb))
    platform.deploy(FunctionSpec("C", fn_c, wc))
    platform.deploy(FunctionSpec("D", fn_d, None))
    return wa, wb, wc


def chain_reference(wa, wb, wc, x):
    return jnp.tanh(jnp.tanh(jnp.tanh(x @ wa) @ wb) @ wc)


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_progressive_fusion_preserves_semantics(backend_cls):
    p = backend_cls(FusionPolicy(min_observations=3, merge_cost_s=0.0))
    try:
        wa, wb, wc = deploy_chain_app(p)
        x = jnp.ones((4, 64))
        outs = [p.invoke("A", x) for _ in range(10)]
        ref = chain_reference(wa, wb, wc, x)
        for out in outs:
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)
        merges = [m for m in p.merger.merge_log if m.healthy]
        assert len(merges) >= 2
        assert merges[-1].members == ("A", "B", "C")
        # routing: all three names now resolve to ONE instance
        insts = {id(p.registry.resolve(n)) for n in ("A", "B", "C")}
        assert len(insts) == 1
    finally:
        p.shutdown()


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_async_edges_never_fuse(backend_cls):
    p = backend_cls(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        deploy_chain_app(p)
        x = jnp.ones((4, 64))
        for _ in range(8):
            p.invoke("A", x)
        time.sleep(0.5)  # let async D invocations drain; provlint: ok
        d_inst = p.registry.resolve("D")
        assert d_inst.members.keys() == {"D"}
        edges = p.handler.edges
        assert edges[("A", "D")].async_count > 0
        assert edges[("A", "D")].sync_count == 0
    finally:
        p.shutdown()


def test_trust_domain_blocks_fusion():
    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        w = jnp.eye(8)

        def fn_b(ctx, params, x):
            return x @ params

        def fn_a(ctx, params, x):
            return ctx.call("B", x @ params)

        p.deploy(FunctionSpec("A", fn_a, w, trust_domain="tenant1"))
        p.deploy(FunctionSpec("B", fn_b, w, trust_domain="tenant2"))
        for _ in range(6):
            p.invoke("A", jnp.ones((2, 8)))
        assert not [m for m in p.merger.merge_log if m.healthy]
        assert len({id(p.registry.resolve(n)) for n in ("A", "B")}) == 2
    finally:
        p.shutdown()


def test_ram_reduction_and_billing():
    p = TinyJaxBackend(FusionPolicy(min_observations=3, merge_cost_s=0.0))
    try:
        wa, wb, wc = deploy_chain_app(p)
        x = jnp.ones((4, 64))
        p.invoke("A", x)
        p.invoke("A", x)
        ram_before = p.ram_bytes()
        blocked_before = p.meter.blocked_gb_seconds()
        assert blocked_before > 0, "double billing must be observable pre-fusion"
        for _ in range(8):
            p.invoke("A", x)
        merges = [m for m in p.merger.merge_log if m.healthy]
        assert merges and all(m.freed_bytes >= 0 for m in merges)
        # instances freed: A,B,C collapsed to one
        live = p.registry.live_instances()
        assert len(live) == 2  # merged[A+B+C] + D
        p.meter.reset()
        for _ in range(5):
            p.invoke("A", x)
        assert p.meter.blocked_gb_seconds() == 0.0, "no blocking after full fusion"
    finally:
        p.shutdown()


def test_merge_aborts_without_canary():
    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        deploy_chain_app(p)
        # no traffic at all -> no canary -> direct merge submit must not swap
        p.handler.edges[("B", "C")] = type(p.handler.edges.get(("B", "C"), None) or object)() if False else None
        from repro.core.handler import EdgeStats

        p.handler.edges[("B", "C")] = EdgeStats(sync_count=5, total_wait_s=1.0)
        p.merger.submit("B", "C")
        assert not [m for m in p.merger.merge_log if m.healthy]
        assert [m for m in p.merger.merge_log if not m.healthy]
        assert len({id(p.registry.resolve(n)) for n in ("B", "C")}) == 2
    finally:
        p.shutdown()


def test_compiled_vs_eager_entry_selection():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        deploy_chain_app(p)
        x = jnp.ones((4, 64))
        p.invoke("A", x)
        # C is a leaf -> compiled; A and B have boundary calls -> eager glue
        inst_c = p.registry.resolve("C")
        inst_a = p.registry.resolve("A")
        assert inst_c._compiled and not inst_c._eager_entries
        assert inst_a._eager_entries and not inst_a._compiled
    finally:
        p.shutdown()


def test_fault_tolerance_redeploys_terminated_instance():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        deploy_chain_app(p)
        x = jnp.ones((4, 64))
        first = p.invoke("A", x)
        # simulate a crashed container
        inst = p.registry.resolve("C")
        inst.state = inst.state.__class__.RETIRED
        inst.params = {}
        out = p.invoke("C", jnp.ones((4, 64)))  # platform must re-provision
        assert out.shape == (4, 64)
        assert p.registry.resolve("C").state.value == "serving"
    finally:
        p.shutdown()
