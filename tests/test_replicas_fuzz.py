"""Race-fuzz for the replicated data plane (test_slo_fuzz.py style).

Seeded, hand-rolled fuzzing: concurrent ``invoke_async`` traffic against a
real TinyJaxBackend while a churn thread scales the replica set out and in
and occasionally redeploys (displacing the WHOLE set at once). The
conservation properties that must hold on EVERY trace:

* every submitted future resolves exactly once — no hangs, no double
  resolution, no drops (a scale-in/redeploy race retries, never strands);
* echoed results match their request payloads;
* no dispatch ever resolves a DRAINING or RETIRED replica — the route flip
  and the DRAINING transition share one critical section;
* every lock the platform stack acquires during the trace records into a
  runtime lock graph that stays acyclic (provlint's runtime net), with the
  scale-out/scale-in paths exercised under instrumentation.
"""
import random
import threading
import time
from concurrent.futures import wait

import pytest

from repro.analysis import LockGraph, patched_locks
from repro.core import FunctionSpec, FusionPolicy, InstanceState, TinyJaxBackend


class _CheckedTiny(TinyJaxBackend):
    """TinyJaxBackend whose dispatch paths resolve through ``resolve_entry``
    and record the replica state they observed — the fuzz's invariant probe
    for 'no request lands on a DRAINING/RETIRED replica'."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.dispatches = 0
        self.state_violations = []
        self._obs_lock = threading.Lock()

    def _observe(self, instance, state):
        with self._obs_lock:
            self.dispatches += 1
            if state in (InstanceState.DRAINING, InstanceState.RETIRED):
                self.state_violations.append(
                    f"{instance.instance_id} resolved while {state.value}")

    def _dispatch_sync(self, name, args):
        instance, state = self.registry.resolve_entry(name)
        self._observe(instance, state)
        return self._run_request(instance, name, args)

    def _dispatch_batch_impl(self, name, args_list):
        instance, state = self.registry.resolve_entry(name)
        self._observe(instance, state)
        return self._run_batch(instance, name, args_list)


@pytest.mark.parametrize("seed", [7, 23])
def test_conservation_under_replica_churn(seed):
    rng = random.Random(seed)
    n_requests = 160
    max_replicas = 3

    # provlint runtime net: instrument every lock the platform stack creates
    # (registry RLock, spread cursor, instance locks, scheduler lanes) and
    # assert the observed acquisition graph never cycles. Entered BEFORE
    # construction so the long-lived locks are all recorded.
    lock_graph = LockGraph()
    lock_patch = patched_locks(lock_graph)
    lock_patch.__enter__()
    p = _CheckedTiny(FusionPolicy(enabled=False), max_batch=4,
                     max_delay_ms=1.0, adaptive=True)
    stop = threading.Event()
    churn_errors = []
    try:
        import jax.numpy as jnp

        p.deploy(FunctionSpec("hot", lambda ctx, params, x: x * 2 + 1, None))
        # warm the pow2 batch buckets (1/2/4) so no fuzz-time XLA compile
        # stretches the trace's real-time budget
        assert float(p.invoke("hot", jnp.float32(3.0))) == 7.0
        for _ in range(3):
            done, not_done = wait(
                [p.invoke_async("hot", jnp.float32(i)) for i in range(4)],
                timeout=30)
            assert not not_done

        def churn():
            while not stop.is_set():
                try:
                    roll = rng.random()
                    replicas = p.registry.replicas("hot")
                    if roll < 0.45 and len(replicas) < max_replicas:
                        p._spawn_replica("hot")
                    elif roll < 0.8 and len(replicas) > 1:
                        # newest-first scale-in; raced no-ops return None
                        p.lifecycle.scale_in(replicas[-1], reason="fuzz")
                    elif roll >= 0.9:
                        # publish churn: displace the WHOLE replica set
                        p._redeploy("hot")
                except Exception as exc:  # noqa: BLE001 — a churn crash is a finding
                    churn_errors.append(repr(exc))
                time.sleep(0.002)

        churner = threading.Thread(target=churn, daemon=True)
        churner.start()

        futs = []
        resolution_counts = {}
        counts_lock = threading.Lock()

        def stamp(idx):
            def cb(_fut):
                with counts_lock:
                    resolution_counts[idx] = resolution_counts.get(idx, 0) + 1
            return cb

        i = 0
        while i < n_requests:
            for _ in range(rng.randrange(1, 7)):  # bursts coalesce into batches
                if i >= n_requests:
                    break
                fut = p.invoke_async("hot", jnp.float32(i))
                fut.add_done_callback(stamp(i))
                futs.append((i, fut))
                i += 1
            if rng.random() < 0.4:
                time.sleep(rng.choice([0.0005, 0.002]))

        done, not_done = wait([f for _, f in futs], timeout=60)
        stop.set()
        churner.join(timeout=10)
        lock_patch.__exit__(None, None, None)
        lock_patch = None

        assert not not_done, f"{len(not_done)} futures hung (conservation violated)"
        assert not churn_errors, churn_errors[:3]
        assert not p.state_violations, p.state_violations[:3]
        # exactly-once, correct-payload resolution: the retry path absorbs
        # scale-in/redeploy races instead of surfacing or duplicating them
        for idx, fut in futs:
            assert fut.exception() is None, (idx, fut.exception())
            assert float(fut.result()) == idx * 2 + 1, (
                f"request {idx} got another's result")
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with counts_lock:
                if len(resolution_counts) >= n_requests:
                    break
            time.sleep(0.001)
        with counts_lock:
            assert len(resolution_counts) == n_requests
            assert all(c == 1 for c in resolution_counts.values()), (
                "a future resolved more than once")
        # the churn actually churned: scale epochs landed in the event log
        kinds = {e.kind for e in p.lifecycle.events}
        assert "scale-out" in kinds, kinds
        assert p.registry.replica_count("hot") >= 1
        assert p.dispatches > 0
        lock_graph.assert_acyclic()
        assert lock_graph.edges(), "lock instrumentation never fired"
    finally:
        stop.set()
        if lock_patch is not None:
            lock_patch.__exit__(None, None, None)
        p.shutdown()
        lock_graph.assert_acyclic()  # shutdown's drains are part of the trace
