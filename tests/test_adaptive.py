"""Adaptive micro-batch windows, SLO-priority admission, and the scheduler
signals the fusion policy consumes. No jax on the hot paths — pure scheduler
mechanics with synthetic dispatch functions, tier-1 fast."""
import threading
import time
from concurrent.futures import Future, wait

import pytest

from repro.scheduler import (
    PRIORITY_HIGH,
    AdaptiveConfig,
    AdaptiveWindow,
    RequestScheduler,
    SchedulerSignals,
)

# ------------------------------------------------------- controller (no threads)


def test_window_grows_on_dense_arrivals_with_low_occupancy():
    cfg = AdaptiveConfig(max_delay_s=0.020)
    win = AdaptiveWindow(max_batch=8, initial_delay_s=0.001, config=cfg)
    # singleton batches arriving 2ms apart: dense traffic the 1ms window misses
    t = 0.0
    for _ in range(30):
        win.observe_batch([t], closed_full=False)
        t += 0.002
    assert win.delay_s > 0.004, "window must grow toward the occupancy target"
    # steady state: the gap-derived target is (0.75*8 - 1) * 2ms = 10ms
    assert win.delay_s <= cfg.max_delay_s


def test_window_decays_to_zero_on_serial_trickle():
    cfg = AdaptiveConfig(max_delay_s=0.020)
    win = AdaptiveWindow(max_batch=8, initial_delay_s=0.020, config=cfg)
    t = 0.0
    for _ in range(30):
        win.observe_batch([t], closed_full=False)
        t += 0.100  # gap far beyond any allowed window: waiting buys nothing
    assert win.delay_s == cfg.min_delay_s, "trickle must decay the window to ~0"


def test_window_shrinks_when_batches_close_full():
    cfg = AdaptiveConfig(max_delay_s=0.020)
    win = AdaptiveWindow(max_batch=4, initial_delay_s=0.020, config=cfg)
    t = 0.0
    for _ in range(30):
        win.observe_batch([t, t + 1e-4, t + 2e-4, t + 3e-4], closed_full=True)
        t += 0.005
    # arrivals fill a batch in well under a millisecond; holding 20ms is waste
    assert win.delay_s < 0.010


def test_window_hysteresis_prevents_flapping():
    cfg = AdaptiveConfig(max_delay_s=0.020)
    win = AdaptiveWindow(max_batch=8, initial_delay_s=0.002, config=cfg)
    t = 0.0
    for _ in range(40):  # stationary traffic: EWMA converges, window settles
        win.observe_batch([t, t + 0.002, t + 0.004], closed_full=False)
        t += 0.010
    settled = win.delay_s
    retunes_before = win.retunes
    for _ in range(20):
        win.observe_batch([t, t + 0.002, t + 0.004], closed_full=False)
        t += 0.010
    assert win.retunes == retunes_before, "stationary traffic must not flap the window"
    assert win.delay_s == settled


def test_window_growth_stops_at_target_occupancy():
    """Once batches fill to target, a grown window buys nothing more — the
    gap-derived target must not keep inflating the wait."""
    cfg = AdaptiveConfig(max_delay_s=0.050, target_occupancy=0.75)
    win = AdaptiveWindow(max_batch=5, initial_delay_s=0.004, config=cfg)
    t = 0.0
    for _ in range(30):  # batches of 4/5 = 0.8, above target; arrivals 4ms apart
        win.observe_batch([t, t + 0.004, t + 0.008, t + 0.012], closed_full=False)
        t += 0.024
    assert win.delay_s == 0.004, "at-target occupancy must freeze growth"


def test_window_reset_forgets_learned_state():
    cfg = AdaptiveConfig(max_delay_s=0.020)
    win = AdaptiveWindow(max_batch=8, initial_delay_s=0.010, config=cfg)
    t = 0.0
    for _ in range(10):
        win.observe_batch([t], closed_full=False)
        t += 0.100
    assert win.delay_s == cfg.min_delay_s  # trickle decayed it
    win.reset(0.010)
    assert win.delay_s == 0.010
    assert win.snapshot()["ewma_gap_ms"] == 0.0


def test_default_config_cap_stretches_with_large_seed():
    """adaptive=True with max_delay_ms above the default 20ms cap must not
    silently clamp the operator's window — the cap stretches to 2x seed."""
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_delay_ms=50.0, adaptive=True)
    try:
        assert sched.adaptive_config.max_delay_s == pytest.approx(0.100)
    finally:
        sched.shutdown()
    # small seeds keep the stock config
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_delay_ms=2.0, adaptive=True)
    try:
        assert sched.adaptive_config.max_delay_s == pytest.approx(AdaptiveConfig().max_delay_s)
    finally:
        sched.shutdown()


def test_reset_stats_clears_history_but_keeps_serving():
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_batch=4, max_delay_ms=5.0,
                             adaptive=True)
    try:
        wait([sched.submit("f", (i,)) for i in range(8)], timeout=5)
        assert sched.stats()["batches"] > 0
        sched.reset_stats()
        st = sched.stats()
        assert st["batches"] == 0 and st["requests"] == 0 and st["mean_batch"] == 0.0
        assert sched.signals_for("f").mean_occupancy == 0.0
        assert sched.submit("f", (9,)).result(timeout=5) == 9  # queues still live
    finally:
        sched.shutdown()


def test_idle_close_tracks_intra_burst_spacing():
    """The early-close cutoff follows the smoothed INTRA-burst gap; burst
    boundary gaps (>= the window cap) must not inflate it."""
    cfg = AdaptiveConfig(max_delay_s=0.020)
    win = AdaptiveWindow(max_batch=8, initial_delay_s=0.002, config=cfg)
    assert win.idle_close_s() is None  # no estimate yet: window governs alone
    t = 0.0
    for _ in range(10):  # bursts spaced 1ms inside, 37ms apart
        win.observe_batch([t, t + 0.001, t + 0.002, t + 0.003], closed_full=False)
        t += 0.040
    ic = win.idle_close_s()
    assert ic is not None and 0.001 <= ic <= 0.006, ic  # ~3x the 1ms spacing


def test_window_bounds_respected():
    cfg = AdaptiveConfig(min_delay_s=0.0005, max_delay_s=0.004)
    win = AdaptiveWindow(max_batch=8, initial_delay_s=0.050, config=cfg)
    assert win.delay_s == cfg.max_delay_s  # initial clamps into [min, max]
    t = 0.0
    for _ in range(30):  # dense arrivals push the target above the cap
        win.observe_batch([t, t + 1e-3], closed_full=False)
        t += 2e-3
    assert cfg.min_delay_s <= win.delay_s <= cfg.max_delay_s


# ------------------------------------------------------- scheduler integration


def test_adaptive_scheduler_converges_bursty_grows_trickle_decays():
    """The satellite convergence check, end to end through real dispatcher
    threads ON THE VIRTUAL CLOCK: dense arrivals grow the retuned window
    above its seed; a serial trickle decays it to ~0 so lone requests stop
    paying the window tax. ~2 simulated seconds, ~no real waiting."""
    from repro.scheduler import VirtualClock

    # trickle: one request every 30ms (virtual) against a 20ms-max window
    clock = VirtualClock()
    sched = RequestScheduler(
        lambda name, a: [x[0] for x in a], max_batch=4, max_delay_ms=20.0,
        adaptive=True, adaptive_config=AdaptiveConfig(max_delay_s=0.020),
        clock=clock,
    )
    try:
        t_lone = []
        for i in range(14):
            t0 = clock.now()
            fut = sched.submit("f", (i,))
            clock.wait_for_waiters(1)
            if not fut.done():  # window still open: expire it virtually
                clock.advance(max(q.max_delay_s for q in sched._queues.values()) + 1e-4)
            assert fut.result(timeout=5) == i
            t_lone.append(clock.now() - t0)
            clock.advance(0.030 - (clock.now() - t0))
        windows = sched.window_snapshot()
        assert windows and windows[0]["max_delay_ms"] < 1.0, windows
        # decayed window: the last lone requests return without the ~20ms wait
        assert min(t_lone[-3:]) < 0.010, t_lone
        clock.assert_elapsed_real_below(10.0)
    finally:
        sched.shutdown()

    # bursty: 3ms-spaced (virtual) arrivals against a 1ms seed window
    clock = VirtualClock()
    sched = RequestScheduler(
        lambda name, a: [x[0] for x in a], max_batch=8, max_delay_ms=1.0,
        adaptive=True, adaptive_config=AdaptiveConfig(max_delay_s=0.050),
        clock=clock,
    )
    try:
        futs = []
        for i in range(60):
            futs.append(sched.submit("f", (i,)))
            clock.wait_for_waiters(1)
            clock.advance(0.003)
        clock.wait_for_waiters(1)
        clock.advance(0.050)  # flush the last open window
        done, not_done = wait(futs, timeout=30)
        assert not not_done
        windows = sched.window_snapshot()
        assert windows and windows[0]["max_delay_ms"] > 2.0, windows
        st = sched.stats()
        assert st["mean_batch"] > 1.5, st
        assert st["adaptive"]["retunes"] > 0
        clock.assert_elapsed_real_below(10.0)
    finally:
        sched.shutdown()


def test_high_priority_closes_window_early():
    """SLO admission: a PRIORITY_HIGH arrival must not wait out a long
    batching window — it closes the window and the whole batch dispatches."""
    sched = RequestScheduler(lambda name, a: [x[0] for x in a], max_batch=8, max_delay_ms=2000.0)
    try:
        t0 = time.perf_counter()
        normal = [sched.submit("f", (i,)) for i in range(3)]
        time.sleep(0.02)  # let the window open on the normal traffic
        urgent = sched.submit("f", (99,), priority=PRIORITY_HIGH)
        done, not_done = wait(normal + [urgent], timeout=5)
        elapsed = time.perf_counter() - t0
        assert not not_done
        assert urgent.result() == 99
        assert elapsed < 1.0, f"2s window must close early on priority ({elapsed:.3f}s)"
    finally:
        sched.shutdown()


def test_high_priority_leads_immediately():
    """A high-priority FIRST request opens no window at all: greedy drain."""
    sched = RequestScheduler(lambda name, a: [x[0] for x in a], max_batch=8, max_delay_ms=2000.0)
    try:
        t0 = time.perf_counter()
        assert sched.submit("f", (1,), priority=PRIORITY_HIGH).result(timeout=5) == 1
        assert time.perf_counter() - t0 < 1.0
    finally:
        sched.shutdown()


def test_high_priority_jumps_queued_backlog():
    """While the dispatcher is busy, a late PRIORITY_HIGH submit must be
    admitted into the next batch ahead of earlier normal requests."""
    order = []
    gate = threading.Event()

    def dispatch(name, args_list):
        if not gate.is_set():
            gate.set()
            time.sleep(0.1)  # first batch holds the dispatcher; backlog forms
        else:
            order.extend(a[0] for a in args_list)
        return [a[0] for a in args_list]

    URGENT = 99
    sched = RequestScheduler(dispatch, max_batch=2, max_delay_ms=0.0)
    try:
        first = sched.submit("f", (0,))
        gate.wait(timeout=5)
        normals = [sched.submit("f", (i,)) for i in range(1, 5)]
        urgent = sched.submit("f", (URGENT,), priority=PRIORITY_HIGH)
        done, not_done = wait([first, urgent] + normals, timeout=5)
        assert not not_done
        assert order[0] == URGENT, order
    finally:
        sched.shutdown()


# ------------------------------------------------------------- signals


def test_signals_for_reports_depth_occupancy_p95():
    release = threading.Event()

    def dispatch(name, args_list):
        release.wait(timeout=5)
        return [a[0] for a in args_list]

    sched = RequestScheduler(dispatch, max_batch=4, max_delay_ms=0.0)
    try:
        futs = [sched.submit("f", (i,)) for i in range(6)]
        time.sleep(0.05)  # dispatcher blocked on the first batch; rest queue up
        sig = sched.signals_for(("f", "g"))
        assert sig.queue_depth > 0
        release.set()
        done, not_done = wait(futs, timeout=5)
        assert not not_done
        sig = sched.signals_for("f")
        assert 0.0 < sig.mean_occupancy <= 1.0
        assert sig.p95_ms > 0.0
        # unknown functions: clean zeros, not KeyErrors
        empty = sched.signals_for(("nope",))
        assert empty.queue_depth == 0 and empty.p95_ms == 0.0
    finally:
        release.set()
        sched.shutdown()


def test_signals_default_is_inert():
    s = SchedulerSignals()
    assert s.queue_depth == 0 and s.mean_occupancy == 0.0 and s.p95_ms == 0.0


def test_signals_for_is_memoized_briefly():
    """A hot unfused edge asks for signals on every sync observation; the
    snapshot (which sorts the latency window) is memoized for a short TTL
    so the control-plane answer stays off the data path's critical cost."""
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_batch=4, max_delay_ms=0.0)
    try:
        wait([sched.submit("f", (i,)) for i in range(4)], timeout=5)
        t0 = time.perf_counter()
        first = sched.signals_for("f")
        assert first.p95_ms > 0
        wait([sched.submit("f", (9,))], timeout=5)
        second = sched.signals_for("f")
        if time.perf_counter() - t0 < 0.04:  # guard: a machine stall can expire the TTL
            assert second is first  # within TTL: cached object
        time.sleep(0.06)
        assert sched.signals_for("f") is not first  # TTL elapsed: recomputed
    finally:
        sched.shutdown()


def test_max_batch_clamps_to_pow2():
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_batch=6)
    try:
        assert sched.max_batch == 4
    finally:
        sched.shutdown()
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_batch=8)
    try:
        assert sched.max_batch == 8
    finally:
        sched.shutdown()


def test_explicit_config_cap_clamps_first_window_too():
    """An explicit AdaptiveConfig whose cap is below the max_delay_ms seed
    must bound the queue's FIRST window, not just retuned ones."""
    cfg = AdaptiveConfig(max_delay_s=0.010)
    sched = RequestScheduler(lambda n, a: [x[0] for x in a], max_delay_ms=50.0,
                             adaptive=True, adaptive_config=cfg)
    try:
        t0 = time.perf_counter()
        assert sched.submit("f", (1,)).result(timeout=5) == 1
        assert time.perf_counter() - t0 < 0.045, "first window must honor the 10ms cap"
        for row in sched.window_snapshot():
            assert row["max_delay_ms"] <= 10.0 + 1e-6
    finally:
        sched.shutdown()


# ------------------------------------------- cross-lane service-time sharing


def test_service_estimate_shared_across_lanes_warm_start():
    """Two controllers sharing one ServiceTimeEstimate: a batch observed on
    lane A warms lane B's M/G/1 model before B ever dispatched."""
    from repro.scheduler import QueueingWindow, ServiceTimeEstimate
    from repro.scheduler.slo import SLOClass

    est = ServiceTimeEstimate(alpha=0.3)
    cfg = AdaptiveConfig(max_delay_s=0.020)
    lane_a = QueueingWindow(8, 0.002, cfg, service=est)
    lane_b = QueueingWindow(8, 0.002, cfg, slo=SLOClass("strict", 50.0), service=est)
    lane_a.observe_batch([0.0, 0.001], closed_full=False, service_s=0.008)
    assert lane_b.service.value == pytest.approx(0.008)
    assert lane_b.snapshot()["service_ms"] == pytest.approx(8.0)
    # B's own observations feed back into A's view (one estimate per function)
    lane_b.observe_batch([0.01], closed_full=False, service_s=0.004)
    assert lane_a.service.value == pytest.approx(0.3 * 0.004 + 0.7 * 0.008)


def test_scheduler_new_class_lane_starts_with_warm_service():
    """A lane created for a NEW class of an already-hot function must see
    the function's service EWMA immediately (no cold start)."""
    from repro.scheduler.slo import SLOClass

    def dispatch(name, args_list):
        time.sleep(0.004)
        return [a[0] for a in args_list]

    sched = RequestScheduler(dispatch, max_batch=4, max_delay_ms=1.0, adaptive=True)
    try:
        for _ in range(3):
            assert sched.submit("f", (1,)).result(timeout=5) == 1
        warm = [r for r in sched.window_snapshot() if r["name"] == "f"]
        assert warm and warm[0]["service_ms"] > 1.0
        # first request of a brand-new class: its controller is born warm
        assert sched.submit("f", (2,), slo=SLOClass("gold", 100.0)).result(timeout=5) == 2
        rows = {r["slo"]: r for r in sched.window_snapshot() if r["name"] == "f"}
        assert rows["gold"]["service_ms"] > 1.0
        # a different FUNCTION still cold-starts (estimates are per function)
        assert sched.submit("g", (3,)).result(timeout=5) == 3
    finally:
        sched.shutdown()


# --------------------------------------------------- per-class overload shed


def test_overload_sheds_best_effort_not_strict():
    """rho >= 1 + best-effort backlog at the bound -> fail fast with
    OverloadShedError; strict submissions keep admitting; shed counts show
    up in class_stats()."""
    from repro.scheduler import OverloadShedError
    from repro.scheduler.slo import SLOClass

    gate = threading.Event()
    entered = threading.Event()

    def dispatch(name, args_list):
        entered.set()
        gate.wait(10)
        return [a[0] for a in args_list]

    sched = RequestScheduler(dispatch, max_batch=4, max_delay_ms=0.5,
                             adaptive=True, be_shed_depth=3)
    try:
        # strict traffic arms shedding for this function (an all-best-effort
        # overload is the fission path's job, not admission control's)
        armer = sched.submit("f", (-1,), slo=SLOClass("strict", 50.0))
        assert entered.wait(5)
        # prime the lane + estimates: one dispatched (blocked) best-effort
        first = sched.submit("f", (0,))
        lane = next(
            q for q in sched._queues.values() if q.name == "f" and q.slo.best_effort
        )
        deadline = time.perf_counter() + 5
        while lane.depth() and time.perf_counter() < deadline:
            time.sleep(0.001)  # first popped into its own (blocked) batch
        # drive the model to overload: 1ms arrivals, 100ms batches
        lane.adaptive._ewma_gap_s = 0.001
        lane.adaptive.service.observe(0.100)
        assert sched._predicted_rho_locked("f") >= 1.0
        queued = [sched.submit("f", (i,)) for i in range(1, 4)]  # depth -> 3
        shed_fut = sched.submit("f", (99,))
        with pytest.raises(OverloadShedError):
            shed_fut.result(timeout=1)
        # strict class is never shed by the best-effort bound
        strict_fut = sched.submit("f", (7,), slo=SLOClass("strict", 50.0))
        gate.set()
        assert strict_fut.result(timeout=5) == 7
        assert armer.result(timeout=5) == -1
        assert first.result(timeout=5) == 0
        assert [f.result(timeout=5) for f in queued] == [1, 2, 3]
        stats = sched.class_stats()
        assert stats["best-effort"]["shed"] == 1
        assert stats.get("strict", {}).get("shed", 0) == 0
        # reset_stats disarms shedding until strict traffic is seen again —
        # a warmup's strict request must not arm it forever
        sched.reset_stats()
        assert sched._strict_fns == set()
    finally:
        gate.set()
        sched.shutdown()


def test_no_shed_below_rho_one():
    """A deep best-effort backlog alone must NOT shed — only predicted
    overload does."""
    gate = threading.Event()

    def dispatch(name, args_list):
        gate.wait(10)
        return [a[0] for a in args_list]

    sched = RequestScheduler(dispatch, max_batch=4, max_delay_ms=0.5,
                             adaptive=True, be_shed_depth=2)
    try:
        futs = [sched.submit("f", (i,)) for i in range(8)]  # depth far past bound
        gate.set()
        assert [f.result(timeout=5) for f in futs] == list(range(8))
        assert sched.class_stats()["best-effort"]["shed"] == 0
    finally:
        gate.set()
        sched.shutdown()
