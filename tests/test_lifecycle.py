"""Control-plane invariants: epoch-versioned routing, instance lifecycle,
reversible fusion (fission), and the merge<->split hysteresis.

The invariants under test are the ones every epoch transition must uphold:
a resolve can never observe a DRAINING instance through a live route, a
split+merge round trip preserves request semantics, redeploys retire the
displaced worker, and the routing version only moves when routes do."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FunctionInstance,
    FunctionSpec,
    FusionPolicy,
    InstanceState,
    OrchestratedBackend,
    TinyJaxBackend,
)
from repro.core.registry import RoutingTable
from repro.scheduler import RequestScheduler

BACKENDS = [TinyJaxBackend, OrchestratedBackend]


def deploy_chain(platform):
    w = jnp.eye(8) * 0.5
    platform.deploy(FunctionSpec("A", lambda ctx, p, x: ctx.call("B", jnp.tanh(x @ p)), w))
    platform.deploy(FunctionSpec("B", lambda ctx, p, x: ctx.call("C", jnp.tanh(x @ p)), w))
    platform.deploy(FunctionSpec("C", lambda ctx, p, x: jnp.tanh(x @ p), w))
    return w


# --------------------------------------------------------------- registry


def test_routing_version_bumps_only_on_actual_change():
    rt = RoutingTable()
    a, b = object(), object()
    assert rt.version == 0
    rt.publish({})  # empty publish: no epoch
    assert rt.version == 0
    rt.register("f", a)
    assert rt.version == 1
    rt.register("f", a)  # identical route: no epoch
    assert rt.version == 1
    rt.swap([], b)  # empty swap: no epoch
    assert rt.version == 1
    rt.swap(["f"], a)  # still identical: no epoch
    assert rt.version == 1
    rt.swap(["f"], b)
    assert rt.version == 2
    rt.publish({"f": b, "g": b})  # one real change among no-ops: ONE epoch
    assert rt.version == 3


# --------------------------------------------------- resolve-during-swap


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_concurrent_resolve_never_observes_draining(backend_cls):
    """Readers hammer resolve_entry while epoch publishes displace and
    retire the routed instance underneath them: the state read atomically
    with the route must never be DRAINING or RETIRED."""
    p = backend_cls(FusionPolicy(enabled=False))
    try:
        p.deploy(FunctionSpec("F", lambda ctx, params, x: x + 1, None))
        bad: list = []
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                inst, state = p.registry.resolve_entry("F")
                if state in (InstanceState.DRAINING, InstanceState.RETIRED):
                    bad.append((inst.instance_id, state))

        threads = [threading.Thread(target=reader, daemon=True) for _ in range(4)]
        for t in threads:
            t.start()
        spec = p.spec_of("F")
        for _ in range(60):
            fresh = FunctionInstance({"F": spec}, p)
            p.attach_instance(fresh)
            fresh.mark_ready()
            event = p.lifecycle.publish({"F": fresh}, kind="redeploy", reason="churn")
            assert event.retired, "each publish must retire the displaced instance"
        stop.set()
        for t in threads:
            t.join(timeout=10)
        assert not bad, f"resolve observed draining/retired instances: {bad[:5]}"
        assert p.registry.resolve("F").state == InstanceState.SERVING
    finally:
        p.shutdown()


# --------------------------------------------------------- split round trip


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_split_merge_round_trip_preserves_outputs(backend_cls):
    from repro.scheduler import VirtualClock

    # the policy's re-merge backoff runs on its own virtual clock: the test
    # expires the hysteresis window by advancing, not by sleeping
    policy_clock = VirtualClock()
    p = backend_cls(FusionPolicy(min_observations=1, merge_cost_s=0.0,
                                 remerge_backoff_s=0.05, clock=policy_clock))
    try:
        deploy_chain(p)
        x = jnp.ones((2, 8))
        ref = np.asarray(p.invoke("A", x))
        for _ in range(4):
            p.invoke("A", x)
        p.merger.wait_idle()
        fused = p.registry.resolve("A")
        assert fused.members.keys() == {"A", "B", "C"}, "chain must fully fuse"
        epoch_before = p.lifecycle.epoch

        event = p.merger.split(
            frozenset({"A", "B", "C"}),
            [frozenset({"A"}), frozenset({"B"}), frozenset({"C"})],
            reason="test fission",
        )
        assert event is not None and event.healthy
        assert event.epoch == p.lifecycle.epoch == epoch_before + 1
        assert set(event.checked_members), "split must health-check against canaries"
        # every member now routes to its own unit; the fused unit retired
        insts = {n: p.registry.resolve(n) for n in ("A", "B", "C")}
        assert len({id(i) for i in insts.values()}) == 3
        assert fused.state == InstanceState.RETIRED
        np.testing.assert_allclose(np.asarray(p.invoke("A", x)), ref, rtol=1e-5, atol=1e-6)

        # hysteresis: fresh hot traffic must NOT immediately re-merge
        n_merges = len(p.merger.merge_log)
        p.invoke("A", x)
        p.merger.wait_idle()
        assert len(p.merger.merge_log) == n_merges, "re-merge inside backoff window"

        # after the backoff expires the merge is allowed again (reversible
        # fusion, not permanent fission) and semantics still hold
        policy_clock.advance(0.08)
        for _ in range(6):
            p.invoke("A", x)
        p.merger.wait_idle()
        assert p.registry.resolve("A").members.keys() == {"A", "B", "C"}
        np.testing.assert_allclose(np.asarray(p.invoke("A", x)), ref, rtol=1e-5, atol=1e-6)
        stats = p.stats()
        kinds = [e["kind"] for e in stats["lifecycle"]["events"]]
        assert "split" in kinds and "merge" in kinds and "deploy" in kinds
        assert stats["splits"] and stats["splits"][0]["reason"] == "test fission"
    finally:
        p.shutdown()


def test_split_rejects_bad_partition_and_stale_group():
    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        deploy_chain(p)
        x = jnp.ones((2, 8))
        for _ in range(4):
            p.invoke("A", x)
        p.merger.wait_idle()
        with pytest.raises(ValueError):
            p.merger.split(frozenset({"A", "B", "C"}), [frozenset({"A"})])
        # a group that is not (or no longer) routed as one unit: no-op
        assert p.merger.split(frozenset({"A", "D"}), [frozenset({"A"}), frozenset({"D"})]) is None
    finally:
        p.shutdown()


# ----------------------------------------------------------- hysteresis


def test_fission_hysteresis_prevents_flapping():
    """Oscillating load must not flap merge<->split: saturation has to be
    *sustained* to split, a fresh merge cannot split inside its age floor,
    and a fresh split cannot re-merge inside its backoff. The backoff
    windows elapse on a virtual clock — no real sleeping."""
    from repro.scheduler import SchedulerSignals, VirtualClock

    clock = VirtualClock()
    policy = FusionPolicy(split_sustain=3, min_group_age_s=0.5,
                          remerge_backoff_s=0.2, split_occupancy=0.8, split_depth=2,
                          clock=clock)
    policy.commit("A", "B")
    members = frozenset({"A", "B"})
    hot = SchedulerSignals(queue_depth=10, mean_occupancy=0.95, p95_ms=50.0)
    cold = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=5.0)

    # too young: even sustained saturation cannot split
    for _ in range(5):
        assert not policy.decide_split(members, signals=hot, age_s=0.1).split

    # oscillating saturation: the streak resets, never reaches split_sustain
    for _ in range(6):
        assert not policy.decide_split(members, signals=hot, age_s=1.0).split
        assert not policy.decide_split(members, signals=hot, age_s=1.0).split
        assert not policy.decide_split(members, signals=cold, age_s=1.0).split

    # sustained saturation: splits on the 3rd consecutive evaluation
    assert not policy.decide_split(members, signals=hot, age_s=1.0).split
    assert not policy.decide_split(members, signals=hot, age_s=1.0).split
    d = policy.decide_split(members, signals=hot, age_s=1.0)
    assert d.split and "saturation" in d.reason
    assert set().union(*d.partition) == members

    # post-split: the edge is in backoff, decide() refuses to re-merge
    policy.dissolve(d.partition)
    from repro.core.handler import EdgeStats

    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    refused = policy.decide("A", "B", stats, "t", "t")
    assert not refused.fuse and "hysteresis" in refused.reason
    clock.advance(0.25)  # backoff expired (virtually): fusion available again
    assert policy.decide("A", "B", stats, "t", "t").fuse
    clock.assert_elapsed_real_below(10.0)


def test_decide_split_regret_signals():
    policy = FusionPolicy(min_group_age_s=0.0, regret_p95_factor=1.5,
                          cold_rate_ratio=0.1)
    members = frozenset({"A", "B"})
    # post-merge tail regression vs the commit-time baseline
    d = policy.decide_split(members, baseline_p95_ms=10.0, current_p95_ms=20.0, age_s=1.0)
    assert d.split and "p95" in d.reason
    # traffic divergence: only members with DIRECT pre-merge demand can go
    # cold — an interior chain member (baseline rate 0) never triggers it
    d = policy.decide_split(
        members, member_rates={"A": 100.0, "B": 0.0},
        baseline_rates={"A": 90.0, "B": 0.0}, age_s=1.0,
    )
    assert not d.split
    d = policy.decide_split(
        members, member_rates={"A": 100.0, "B": 0.0},
        baseline_rates={"A": 90.0, "B": 50.0}, age_s=1.0,
    )
    assert d.split and "diverged" in d.reason
    assert frozenset({"B"}) in d.partition  # cold member in its own cell


def test_healthy_fused_chain_never_splits_on_divergence():
    """A chain whose interior members are served by inlined calls must not
    read as 'traffic diverged': demand baselines count only direct client
    traffic and inbound edges from OUTSIDE the group, so a callee that was
    only ever reached through the chain has baseline 0 and is exempt."""
    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0,
                                    min_group_age_s=0.0))
    try:
        deploy_chain(p)
        x = jnp.ones((2, 8))
        for _ in range(5):
            p.invoke("A", x)  # client traffic lands on A only
        p.merger.wait_idle()
        assert p.registry.resolve("A").members.keys() == {"A", "B", "C"}
        rec = p.merger.committed_groups()[0]
        assert rec.baseline_rates["B"] == 0.0 and rec.baseline_rates["C"] == 0.0
        # repeated regret evaluations on the hot chain: never a split
        for _ in range(5):
            assert p.merger.evaluate_splits() == []
        assert p.registry.resolve("A").members.keys() == {"A", "B", "C"}
    finally:
        p.shutdown()


def test_failed_split_is_quarantined_not_retried():
    from repro.core import SplitDecision

    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        w = jnp.eye(8) * 0.5
        p.deploy(FunctionSpec("A", lambda ctx, q, x: ctx.call("B", x @ q), w))
        p.deploy(FunctionSpec("B", lambda ctx, q, x: jnp.tanh(x @ q), w))
        x = jnp.ones((2, 8))
        for _ in range(3):
            p.invoke("A", x)
        p.merger.wait_idle()
        fused = p.registry.resolve("A")
        assert fused.members.keys() == {"A", "B"}
        # corrupt B's SPEC: rebuilt units diverge from the live fused unit
        good = p._specs["B"]
        p._specs["B"] = FunctionSpec("B", lambda ctx, q, xx: jnp.tanh(xx @ q) + 100.0, good.params)

        members = frozenset({"A", "B"})
        cells = [frozenset({"A"}), frozenset({"B"})]
        event = p.merger.split(members, cells, reason="doomed")
        assert event is not None and not event.healthy
        assert event.reason == "health check failed"
        assert p.registry.resolve("A") is fused, "unhealthy split must not swap"

        # a persistent regret signal must NOT rebuild the doomed partition
        # on every evaluation — the failed member set is quarantined
        p.policy.decide_split = lambda *a, **k: SplitDecision(True, "forced", tuple(cells))
        n_events = len(p.merger.split_log)
        assert p.merger.evaluate_splits() == []
        assert len(p.merger.split_log) == n_events, "quarantined split was rebuilt"
    finally:
        p.shutdown()


# ------------------------------------------------------------- redeploy


def test_redeploy_retires_displaced_worker():
    p = OrchestratedBackend(FusionPolicy(enabled=False))
    try:
        p.deploy(FunctionSpec("B", lambda ctx, params, x: x + 1, None))
        old = p.registry.resolve("B")
        old_worker = p._workers[old.instance_id]
        ram_before = p.ram_bytes()
        # simulate a crashed container
        old.state = InstanceState.RETIRED
        old.params = {}
        assert int(p.invoke("B", jnp.int32(1))) == 2  # re-provisions
        fresh = p.registry.resolve("B")
        assert fresh is not old and fresh.state == InstanceState.SERVING
        old_worker.thread.join(timeout=10)
        assert not old_worker.thread.is_alive(), "displaced pod's loop must exit"
        assert old.instance_id not in p._workers, "displaced pod leaked"
        # a leaked instance would add its whole 32 MiB runtime constant; the
        # few bytes of freshly-compiled entry workspace must not trip this
        from repro.core.function import INSTANCE_RUNTIME_OVERHEAD_BYTES

        assert p.ram_bytes() < ram_before + INSTANCE_RUNTIME_OVERHEAD_BYTES, \
            "retired instance still counted in RAM"
        events = [e for e in p.lifecycle.stats()["events"] if e["kind"] == "redeploy"]
        assert events and old.instance_id in events[-1]["retired"]
    finally:
        p.shutdown()


# ------------------------------------------------------- merger threads


def test_merger_threads_pruned_under_async_build():
    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0),
                       async_build=True)
    try:
        deploy_chain(p)
        x = jnp.ones((2, 8))
        # park a pile of completed threads where submit used to leak them
        for _ in range(50):
            t = threading.Thread(target=lambda: None)
            t.start()
            t.join()
            p.merger._threads.append(t)
        for _ in range(4):
            p.invoke("A", x)
        p.merger.wait_idle()
        assert p.merger._threads == [], "wait_idle must prune completed builds"
        assert [m for m in p.merger.merge_log if m.healthy], "merge must have run"
    finally:
        p.shutdown()


# ----------------------------------------------------- trough + barrier


def test_scheduler_trough_and_quiesce_barrier():
    release = threading.Event()

    def dispatch(name, args_list):
        release.wait(2.0)
        return [a[0] for a in args_list]

    s = RequestScheduler(dispatch, max_batch=4, max_delay_ms=1.0)
    try:
        futs = [s.submit("f", (i,)) for i in range(4)]
        deadline = time.perf_counter() + 1.0
        saw_busy = False
        while time.perf_counter() < deadline:
            if not s.is_trough(min_quiet_s=0.0):
                saw_busy = True
                break
            time.sleep(0.001)
        assert saw_busy, "in-flight batch must defeat the trough detector"
        assert not s.quiesce(timeout=0.05), "quiesce must time out while busy"
        release.set()
        assert s.quiesce(timeout=5.0), "drain barrier must clear after dispatch"
        for f in futs:
            assert f.result(timeout=5) is not None
        time.sleep(0.02)
        assert s.is_trough(min_quiet_s=0.01), "quiet + drained = trough"
    finally:
        s.shutdown()


def test_reconciler_executes_queued_transition_in_trough():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        ran = threading.Event()
        p.lifecycle.enqueue(ran.set, kind="test", names=("X",), max_defer_s=30.0)
        # no traffic at all -> permanent trough -> runs on the next tick,
        # long before the 30s deadline
        assert ran.wait(5.0), "reconciler must run queued work in a trough"
    finally:
        p.shutdown()
